"""Paper Fig. 12 (Sec. 4.4): scheduling overhead at cluster scale —
per-request predict+schedule wall-clock at 1..64 nodes (8 RPS/node,
queue depth up to 1000, 10k history)."""

from repro.simulator import measure_scheduler_overhead

from .common import emit


def run(quick=False):
    rows = []
    nodes = (1, 8, 64) if quick else (1, 4, 16, 64)
    for n in nodes:
        o = measure_scheduler_overhead(n, n_probe=30 if quick else 100)
        rows.append((f"fig12.predict_ms.n{n}", round(o["predict_ms"], 3),
                     "per_request_ms"))
        rows.append((f"fig12.schedule_ms.n{n}", round(o["schedule_ms"], 3),
                     "per_request_ms"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
