"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig7,...]

Prints ``name,value,derived`` CSV rows (harness contract).
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (bench_kernels, bench_scheduler, fig7_end_to_end,
               fig8_per_dataset, fig9_predictor, fig10_cost_model,
               fig11_policy, fig12_scalability, fig13_sensitivity, roofline)

SUITES = {
    "scheduler": bench_scheduler.run,
    "fig7": fig7_end_to_end.run,
    "fig8": fig8_per_dataset.run,
    "fig9": fig9_predictor.run,
    "fig10": fig10_cost_model.run,
    "fig11": fig11_policy.run,
    "fig12": fig12_scalability.run,
    "fig13": fig13_sensitivity.run,
    "roofline": roofline.run,
    "kernels": bench_kernels.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps for CI")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")
    for name in names:
        if name not in SUITES:
            print(f"unknown suite {name!r}; have {list(SUITES)}",
                  file=sys.stderr)
            sys.exit(2)
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        SUITES[name](quick=args.quick)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
