"""Paper Fig. 8: per-dataset TTLT (ShareGPT / Alpaca-Summarization /
Document-Write separately)."""

from .common import emit, run_policy, seed_records, workload

POLICIES = ("fcfs", "fastserve", "ssjf", "ltr", "trail", "sagesched")


def run(n=500, rps=8.0, quick=False):
    rows = []
    for ds in ("sharegpt", "alpaca", "write"):
        reqs = workload(n=n, rps=rps, datasets=(ds,))
        records = seed_records()
        for pol in (POLICIES if not quick else ("fcfs", "trail",
                                                "sagesched")):
            res = run_policy(pol, reqs, records=records)
            rows.append((f"fig8.ttlt.{ds}.{pol}", round(res.mean_ttlt(), 3),
                         "mean_ttlt_s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
