"""Prediction-drift regret bench: graceful degradation of the hedged
scheduler when the length predictor rots.

The experiment isolates the robustness question PR 10 answers: SageSched
with a *frozen* predictor is great while predictions hold and silently
bad once the workload drifts away from them; the hedged scheduler
(``Scheduler(policy="hedged", posterior_quantile=...)``) must track
frozen Gittins when predictions are good AND refuse to cliff when they
are not.  Setup:

  * **Frozen predictor** — an ``OraclePredictor`` registered, per
    prompt, with the request's cluster-level output-length distribution
    from the UNDRIFTED workload: the best predictor money can buy the
    day it was trained.  The drifted traces multiply true output
    lengths (``generate_workload(drift_scale=...)``) while prompts and
    clusters stay put, so this predictor is honestly, progressively
    wrong — exactly the failure ``FlakyPredictor(mode="drift")``
    injects, produced here at the workload level so every policy sees
    one identical trace.
  * **Oracle baseline** — the same predictor rebuilt with each
    request's DRIFTED cluster distribution (``scale_distribution`` by
    the recorded per-request ``drift_factor``): distributional
    knowledge of the drift, the regret reference.
  * **Policies** — ``frozen_gittins`` (SageSched, beliefs frozen at
    admission), ``fcfs`` (prediction-free), ``hedged`` (multiplicative-
    weights blend of both orderings + mid-flight posterior truncation
    at the 0.9 quantile + calibration-driven conformal widening).
  * **Traces** — ``none`` (no drift), ``drift2x`` (2x length ramp
    settling mid-trace), ``adversarial`` (3x oscillating drift: any
    frozen correction is wrong half the time).

Metric: mean slowdown = TTLT / ideal single-request service time
(prefill + solo decode from the ServiceModel), plus regret vs the
oracle run.  The CI-asserted gates live in ``["drift"]["gates"]``:
hedged within 5% of frozen Gittins at no-drift, and >= 10% better mean
slowdown under the 2x drift trace.

Results merge into BENCH_scheduler.json under the ``drift`` key.

    PYTHONPATH=src python benchmarks/bench_drift.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from common import PROFILES
from repro.core import (OraclePredictor, Scheduler, empirical_distribution,
                        make_policy)
from repro.simulator import NodeSpec, ServiceModel, generate_workload
from repro.simulator.simulator import simulate
from repro.testing import scale_distribution

PROFILE = PROFILES["sharegpt"]
# Constrained node: with the default 256 decode slots everything runs
# concurrently and scheduling order is irrelevant — 16 slots puts the
# node in the contended regime where ordering decides slowdown.
SPEC = NodeSpec(max_batch=16)
MODEL = ServiceModel(SPEC)

TRACES = {
    "none": dict(),
    "drift2x": dict(drift_scale=2.0, drift_mode="ramp",
                    drift_start=0.25, drift_ramp=0.2),
    "adversarial": dict(drift_scale=3.0, drift_mode="oscillate",
                        drift_start=0.2, drift_ramp=0.15),
}


def _cluster_dists(seed: int = 7) -> dict:
    """Undrifted per-cluster empirical output-length distributions —
    what a well-trained predictor knows on deployment day."""
    rng = np.random.default_rng(seed)
    return {c.cluster_id: empirical_distribution(
                c.true_length_samples(rng, 512))
            for c in PROFILE.clusters}


def _frozen_predictor(reqs, dists) -> OraclePredictor:
    o = OraclePredictor()
    for r in reqs:
        o.register(r.prompt, dists[r.cluster.cluster_id])
    return o


def _oracle_predictor(reqs, dists) -> OraclePredictor:
    """Drift-aware reference: the cluster distribution scaled by the
    request's recorded drift factor (same transform the workload
    generator applied to the truth)."""
    o = OraclePredictor()
    for r in reqs:
        d = dists[r.cluster.cluster_id]
        if r.drift_factor != 1.0:
            d = scale_distribution(d, r.drift_factor)
        o.register(r.prompt, d)
    return o


def _mean_slowdown(result) -> float:
    """TTLT over the ideal solo service time (prefill + lone decode)."""
    slow = []
    for m in result.metrics:
        ideal = (MODEL.prefill_time(m.input_len)
                 + MODEL.decode_run_time(1, m.input_len, m.output_len))
        slow.append(m.ttlt / ideal)
    return float(np.mean(slow))


def _run(policy_name: str, reqs, predictor, *,
         posterior_quantile=None) -> dict:
    sched = Scheduler(policy=make_policy(policy_name), predictor=predictor,
                      posterior_quantile=posterior_quantile)
    res = simulate(reqs, sched, spec=SPEC)
    out = {"mean_slowdown": _mean_slowdown(res),
           "posterior_updates": res.scheduler_stats.get(
               "posterior_updates", 0)}
    hedge = res.scheduler_stats.get("hedge")
    if hedge:
        out["hedge"] = hedge
    return out


def bench_drift(smoke: bool) -> dict:
    n = 150 if smoke else 400
    rps = 6.0
    dists = _cluster_dists()
    out: dict = {"n_requests": n, "rps": rps, "traces": {}}
    for trace, kw in TRACES.items():
        reqs = generate_workload([PROFILE], n, rps=rps, seed=11, **kw)
        frozen = lambda: _frozen_predictor(reqs, dists)  # noqa: E731
        rows = {
            "frozen_gittins": _run("sagesched", reqs, frozen()),
            "fcfs": _run("fcfs", reqs, frozen()),
            "hedged": _run("hedged", reqs, frozen(),
                           posterior_quantile=0.9),
            "oracle": _run("sagesched", reqs,
                           _oracle_predictor(reqs, dists)),
        }
        oracle = rows["oracle"]["mean_slowdown"]
        for row in rows.values():
            row["regret"] = row["mean_slowdown"] - oracle
        out["traces"][trace] = rows
    t = out["traces"]
    hedged_none = t["none"]["hedged"]["mean_slowdown"]
    gittins_none = t["none"]["frozen_gittins"]["mean_slowdown"]
    hedged_2x = t["drift2x"]["hedged"]["mean_slowdown"]
    gittins_2x = t["drift2x"]["frozen_gittins"]["mean_slowdown"]
    out["gates"] = {
        # graceful degradation, both directions: no tax when predictions
        # are good, no cliff when they rot
        "no_drift_within_5pct": bool(hedged_none <= 1.05 * gittins_none),
        "no_drift_ratio": hedged_none / gittins_none,
        "drift2x_at_least_10pct_better": bool(
            hedged_2x <= 0.90 * gittins_2x),
        "drift2x_ratio": hedged_2x / gittins_2x,
    }
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: minimal sizes")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         .parent / "BENCH_scheduler.json"))
    args = ap.parse_args(argv)

    drift = bench_drift(args.smoke)
    path = Path(args.out)
    doc = json.loads(path.read_text()) if path.exists() else {}
    doc["drift"] = drift
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(json.dumps(drift, indent=2, sort_keys=True))
    return drift


if __name__ == "__main__":
    main()
