"""Scheduler decision-throughput benchmark: object path vs batch path.

Measures the three hot operations of the decision loop at several queue
depths, for each priority backend:

  * admit/sec    — predict + cost pushforward + initial priority,
  * refresh/sec  — bucket-boundary priority recomputation (the paper's
                   runtime Gittins refresh; Fig. 12's scaling bottleneck),
  * order() ms   — full-queue priority ranking.

The object backend is the seed's per-request scalar path; numpy is the
vectorized BatchState path (bit-identical results); pallas runs the
Gittins kernel (interpret-mode off-TPU, so only meaningful as a hot path
on real hardware — enable with --backends ...,pallas).

An *admission* sweep times the batch-first ingress (PR 3): one
``admit_batch`` call vs the equivalent scalar ``admit`` loop at burst
sizes 1/32/256/1024 with the real ``SemanticHistoryPredictor`` over a
full 10k history window (the `admit.*` rows; acceptance: >= 5x at 1024).
A *routing* sweep compares jsow vs cost-aware vs quantile-of-cost
placement on one workload.

A second sweep measures the *cluster* decision path (paper Fig. 12): one
central scheduler in front of 1→64 nodes at 8 RPS/node, standing queue
scaled with load — per-arrival predict and schedule (cluster-wide batched
refresh + node-masked order) wall-clock through
``repro.simulator.measure_scheduler_overhead``.  The headline acceptance
metric is *sublinearity*: schedule-stage cost divided by node count must
shrink as the cluster grows (the refresh is one fused array pass, not 64
per-node loops).

Emits BENCH_scheduler.json (repo root by default) so future PRs can
track the trajectory.

    PYTHONPATH=src python benchmarks/bench_scheduler.py [--quick|--smoke]
"""

from __future__ import annotations

import argparse
import json
import time
import zlib
from pathlib import Path

import numpy as np

from repro.core import (LengthDistribution, Predictor, ResourceBoundCost,
                        Scheduler, SemanticHistoryPredictor, make_policy)


class PooledPredictor(Predictor):
    """Deterministic zero-cost predictor: a fixed pool of pre-generated
    length distributions keyed by prompt, so the benchmark times the
    scheduler, not the embedding stack."""

    def __init__(self, pool: int = 256, max_support: int = 48, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.dists = []
        for _ in range(pool):
            k = int(rng.integers(4, max_support + 1))
            lens = np.sort(rng.choice(np.arange(1, 4096), k, replace=False))
            self.dists.append(LengthDistribution(lens, rng.dirichlet(
                np.ones(k))))

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        # crc32, not hash(): PYTHONHASHSEED randomizes the latter per
        # process, which would make the recorded trajectory irreproducible
        return self.dists[zlib.crc32(prompt.encode()) % len(self.dists)]


def make_scheduler(backend: str, policy: str, bucket_size: int) -> Scheduler:
    return Scheduler(predictor=PooledPredictor(),
                     cost_model=ResourceBoundCost(),
                     policy=make_policy(policy),
                     bucket_size=bucket_size,
                     priority_backend=backend)


def bench_one(backend: str, depth: int, *, policy: str = "sagesched",
              bucket_size: int = 200, reps: int = 3) -> dict:
    sched = make_scheduler(backend, policy, bucket_size)
    rng = np.random.default_rng(depth)
    input_lens = rng.integers(16, 2048, depth)

    t0 = time.perf_counter()
    for i in range(depth):
        sched.admit(f"r{i}", f"prompt-{i % 256}", int(input_lens[i]),
                    arrival=float(i))
    admit_s = time.perf_counter() - t0

    # refresh cycle: push every request across its next bucket boundary,
    # then (batch path) recompute all dirty priorities in one call.  The
    # object path refreshes eagerly inside on_progress_many — both
    # timings cover the same boundary crossings end to end.
    ids = [f"r{i}" for i in range(depth)]
    gen = np.zeros(depth, np.int64)
    refresh_s = 0.0
    n_refreshed = 0
    for _ in range(reps):
        gen += bucket_size
        t0 = time.perf_counter()
        sched.on_progress_many(ids, gen)
        sched.refresh()
        refresh_s += time.perf_counter() - t0
        n_refreshed += depth

    order_times = []
    for _ in range(max(3, reps)):
        t0 = time.perf_counter()
        sched.order()
        order_times.append(time.perf_counter() - t0)

    return {
        "backend": backend,
        "depth": depth,
        "policy": policy,
        "admit_per_s": depth / admit_s,
        "refresh_per_s": n_refreshed / refresh_s,
        "order_ms": float(np.median(order_times) * 1e3),
        "refreshes_counted": sched.stats["refreshes"],
    }


def _seeded_semantic_predictor(history_size: int = 10_000, pool: int = 256,
                               seed: int = 0) -> SemanticHistoryPredictor:
    """The paper's predictor over a full 10k history window, seeded from a
    pool of prompt templates (bursty traffic repeats semantics — Fig. 4)."""
    rng = np.random.default_rng(seed)
    words = ("alpha beta gamma delta epsilon zeta eta theta iota kappa "
             "lambda mu nu xi omicron pi rho sigma tau upsilon").split()
    prompts = [" ".join(rng.choice(words, size=16)) for _ in range(pool)]
    reps = max(1, history_size // pool)
    pred = SemanticHistoryPredictor()
    pred.seed(prompts * reps, np.full(pool * reps, 128),
              rng.integers(50, 2000, pool * reps))
    pred._bench_pool = prompts          # reused by bench_admission
    return pred


def bench_admission(bursts: list[int], history_size: int = 10_000,
                    seed: int = 0) -> list[dict]:
    """Admission-throughput sweep: one ``admit_batch`` call vs the
    equivalent scalar ``admit`` loop, per burst size, with the real
    ``SemanticHistoryPredictor`` over a 10k history (the batched ingress
    acceptance metric: >= 5x at 1024-request bursts on CPU).  Both sides
    share the predictor (reads only), so the comparison isolates the
    ingress path: batched history search + batched pushforward +
    single-pass BatchState append vs the per-request loop."""
    pred = _seeded_semantic_predictor(history_size, seed=seed)
    pool = pred._bench_pool
    # warm the prompt-embedding memo for the whole pool so neither timed
    # side pays one-off embedding of a prompt the other then gets for
    # free (the scalar loop runs first and would otherwise hand the
    # batched side a fully warm cache)
    pred.predict_batch(pool, [128] * len(pool))
    rng = np.random.default_rng(seed + 1)
    rows = []
    for burst in bursts:
        prompts = [pool[i % len(pool)] for i in range(burst)]
        input_lens = [int(x) for x in rng.integers(16, 1024, burst)]
        arrivals = [float(i) for i in range(burst)]
        ids = [f"r{i}" for i in range(burst)]
        mk = lambda: Scheduler(predictor=pred,
                               cost_model=ResourceBoundCost(),
                               policy=make_policy("sagesched"),
                               priority_backend="numpy")
        scalar_sched, batch_sched = mk(), mk()
        t0 = time.perf_counter()
        for i in range(burst):
            scalar_sched.admit(ids[i], prompts[i], input_lens[i],
                               arrival=arrivals[i])
        t_scalar = time.perf_counter() - t0
        t0 = time.perf_counter()
        batch_sched.admit_batch(ids, prompts, input_lens, arrivals=arrivals)
        t_batch = time.perf_counter() - t0
        assert scalar_sched.order() == batch_sched.order()  # parity guard
        rows.append({
            "burst": burst,
            "history_size": history_size,
            "scalar_per_s": burst / t_scalar,
            "batched_per_s": burst / t_batch,
            "speedup": t_scalar / t_batch,
        })
        print(f"admit burst={burst:>5d}  scalar/s={burst / t_scalar:>8.0f}  "
              f"batched/s={burst / t_batch:>8.0f}  "
              f"speedup={t_scalar / t_batch:.1f}x")
    return rows


def bench_routing(n_requests: int, n_nodes: int, seed: int = 0
                  ) -> list[dict]:
    """Router sweep on one workload: jsow baseline vs cost-aware routing
    on the predicted mean vs its 0.9-quantile (robust placement under
    heavy-tailed predictions, cf. arXiv:2508.14544)."""
    from repro.simulator import generate_workload, make_profile, \
        simulate_cluster

    profiles = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]
    reqs = generate_workload(profiles, n_requests, rps=6.0 * n_nodes,
                             seed=seed)
    rows = []
    for router, quantile in (("jsow", None), ("cost", None), ("cost", 0.9)):
        res = simulate_cluster(
            reqs, lambda: Scheduler(policy=make_policy("sagesched")),
            n_nodes, router=router, route_quantile=quantile)
        rows.append({
            "router": res.router,
            "n_nodes": n_nodes,
            "n_requests": n_requests,
            "mean_ttlt_s": res.mean_ttlt,
            "mean_ttft_s": res.mean_ttft,
        })
        print(f"routing {res.router:>10s} nodes={n_nodes}  "
              f"ttlt={res.mean_ttlt:7.2f}s  ttft={res.mean_ttft:7.2f}s")
    return rows


def bench_cluster(nodes: list[int], backends: list[str],
                  n_probe: int, pallas_probe: int = 5) -> list[dict]:
    """Fig. 12 cluster sweep: central-scheduler per-arrival overhead at
    1→64 nodes through the real batched path (shared BatchState admit,
    cluster-wide refresh, node-masked order)."""
    from repro.simulator import measure_scheduler_overhead

    rows = []
    for backend in backends:
        probes = pallas_probe if backend == "pallas" else n_probe
        for n in nodes:
            o = measure_scheduler_overhead(n, n_probe=probes,
                                           backend=backend)
            rows.append(o)
            print(f"cluster {backend:>7s} nodes={n:>3d} "
                  f"depth={o['queue_depth']:>5d}  "
                  f"predict={o['predict_ms']:.3f} ms  "
                  f"schedule={o['schedule_ms']:.3f} ms")
    return rows


def _sublinearity(rows: list[dict]) -> dict:
    """schedule_ms growth vs node-count growth per backend; < 1 means the
    central refresh scales sublinearly in cluster size (the acceptance
    criterion for the shared-BatchState design)."""
    out = {}
    for backend in {r["backend"] for r in rows}:
        sub = sorted((r for r in rows if r["backend"] == backend),
                     key=lambda r: r["n_nodes"])
        lo, hi = sub[0], sub[-1]
        if hi["n_nodes"] > lo["n_nodes"]:
            growth = hi["schedule_ms"] / max(lo["schedule_ms"], 1e-9)
            out[backend] = {
                "nodes": [lo["n_nodes"], hi["n_nodes"]],
                "schedule_ms": [lo["schedule_ms"], hi["schedule_ms"]],
                "growth": growth,
                "per_node": growth / (hi["n_nodes"] / lo["n_nodes"]),
            }
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small depths + fewer reps")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: --quick depths + tiny cluster sweep")
    ap.add_argument("--depths", default=None,
                    help="comma-separated queue depths")
    ap.add_argument("--backends", default="object,numpy",
                    help="comma-separated: object,numpy,pallas")
    ap.add_argument("--cluster-nodes", default=None,
                    help="comma-separated node counts for the cluster "
                         "sweep (default 1,4,16,64; empty string skips)")
    ap.add_argument("--cluster-backends", default="numpy,pallas",
                    help="backends for the cluster sweep")
    ap.add_argument("--policy", default="sagesched")
    ap.add_argument("--bucket-size", type=int, default=200)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--bursts", default=None,
                    help="comma-separated admission burst sizes "
                         "(default 1,32,256,1024; empty string skips)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_scheduler.json"))
    args = ap.parse_args(argv)

    quick = args.quick or args.smoke
    if args.depths:
        depths = [int(d) for d in args.depths.split(",")]
    else:
        depths = [100, 1000] if quick else [100, 1000, 10000]
    reps = args.reps or (2 if quick else 3)
    backends = args.backends.split(",")

    results = []
    for depth in depths:
        for backend in backends:
            r = bench_one(backend, depth, policy=args.policy,
                          bucket_size=args.bucket_size, reps=reps)
            results.append(r)
            print(f"{backend:>7s} depth={depth:>6d}  "
                  f"admit/s={r['admit_per_s']:>10.0f}  "
                  f"refresh/s={r['refresh_per_s']:>10.0f}  "
                  f"order={r['order_ms']:.3f} ms")

    speedup = {}
    for depth in depths:
        by = {r["backend"]: r for r in results if r["depth"] == depth}
        if "object" in by and "numpy" in by:
            speedup[str(depth)] = {
                "refresh": by["numpy"]["refresh_per_s"]
                / by["object"]["refresh_per_s"],
                "order": by["object"]["order_ms"] / by["numpy"]["order_ms"],
                "admit": by["numpy"]["admit_per_s"]
                / by["object"]["admit_per_s"],
            }
            print(f"numpy vs object @ {depth}: "
                  f"{speedup[str(depth)]['refresh']:.1f}x refresh, "
                  f"{speedup[str(depth)]['order']:.1f}x order")

    # batched-ingress sections: admission bursts + router sweep.  Cheap
    # enough (~seconds) to run under --smoke unchanged, so CI tracks the
    # admit.* speedups on every push.
    if args.bursts == "":
        bursts = []
    elif args.bursts:
        bursts = [int(b) for b in args.bursts.split(",")]
    else:
        bursts = [1, 32, 256, 1024]
    admission_rows = bench_admission(bursts) if bursts else []
    routing_rows = bench_routing(n_requests=60 if quick else 300,
                                 n_nodes=2 if quick else 4)

    if args.cluster_nodes == "":
        nodes = []
    elif args.cluster_nodes:
        nodes = [int(n) for n in args.cluster_nodes.split(",")]
    else:
        nodes = [1, 8] if quick else [1, 4, 16, 64]
    cluster_rows = []
    sublinearity = {}
    if nodes:
        cluster_rows = bench_cluster(
            nodes, args.cluster_backends.split(","),
            n_probe=10 if quick else 100,
            pallas_probe=3 if quick else 5)
        sublinearity = _sublinearity(cluster_rows)
        for backend, s in sublinearity.items():
            print(f"cluster sublinearity [{backend}]: schedule cost "
                  f"x{s['growth']:.2f} over x{s['nodes'][1] // s['nodes'][0]}"
                  f" nodes ({s['per_node']:.3f} per-node ratio)")

    payload = {
        "bench": "scheduler_decision_throughput",
        "policy": args.policy,
        "bucket_size": args.bucket_size,
        "reps": reps,
        "results": results,
        "speedup_numpy_vs_object": speedup,
        "admission": {
            "predictor": "semantic_history",
            "history_size": 10_000,
            "results": admission_rows,
            "speedup": {str(r["burst"]): round(r["speedup"], 2)
                        for r in admission_rows},
        },
        "routing": routing_rows,
        "cluster": {
            "rps_per_node": 8.0,
            "results": cluster_rows,
            "sublinearity": sublinearity,
        },
    }
    out_path = Path(args.out)
    doc = {}
    if out_path.exists():
        try:
            doc = json.loads(out_path.read_text())
        except (json.JSONDecodeError, OSError):
            doc = {}
    # merge: other benchmarks (bench_engine.py's "engine" section) own
    # their top-level keys in the same trajectory file
    doc.update(payload)
    out_path.write_text(json.dumps(doc, indent=2) + "\n")
    print(f"wrote {args.out}")
    return payload


def run(quick: bool = False):
    """Harness adapter (benchmarks.run): emit name,value,derived rows."""
    try:
        from .common import emit       # python -m benchmarks.run
    except ImportError:
        from common import emit        # direct script execution
    payload = main(["--quick"] if quick else [])
    rows = []
    for r in payload["results"]:
        tag = f"scheduler.{r['backend']}_{r['depth']}"
        rows.append((f"{tag}.refresh_per_s", round(r["refresh_per_s"]),
                     "refresh_per_s"))
        rows.append((f"{tag}.order_ms", round(r["order_ms"], 3), "ms"))
    for depth, s in payload["speedup_numpy_vs_object"].items():
        rows.append((f"scheduler.speedup_{depth}.refresh",
                     round(s["refresh"], 2), "x_vs_object"))
    for r in payload["admission"]["results"]:
        tag = f"admit.burst_{r['burst']}"
        rows.append((f"{tag}.batched_per_s", round(r["batched_per_s"]),
                     "admissions_per_s"))
        rows.append((f"{tag}.speedup", round(r["speedup"], 2),
                     "x_vs_scalar_loop"))
    for r in payload["routing"]:
        rows.append((f"routing.{r['router']}.mean_ttlt",
                     round(r["mean_ttlt_s"], 3), "s"))
    for r in payload["cluster"]["results"]:
        tag = f"scheduler.cluster_{r['backend']}_n{r['n_nodes']}"
        rows.append((f"{tag}.schedule_ms", round(r["schedule_ms"], 3), "ms"))
    for backend, s in payload["cluster"]["sublinearity"].items():
        rows.append((f"scheduler.cluster_{backend}.per_node_ratio",
                     round(s["per_node"], 4), "lt1_is_sublinear"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
