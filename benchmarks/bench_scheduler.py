"""Scheduler decision-throughput benchmark: object path vs batch path.

Measures the three hot operations of the decision loop at several queue
depths, for each priority backend:

  * admit/sec    — predict + cost pushforward + initial priority,
  * refresh/sec  — bucket-boundary priority recomputation (the paper's
                   runtime Gittins refresh; Fig. 12's scaling bottleneck),
  * order() ms   — full-queue priority ranking.

The object backend is the seed's per-request scalar path; numpy is the
vectorized BatchState path (bit-identical results); pallas runs the
Gittins kernel (interpret-mode off-TPU, so only meaningful as a hot path
on real hardware — enable with --backends ...,pallas).

Emits BENCH_scheduler.json (repo root by default) so future PRs can
track the trajectory.

    PYTHONPATH=src python benchmarks/bench_scheduler.py [--quick]
"""

from __future__ import annotations

import argparse
import json
import time
import zlib
from pathlib import Path

import numpy as np

from repro.core import (LengthDistribution, Predictor, ResourceBoundCost,
                        Scheduler, make_policy)


class PooledPredictor(Predictor):
    """Deterministic zero-cost predictor: a fixed pool of pre-generated
    length distributions keyed by prompt, so the benchmark times the
    scheduler, not the embedding stack."""

    def __init__(self, pool: int = 256, max_support: int = 48, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.dists = []
        for _ in range(pool):
            k = int(rng.integers(4, max_support + 1))
            lens = np.sort(rng.choice(np.arange(1, 4096), k, replace=False))
            self.dists.append(LengthDistribution(lens, rng.dirichlet(
                np.ones(k))))

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        # crc32, not hash(): PYTHONHASHSEED randomizes the latter per
        # process, which would make the recorded trajectory irreproducible
        return self.dists[zlib.crc32(prompt.encode()) % len(self.dists)]


def make_scheduler(backend: str, policy: str, bucket_size: int) -> Scheduler:
    return Scheduler(predictor=PooledPredictor(),
                     cost_model=ResourceBoundCost(),
                     policy=make_policy(policy),
                     bucket_size=bucket_size,
                     priority_backend=backend)


def bench_one(backend: str, depth: int, *, policy: str = "sagesched",
              bucket_size: int = 200, reps: int = 3) -> dict:
    sched = make_scheduler(backend, policy, bucket_size)
    rng = np.random.default_rng(depth)
    input_lens = rng.integers(16, 2048, depth)

    t0 = time.perf_counter()
    for i in range(depth):
        sched.admit(f"r{i}", f"prompt-{i % 256}", int(input_lens[i]),
                    arrival=float(i))
    admit_s = time.perf_counter() - t0

    # refresh cycle: push every request across its next bucket boundary,
    # then (batch path) recompute all dirty priorities in one call.  The
    # object path refreshes eagerly inside on_progress_many — both
    # timings cover the same boundary crossings end to end.
    ids = [f"r{i}" for i in range(depth)]
    gen = np.zeros(depth, np.int64)
    refresh_s = 0.0
    n_refreshed = 0
    for _ in range(reps):
        gen += bucket_size
        t0 = time.perf_counter()
        sched.on_progress_many(ids, gen)
        sched.refresh()
        refresh_s += time.perf_counter() - t0
        n_refreshed += depth

    order_times = []
    for _ in range(max(3, reps)):
        t0 = time.perf_counter()
        sched.order()
        order_times.append(time.perf_counter() - t0)

    return {
        "backend": backend,
        "depth": depth,
        "policy": policy,
        "admit_per_s": depth / admit_s,
        "refresh_per_s": n_refreshed / refresh_s,
        "order_ms": float(np.median(order_times) * 1e3),
        "refreshes_counted": sched.stats["refreshes"],
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small depths + fewer reps (CI smoke)")
    ap.add_argument("--depths", default=None,
                    help="comma-separated queue depths")
    ap.add_argument("--backends", default="object,numpy",
                    help="comma-separated: object,numpy,pallas")
    ap.add_argument("--policy", default="sagesched")
    ap.add_argument("--bucket-size", type=int, default=200)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent.parent
                                         / "BENCH_scheduler.json"))
    args = ap.parse_args(argv)

    if args.depths:
        depths = [int(d) for d in args.depths.split(",")]
    else:
        depths = [100, 1000] if args.quick else [100, 1000, 10000]
    reps = args.reps or (2 if args.quick else 3)
    backends = args.backends.split(",")

    results = []
    for depth in depths:
        for backend in backends:
            r = bench_one(backend, depth, policy=args.policy,
                          bucket_size=args.bucket_size, reps=reps)
            results.append(r)
            print(f"{backend:>7s} depth={depth:>6d}  "
                  f"admit/s={r['admit_per_s']:>10.0f}  "
                  f"refresh/s={r['refresh_per_s']:>10.0f}  "
                  f"order={r['order_ms']:.3f} ms")

    speedup = {}
    for depth in depths:
        by = {r["backend"]: r for r in results if r["depth"] == depth}
        if "object" in by and "numpy" in by:
            speedup[str(depth)] = {
                "refresh": by["numpy"]["refresh_per_s"]
                / by["object"]["refresh_per_s"],
                "order": by["object"]["order_ms"] / by["numpy"]["order_ms"],
                "admit": by["numpy"]["admit_per_s"]
                / by["object"]["admit_per_s"],
            }
            print(f"numpy vs object @ {depth}: "
                  f"{speedup[str(depth)]['refresh']:.1f}x refresh, "
                  f"{speedup[str(depth)]['order']:.1f}x order")

    payload = {
        "bench": "scheduler_decision_throughput",
        "policy": args.policy,
        "bucket_size": args.bucket_size,
        "reps": reps,
        "results": results,
        "speedup_numpy_vs_object": speedup,
    }
    Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.out}")
    return payload


def run(quick: bool = False):
    """Harness adapter (benchmarks.run): emit name,value,derived rows."""
    try:
        from .common import emit       # python -m benchmarks.run
    except ImportError:
        from common import emit        # direct script execution
    payload = main(["--quick"] if quick else [])
    rows = []
    for r in payload["results"]:
        tag = f"scheduler.{r['backend']}_{r['depth']}"
        rows.append((f"{tag}.refresh_per_s", round(r["refresh_per_s"]),
                     "refresh_per_s"))
        rows.append((f"{tag}.order_ms", round(r["order_ms"], 3), "ms"))
    for depth, s in payload["speedup_numpy_vs_object"].items():
        rows.append((f"scheduler.speedup_{depth}.refresh",
                     round(s["refresh"], 2), "x_vs_object"))
    emit(rows)
    return rows


if __name__ == "__main__":
    main()
