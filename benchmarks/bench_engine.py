"""Engine-level execution benchmark: the memory-hybrid serving layer.

Five experiments on the REAL JAX engine (reduced configs, CPU):

  * preemption — the same oversubscribed workload under swap-mode vs
    recompute-mode preemption.  Swap restores KV from the host pool
    instead of re-prefilling, so the interesting numbers are the
    re-prefilled tokens recompute pays (``reprefill_tokens``) vs the
    modeled swap IO swap-mode pays (``modeled_swap_s``, priced by the
    same ServiceModel.swap_time / block accounting the simulator uses).

  * prefill — chunked (Sarathi) vs atomic prefill on a workload with
    long prompts landing on a busy decode batch: records TTFT
    percentiles and inter-token latency.  On this CPU testbed the
    wall-clock numbers carry jit-compile noise; the trajectory metric is
    the *relative* chunked/atomic shape, not the absolute seconds.

  * decode_hot_loop — the fused jitted step (on-device sampling +
    bookkeeping, one transfer per call, pow2-bucketed shapes) vs the
    Python-orchestrated per-step path at a full decode batch, single-
    and multi-step (``decode_steps``): steady-state decode steps/s, plus
    the fused step's REAL compile count (jit cache size) over a churny
    admit/finish workload against the bucket-ladder bound.

  * sharded — mesh-parallel decode swept over every mesh width the
    process's devices allow, with BOTH parallel modes per width: exact
    (per-shard paged KV pool + expert parallelism, bit-identical) and
    efficient (Megatron column/row-parallel projections + vocab-sharded
    lm_head, tolerance contract).  Per (width, mode): decode steps/s
    measured AND roofline-priced from deterministic FLOP-placement
    accounting (``decode_flop_split``) + compiled-HLO collective bytes,
    plus the off-replica FLOP ratio efficient/exact and the compile
    count against the bucket-ladder bound.  Runs at width 1 on a plain
    CPU; CI's mesh job re-runs it under 8 forced host devices
    (``--only sharded``).

  * prefix_reuse — copy-on-write prefix sharing on a few-hundred-session
    multi-tenant sweep (per-group system prompts, unique user tails):
    re-prefilled tokens and TTFT percentiles with sharing off vs on,
    plus a bit-identical output check (sharing must be a pure cost
    optimization).

Results merge into BENCH_scheduler.json under the ``engine`` key (the
scheduler benchmark owns the rest of the file).

    PYTHONPATH=src python benchmarks/bench_engine.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy)
from repro.models import build_model
from repro.serving import ServeRequest, ServingEngine


def _workload(cfg, n, prompt_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size,
                                             prompt_len)]
        reqs.append(ServeRequest(
            request_id=f"r{i}", prompt=f"bench prompt {i}",
            prompt_tokens=toks, max_new_tokens=max_new,
            temperature=0.0, eos_token=1))   # arrival stamped at submit
    return reqs


def _oracle(n, max_new):
    o = OraclePredictor()
    for i in range(n):
        o.register(f"bench prompt {i}", LengthDistribution(
            np.array([max_new]), np.array([1.0])))
    return o


def _run(cfg, reqs, *, mode="swap", chunk=None, cap=None, n_slots=2,
         policy="sagesched", max_new=12):
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy(policy),
                            predictor=_oracle(len(reqs), max_new)),
        n_slots=n_slots, max_seq_len=192, capacity_tokens=cap,
        block_size=8, preemption_mode=mode, prefill_chunk=chunk, seed=0)
    eng.submit_batch(reqs)
    t0 = time.perf_counter()
    eng.run_until_done(max_steps=20_000)
    wall = time.perf_counter() - t0
    s = eng.metrics.summary(reqs)
    s["wall_s"] = wall
    return eng, s


def bench_preemption(smoke: bool) -> dict:
    """Swap vs recompute under forced preemption (tight KV budget)."""
    cfg = get_config("llama3.2-1b", reduced=True)
    n, max_new, cap = (6, 12, 48) if smoke else (10, 20, 64)
    out = {}
    token_streams = {}
    for mode in ("swap", "recompute"):
        reqs = _workload(cfg, n, prompt_len=10, max_new=max_new)
        eng, s = _run(cfg, reqs, mode=mode, cap=cap, max_new=max_new)
        token_streams[mode] = [r.output_tokens for r in reqs]
        out[mode] = {
            "wall_s": s["wall_s"],
            "preemptions": eng.metrics.preemptions,
            "prefills": eng.metrics.prefills,
            "prefill_tokens": eng.metrics.prefill_tokens,
            "swap_ins": eng.metrics.swap_ins,
            "modeled_swap_s": eng.metrics.modeled_swap_s,
            "mean_ttlt_s": s["mean_ttlt_s"],
        }
    out["token_identical"] = \
        token_streams["swap"] == token_streams["recompute"]
    out["reprefill_tokens_saved"] = (out["recompute"]["prefill_tokens"]
                                     - out["swap"]["prefill_tokens"])
    return out


def bench_prefill(smoke: bool) -> dict:
    """Chunked vs atomic prefill TTFT under prompt-heavy load."""
    cfg = get_config("llama3.2-1b", reduced=True)
    n, plen, chunk = (5, 48, 16) if smoke else (8, 96, 32)
    out = {}
    for name, ch in (("atomic", None), ("chunked", chunk)):
        reqs = _workload(cfg, n, prompt_len=plen, max_new=8, seed=1)
        eng, s = _run(cfg, reqs, mode="swap", chunk=ch, n_slots=4,
                      policy="fcfs", max_new=8)
        out[name] = {
            "wall_s": s["wall_s"],
            "p50_ttft_s": s["p50_ttft_s"],
            "p95_ttft_s": s["p95_ttft_s"],
            "mean_itl_s": s["mean_itl_s"],
            "prefill_chunks": eng.metrics.prefill_chunks,
        }
    out["chunk_tokens"] = chunk
    return out


def _steady_engine(cfg, *, n_slots, step_mode, decode_steps, max_seq,
                   prompt_len, tp=1, parallel="exact"):
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("fcfs")),
        n_slots=n_slots, max_seq_len=max_seq, block_size=8,
        seed=0, step_mode=step_mode, decode_steps=decode_steps, tp=tp,
        parallel=parallel)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(n_slots):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size,
                                             prompt_len)]
        # eos_token=-1: never sampled, so the batch stays full (steady
        # state) until max_new_tokens — we measure decode, not churn
        reqs.append(ServeRequest(
            request_id=f"d{i}", prompt=f"d{i}", prompt_tokens=toks,
            max_new_tokens=max_seq, temperature=0.0, eos_token=-1))
    eng.submit_batch(reqs)
    return eng


def bench_decode_hot_loop(smoke: bool) -> dict:
    """Fused vs orchestrated decode throughput at a full decode batch,
    plus compile-count discipline under churn.

    The throughput phase measures *steady state*: prompts are sized so
    the whole window stays inside one (batch, page) bucket — bucket-edge
    compiles are the churn phase's subject, where they are counted
    against the ladder bound rather than timed.  The orchestrated
    baseline always runs full-width tables (its only shape-stable
    option), so the fused speedup includes the bucketing win.

    The reduced config's 512-entry vocab would hide the orchestrated
    path's real per-token tax — shipping (n_slots, V) logits to the host
    and sampling there — so the throughput phase restores a
    production-shaped head (32k vocab); everything else stays reduced."""
    cfg = get_config("llama3.2-1b", reduced=True).with_overrides(
        vocab_size=32768)
    n_slots, iters, multi = (8, 12, 4) if smoke else (64, 48, 8)
    # prompt 65 tokens -> 9 pages -> the pow2-16 page bucket, which holds
    # 128 tokens of context: warmup + measurement never leave the bucket
    prompt_len, max_seq = 65, 160
    out = {"n_slots": n_slots, "measured_iterations": iters,
           "decode_steps_multi": multi, "prompt_len": prompt_len,
           "vocab_size": cfg.vocab_size}
    for name, mode, dsteps in (("orchestrated", "orchestrated", 1),
                               ("fused", "fused", 1),
                               ("fused_multi", "fused", multi)):
        eng = _steady_engine(cfg, n_slots=n_slots, step_mode=mode,
                             decode_steps=dsteps, max_seq=max_seq,
                             prompt_len=prompt_len)
        # prefill + compile warmup, budgeted so warmup + measurement
        # stay inside the pow2-16 page bucket
        for _ in range(3 if dsteps == 1 else 1):
            eng.step()
        calls = max(1, iters // dsteps)
        t0 = time.perf_counter()
        for _ in range(calls):
            eng.step()
        wall = time.perf_counter() - t0
        done = calls * dsteps
        out[name] = {
            "wall_s": wall,
            "decode_steps_per_s": done / wall,
            "decode_steps": dsteps,
            "tokens_per_s": done * n_slots / wall,
        }
    base = out["orchestrated"]["decode_steps_per_s"]
    out["speedup_fused_vs_orchestrated"] = \
        out["fused"]["decode_steps_per_s"] / base
    out["speedup_multi_vs_orchestrated"] = \
        out["fused_multi"]["decode_steps_per_s"] / base

    # churn: admit/finish events walk the active-lane and page buckets up
    # and down; the fused jit cache must stay inside the ladder bound
    n_churn = 30 if smoke else 250
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("fcfs")),
        n_slots=n_slots, max_seq_len=max_seq, block_size=8,
        seed=0, step_mode="fused")
    rng = np.random.default_rng(1)
    reqs = []
    for i in range(n_churn):
        toks = [int(t) for t in rng.integers(
            3, cfg.vocab_size, int(rng.integers(4, 24)))]
        reqs.append(ServeRequest(
            request_id=f"c{i}", prompt=f"c{i}", prompt_tokens=toks,
            max_new_tokens=1 + (i % 7), temperature=0.0, eos_token=1,
            arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    eng.run_until_done(max_steps=100_000)
    out["churn"] = {
        # batch-shape events: every admit, finish, and preemption moves
        # the active-lane / page counts the bucket ladder must absorb
        "events": 2 * n_churn + eng.metrics.preemptions,
        "recompile_count": eng.fused_compile_count,
        "recompile_bound": eng.max_fused_compiles(),
        "fused_calls": eng.metrics.fused_steps,
    }
    return out


def bench_sharded(smoke: bool) -> dict:
    """Mesh-parallel decode: steady-state steps/s and roofline-relative
    utilization as a function of device count.

    The sweep runs the fused decode engine at every mesh width the
    process's devices allow (1 on a plain CPU run; 1/2/4/8 under CI's
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` mesh job) and,
    per width, re-lowers the last fused step to compiled HLO so
    ``launch.roofline.collective_bytes`` can price the collectives the
    partitioner actually emitted.  Two utilization numbers:

      * ``mfu`` — useful model FLOPs/s (2ND decode) against the HW peak:
        meaningless in absolute terms on a CPU testbed, but its *ratio*
        across widths is the scaling curve;
      * ``roofline_rel`` — the analytic per-step floor (max of compute /
        memory / collective terms, per chip) divided by the measured
        step time: how far the testbed sits from the modeled ceiling.

    Each width runs BOTH parallel modes side by side — exact (bit-
    identical, projections replicated) and efficient (Megatron column/
    row-parallel, tolerance contract) — so the record shows what the
    tolerance buys.  Wall-clock on a host-device testbed is noise for
    that comparison, so the mode race is decided by deterministic
    accounting: ``launch.roofline.decode_flop_split`` prices how many
    FLOPs each mode's rule table moves off-replica, and
    ``roofline_steps_per_s`` converts each mode's per-device FLOPs +
    collectives into modeled decode steps/s on the reference HW.
    Measured steps/s is recorded alongside.  The compile count is
    recorded per width against the bucket-ladder bound (the CI smoke
    asserts it holds)."""
    from collections import namedtuple

    import jax

    from repro.launch.roofline import (HW, analytic_floors,
                                       collective_bytes,
                                       decode_flop_split, model_flops,
                                       roofline_terms)

    _Shape = namedtuple("Shape", "kind global_batch seq_len")

    # head counts chosen so every swept width divides them; the
    # production-shaped vocab keeps the head from vanishing in the noise
    cfg = get_config("qwen2-1.5b", reduced=True).with_overrides(
        n_heads=8, n_kv_heads=8, vocab_size=32768)
    n_slots, iters = (4, 6) if smoke else (8, 24)
    prompt_len, max_seq = 65, 160
    n_dev = jax.device_count()
    widths = [t for t in (1, 2, 4, 8)
              if t <= n_dev and cfg.n_kv_heads % t == 0]
    out = {"device_count": n_dev, "widths": widths, "n_slots": n_slots,
           "measured_iterations": iters, "prompt_len": prompt_len}
    for tp in widths:
        by_mode = {}
        for parallel in ("exact", "efficient"):
            eng = _steady_engine(cfg, n_slots=n_slots, step_mode="fused",
                                 decode_steps=1, max_seq=max_seq,
                                 prompt_len=prompt_len, tp=tp,
                                 parallel=parallel)
            for _ in range(3):            # prefill + compile warmup
                eng.step()
            t0 = time.perf_counter()
            for _ in range(iters):
                eng.step()
            wall = time.perf_counter() - t0
            step_s = wall / iters
            s_cache = prompt_len + 3 + iters
            shape = _Shape("decode", n_slots, s_cache)
            floors = analytic_floors(cfg, shape, tp)
            hlo = eng.lower_fused_hlo()
            coll = collective_bytes(hlo) if hlo \
                else {"total": 0, "counts": {}}
            terms = roofline_terms(floors["flops_floor"],
                                   floors["bytes_floor"],
                                   max(coll["total"],
                                       floors["collective_floor"]))
            mf = model_flops(cfg, shape, tp)
            floor_s = max(terms["compute_s"], terms["memory_s"],
                          terms["collective_s"])
            split = decode_flop_split(cfg, tp=tp, parallel=parallel,
                                      batch=n_slots, s_cache=s_cache)
            # modeled decode steps/s: per-device FLOPs at peak + the
            # measured collectives at link bandwidth, serialized — a
            # deterministic price of this mode's placement
            priced_s = (split["per_device_flops"] / HW["peak_flops"]
                        + coll["total"] / HW["link_bw"])
            by_mode[parallel] = {
                "devices": tp,
                "decode_steps_per_s": 1.0 / step_s,
                "roofline_decode_steps_per_s": 1.0 / priced_s,
                "tokens_per_s": n_slots / step_s,
                "mfu": mf / step_s / HW["peak_flops"],
                "roofline_rel": floor_s / step_s,
                "roofline": terms,
                "flop_split": {k: split[k] for k in
                               ("total_flops", "sharded_flops",
                                "replicated_flops", "off_replica_flops",
                                "per_device_flops")},
                "collective_bytes_per_chip": coll["total"],
                "collective_counts": coll.get("counts", {}),
                "recompile_count": eng.fused_compile_count,
                "recompile_bound": eng.max_fused_compiles(),
                "sharding": eng.sharding_report(),
            }
        rec = dict(by_mode)
        if tp > 1:
            rec["off_replica_ratio_efficient_vs_exact"] = (
                by_mode["efficient"]["flop_split"]["off_replica_flops"]
                / max(1.0,
                      by_mode["exact"]["flop_split"]["off_replica_flops"]))
            rec["roofline_speedup_efficient_vs_exact"] = (
                by_mode["efficient"]["roofline_decode_steps_per_s"]
                / by_mode["exact"]["roofline_decode_steps_per_s"])
        out[f"tp{tp}"] = rec
    base = out[f"tp{widths[0]}"]["exact"]["decode_steps_per_s"]
    out["scaling"] = {
        f"tp{t}": out[f"tp{t}"]["exact"]["decode_steps_per_s"] / base
        for t in widths}
    return out


def bench_prefix_reuse(smoke: bool) -> dict:
    """Copy-on-write prefix sharing on a few-hundred-session sweep:
    sessions arrive in groups, each group opening with its own 112-token
    system prompt and diverging into a short unique user message (a
    multi-tenant trace, not one global prefix).  Sharing off re-prefills
    the system prompt per session; sharing on pays it once per group and
    adopts the published blocks for the rest — fewer chunk dispatches,
    lower TTFT, bit-identical tokens (the CI gate asserts all three,
    including >= 50% re-prefilled-token savings).

    TTFT is reported two ways: wall seconds (noisy on a CPU testbed —
    per-step dispatch overhead swamps the skipped prefill math) and
    *engine steps* on a hand-advanced virtual clock (1.0 per step),
    which deterministically counts the scheduling rounds a request
    waits — the structural quantity sharing improves.  The CI gate
    asserts on the step-clock numbers.  A sharing-on warmup pass runs
    first so jit compilation (the resumed-prefill shapes exist only on
    the sharing path) is paid before either measured run."""
    from repro.testing import VirtualClock

    cfg = get_config("llama3.2-1b", reduced=True)
    # (sessions, groups): ~200-session sweep in CI smoke, ~400 full
    n, groups, max_new = (192, 8, 4) if smoke else (384, 12, 6)
    sys_len, user_len = 112, 8
    rng = np.random.default_rng(4)
    systems = [[int(t) for t in rng.integers(3, cfg.vocab_size, sys_len)]
               for _ in range(groups)]

    def session_reqs(k=None):
        r = np.random.default_rng(5)
        # group-major arrival: a group's sessions are contiguous, so its
        # published prefix is hot while its members admit (tenant bursts)
        return [ServeRequest(
            request_id=f"s{i}", prompt=f"bench prompt {i}",
            prompt_tokens=systems[i * groups // (k or n)] + [
                int(t) for t in r.integers(3, cfg.vocab_size, user_len)],
            max_new_tokens=max_new, temperature=0.0, eos_token=1)
            for i in range(k or n)]

    def run_once(sharing, batch):
        clock = VirtualClock()
        eng = ServingEngine(
            model=build_model(cfg),
            scheduler=Scheduler(policy=make_policy("fcfs"),
                                predictor=_oracle(len(batch), max_new)),
            n_slots=2, max_seq_len=192, block_size=8, prefill_chunk=16,
            seed=0, prefix_sharing=sharing, clock=clock)
        eng.submit_batch(batch)
        t0 = time.perf_counter()
        steps = 0
        while eng.has_work:
            eng.step()
            clock.advance(1.0)      # TTFT in deterministic step units
            steps += 1
            if steps > 100_000:
                raise RuntimeError("bench engine stalled")
        return eng, time.perf_counter() - t0

    run_once(True, session_reqs(3))       # compile warmup, unrecorded

    out = {"n_requests": n, "session_groups": groups,
           "system_prompt_tokens": sys_len, "user_tokens": user_len}
    streams = {}
    for name, sharing in (("off", False), ("on", True)):
        batch = session_reqs()
        eng, wall = run_once(sharing, batch)
        s = eng.metrics.summary(batch)
        streams[name] = [r.output_tokens for r in batch]
        out[name] = {
            "wall_s": wall,
            "prefill_tokens": eng.metrics.prefill_tokens,
            "prefill_tokens_reused": eng.metrics.prefill_tokens_reused,
            "prefill_chunks": eng.metrics.prefill_chunks,
            # virtual step-clock TTFT: deterministic scheduling rounds
            "p50_ttft_steps": s["p50_ttft_s"],
            "p95_ttft_steps": s["p95_ttft_s"],
        }
    out["token_identical"] = streams["off"] == streams["on"]
    out["reused_fraction"] = (out["on"]["prefill_tokens_reused"]
                              / max(1, out["off"]["prefill_tokens"]))
    return out


BENCHES = {
    "preemption": bench_preemption,
    "prefill": bench_prefill,
    "decode_hot_loop": bench_decode_hot_loop,
    "sharded": bench_sharded,
    "prefix_reuse": bench_prefix_reuse,
}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: minimal sizes")
    ap.add_argument("--only", choices=sorted(BENCHES), default=None,
                    help="run a single experiment and merge it into the "
                         "existing engine record (CI's mesh job re-runs "
                         "just the sharded sweep under 8 host devices)")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         .parent / "BENCH_scheduler.json"))
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(BENCHES)
    engine = {name: BENCHES[name](args.smoke) for name in names}
    path = Path(args.out)
    doc = json.loads(path.read_text()) if path.exists() else {}
    if args.only:
        doc.setdefault("engine", {}).update(engine)
    else:
        doc["engine"] = engine
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(json.dumps(engine, indent=2, sort_keys=True))
    return engine


if __name__ == "__main__":
    main()
