"""Microbenchmarks of the Pallas-kernel reference paths + the Gittins
batch computation (wall-clock on CPU; the TPU numbers come from the
dry-run roofline)."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gittins_index_batch

from .common import emit


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick=False):
    rows = []
    rng = np.random.default_rng(0)
    # gittins batch (numpy scheduler path)
    sup = np.sort(rng.uniform(1, 1e6, (1000, 32)), axis=1)
    pr = rng.dirichlet(np.ones(32), 1000)
    t0 = time.perf_counter()
    for _ in range(10):
        gittins_index_batch(sup, pr)
    rows.append(("kernels.gittins_batch_1000x32",
                 round((time.perf_counter() - t0) / 10 * 1e6, 1),
                 "us_per_call"))
    # flash attention reference path
    from repro.kernels.flash_attention.ops import flash_attention
    q = jnp.asarray(rng.normal(0, 1, (1, 512, 8, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (1, 512, 2, 64)), jnp.bfloat16)
    us = _time(lambda a, b, c: flash_attention(a, b, c), q, k, k)
    rows.append(("kernels.flash_attention_ref_512", round(us, 1),
                 "us_per_call"))
    # ssd scan reference path
    from repro.kernels.ssd_scan.ops import ssd_scan_op
    x = jnp.asarray(rng.normal(0, 1, (1, 512, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 1, (1, 512, 8)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (1, 512, 8)), jnp.float32)
    bm = jnp.asarray(rng.normal(0, 0.5, (1, 512, 64)), jnp.float32)
    us = _time(lambda *t: ssd_scan_op(*t), x, dt, a, bm, bm)
    rows.append(("kernels.ssd_scan_ref_512", round(us, 1), "us_per_call"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
