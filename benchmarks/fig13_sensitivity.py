"""Paper Fig. 13: sensitivity — (a) similarity threshold tau, (b) Gittins
refresh bucket size."""

from .common import emit, run_policy, seed_records, workload


def run(n=500, rps=8.0, quick=False):
    rows = []
    reqs = workload(n=n, rps=rps)
    records = seed_records()
    taus = (0.6, 0.8, 0.95) if quick else (0.4, 0.6, 0.8, 0.9, 0.95)
    for tau in taus:
        res = run_policy("sagesched", reqs, predictor_kind="semantic",
                         records=records, similarity_threshold=tau)
        rows.append((f"fig13a.ttlt.tau{tau}", round(res.mean_ttlt(), 3),
                     "mean_ttlt_s"))
    buckets = (50, 200, 800) if quick else (25, 50, 100, 200, 400, 800)
    for bs in buckets:
        res = run_policy("sagesched", reqs, predictor_kind="semantic",
                         records=records, bucket_size=bs)
        rows.append((f"fig13b.ttlt.bucket{bs}", round(res.mean_ttlt(), 3),
                     "mean_ttlt_s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
