"""Roofline report (deliverable g): reads dryrun_results.json and prints
the three-term roofline per (arch x shape x mesh) as CSV rows."""

import json
import os

from .common import emit


def run(path=None, quick=False):
    path = path or os.path.join(os.path.dirname(__file__), "..",
                                "dryrun_results.json")
    if not os.path.exists(path):
        emit([("roofline.missing", 0, "run repro.launch.dryrun --all first")])
        return []
    with open(path) as f:
        results = json.load(f)
    rows = []
    for key, rec in sorted(results.items()):
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        tag = key.replace("|", ".")
        rows.append((f"roofline.{tag}.compute_s", f"{r['compute_s']:.3e}",
                     "seconds"))
        rows.append((f"roofline.{tag}.memory_s", f"{r['memory_s']:.3e}",
                     "seconds"))
        rows.append((f"roofline.{tag}.collective_s",
                     f"{r['collective_s']:.3e}", "seconds"))
        rows.append((f"roofline.{tag}.dominant", r["dominant"],
                     f"useful_ratio={rec.get('useful_flops_ratio')}"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
