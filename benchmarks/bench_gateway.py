"""Gateway overload benchmark: goodput under load with and without
uncertainty-aware shedding, plus the injected-fault matrix.

Two experiments on the REAL JAX engine (reduced llama config, CPU),
driven by a virtual clock so deadlines and retry backoff are
deterministic:

  * overload — a goodput-under-overload curve: the same deadline-bound
    request stream offered at 1x/2x/4x the engine's service rate
    (sustained paced arrivals, not a single burst), through three front
    doors: ``cost`` (bounded queues + uncertainty-aware shedding on the
    predicted-cost upper quantile), ``tail`` (bounded queues + FCFS
    tail-drop), and ``none`` (no bounds — every request submitted, the
    seed behavior).  The stream mixes tight-deadline cheap requests
    with wide-tail heavy ones whose true decode run monopolises a slot
    for seconds; deadline violators are timeout-aborted, so
    ``goodput_requests`` (completions, all deadline-met) and
    ``goodput_tokens`` (decode - wasted) count only work that reached a
    deadline-respecting finish.  Under sustained overload the unbounded
    door turns decoded tokens into waste, and the tail door's queue
    clogs with heavies that starve the cheap flow — the cost door sheds
    exactly those, which is the CI-asserted separation.

  * faults — the injected-fault matrix (predictor outage mid-burst,
    swap-in payload loss, grow exhaustion, deadline storm), each checked
    for the post-fault invariants: engine drains, KV block accounting
    conserves, every offered id ends FINISHED / SHED / ABORTED with a
    reason.  ``conservation_violations`` must be 0 (CI-asserted).

Results merge into BENCH_scheduler.json under the ``gateway`` key.

    PYTHONPATH=src python benchmarks/bench_gateway.py [--smoke]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy)
from repro.models import build_model
from repro.serving import Gateway, GatewayConfig, ServeRequest, ServingEngine
from repro.testing import (FlakyPredictor, VirtualClock,
                           assert_engine_quiesced, inject_kv_fault)

CFG = get_config("llama3.2-1b", reduced=True)

CHEAP_NEW, HEAVY_NEW = 4, 28          # true decode lengths (tokens)
CHEAP_TTLT, HEAVY_TTLT = 1.5, 6.0     # per-class SLOs (virtual seconds)


def _oracle() -> OraclePredictor:
    o = OraclePredictor()
    o.register("cheap", LengthDistribution(np.array([CHEAP_NEW]),
                                           np.array([1.0])))
    # heavy requests carry the wide right tail the quantile score targets
    o.register("heavy", LengthDistribution(
        np.array([CHEAP_NEW, 4 * HEAVY_NEW]), np.array([0.5, 0.5])))
    return o


def _request(i: int, arrival: float, seed: int = 0,
             ttlt: float | None = None) -> ServeRequest:
    """Stream mix: 2/3 cheap/tight-SLO, 1/3 heavy/loose-SLO."""
    heavy = i % 3 == 2
    rng = np.random.default_rng(seed * 1000 + i)
    toks = [int(t) for t in rng.integers(3, CFG.vocab_size, 8)]
    if ttlt is None:
        ttlt = HEAVY_TTLT if heavy else CHEAP_TTLT
    return ServeRequest(
        request_id=f"o{i}", prompt="heavy" if heavy else "cheap",
        prompt_tokens=toks,
        max_new_tokens=HEAVY_NEW if heavy else CHEAP_NEW,
        temperature=0.0, eos_token=1, arrival=arrival,
        ttlt_deadline_s=ttlt)


def _requests(n: int, ttlt: float, seed: int = 0) -> list[ServeRequest]:
    """A burst variant of the stream (fault-matrix scenarios)."""
    return [_request(i, arrival=0.0, seed=seed, ttlt=ttlt)
            for i in range(n)]


def _engine(n_slots: int = 2) -> ServingEngine:
    return ServingEngine(
        model=build_model(CFG),
        scheduler=Scheduler(policy=make_policy("sagesched"),
                            predictor=_oracle()),
        n_slots=n_slots, max_seq_len=96, seed=0, clock=VirtualClock())


def _gateway(eng: ServingEngine, door: str) -> Gateway:
    if door == "none":
        cfg = GatewayConfig(max_inflight=10**9, max_total_queue=10**9,
                            max_queue_per_tenant=10**9, max_retries=0,
                            shed_policy="tail")
    else:
        cfg = GatewayConfig(max_inflight=4, max_queue_per_tenant=4,
                            max_total_queue=4, max_retries=1,
                            retry_backoff_s=0.2, shed_policy=door,
                            shed_quantile=0.9)
    return Gateway(eng, cfg)


BASE_INTERARRIVAL_S = 0.5     # 1x stream rate: near 2-slot capacity


def run_overload_point(factor: int, door: str, n_requests: int,
                       step_dt: float) -> dict:
    """Offer the same n-request stream at ``factor``x the base arrival
    rate (sustained overload), then drain, and account goodput."""
    eng = _engine()
    gw = _gateway(eng, door)
    clock = gw.clock
    clock.advance(1.0)                  # nonzero arrivals for every req
    steps_per_arrival = max(1, round(
        BASE_INTERARRIVAL_S / factor / step_dt))
    for i in range(n_requests):
        gw.offer(_request(i, arrival=clock()))
        for _ in range(steps_per_arrival):
            gw.step()
            clock.advance(step_dt)
    gw.run_until_drained(max_steps=50_000, step_dt=step_dt)
    gw.assert_all_terminal()
    conserved = True
    try:
        assert_engine_quiesced(eng)
    except (AssertionError, RuntimeError):
        conserved = False
    m = eng.metrics
    kinds = [k for k, _ in gw.dispositions.values()]
    completed = kinds.count("FINISHED")   # deadline violators are aborted
    return {
        "offered": n_requests,
        "goodput_requests": completed,
        "shed": kinds.count("SHED"),
        "aborted": kinds.count("ABORTED"),
        "timeout_aborts": m.timeout_aborts,
        "retries": m.retries,
        "decode_tokens": m.decode_tokens,
        "wasted_tokens": m.wasted_tokens,
        "goodput_tokens": m.decode_tokens - m.wasted_tokens,
        "conserved": conserved,
    }


def bench_overload(smoke: bool) -> dict:
    n = 24 if smoke else 36
    step_dt = 0.1
    factors = (1, 2) if smoke else (1, 2, 4)
    curve: dict[str, dict] = {}
    for factor in factors:
        row = {door: run_overload_point(factor, door, n, step_dt)
               for door in ("cost", "tail", "none")}
        curve[f"{factor}x"] = row
    return {
        "n_requests": n,
        "base_interarrival_s": BASE_INTERARRIVAL_S,
        "ttlt_deadline_s": {"cheap": CHEAP_TTLT, "heavy": HEAVY_TTLT},
        "step_dt_s": step_dt,
        "curve": curve,
        "conservation_violations": sum(
            not point["conserved"]
            for row in curve.values() for point in row.values()),
    }


# ------------------------------------------------------------ fault matrix

def _drain_scenario(eng: ServingEngine, gw: Gateway,
                    reqs: list[ServeRequest]) -> dict:
    gw.offer_batch(reqs)
    gw.run_until_drained(max_steps=50_000, step_dt=0.05)
    gw.assert_all_terminal()
    ok = True
    try:
        assert_engine_quiesced(eng)
    except (AssertionError, RuntimeError):
        ok = False
    kinds = [k for k, _ in gw.dispositions.values()]
    return {"offered": len(reqs), "completed": kinds.count("FINISHED"),
            "shed": kinds.count("SHED"), "aborted": kinds.count("ABORTED"),
            "conserved": ok}


def bench_faults(smoke: bool) -> dict:
    n = 6 if smoke else 12
    out = {}

    # predictor outage mid-burst: the gateway's cost scoring degrades to
    # FCFS tail-drop, recovers when the predictor comes back, no crash
    flaky = FlakyPredictor(_oracle(), mode="outage", fail_after=2,
                           n_failures=3)
    eng = ServingEngine(
        model=build_model(CFG),
        scheduler=Scheduler(policy=make_policy("sagesched"),
                            predictor=flaky),
        n_slots=2, max_seq_len=96, seed=0, clock=VirtualClock())
    out["predictor_outage"] = _drain_scenario(
        eng, _gateway(eng, "cost"), _requests(n, ttlt=30.0))
    out["predictor_outage"]["injected"] = flaky.faults

    # swap-in payload loss under tight capacity: recompute fallback
    eng = ServingEngine(
        model=build_model(CFG),
        scheduler=Scheduler(policy=make_policy("sagesched"),
                            predictor=_oracle()),
        n_slots=2, max_seq_len=96, capacity_tokens=56, block_size=8,
        preemption_mode="swap", seed=0, clock=VirtualClock())
    gw = _gateway(eng, "cost")
    with inject_kv_fault(eng.kv, "swap_in", at_call=0, n_calls=2) as stats:
        out["swap_in_fault"] = _drain_scenario(
            eng, gw, _requests(n, ttlt=60.0, seed=1))
    out["swap_in_fault"]["injected"] = stats["faults"]
    out["swap_in_fault"]["recovered_by_recompute"] = \
        eng.metrics.swap_in_faults

    # grow exhaustion: pressure relief absorbs it
    eng = ServingEngine(
        model=build_model(CFG),
        scheduler=Scheduler(policy=make_policy("sagesched"),
                            predictor=_oracle()),
        n_slots=2, max_seq_len=96, capacity_tokens=64, block_size=8,
        seed=0, clock=VirtualClock())
    with inject_kv_fault(eng.kv, "grow", at_call=4, n_calls=4) as stats:
        out["grow_fault"] = _drain_scenario(
            eng, _gateway(eng, "cost"), _requests(n, ttlt=60.0, seed=2))
    out["grow_fault"]["injected"] = stats["faults"]

    # deadline storm: tight budgets, every timeout abort must release
    eng = _engine()
    out["deadline_storm"] = _drain_scenario(
        eng, _gateway(eng, "cost"), _requests(2 * n, ttlt=0.4, seed=3))
    out["deadline_storm"]["timeout_aborts"] = eng.metrics.timeout_aborts

    out["conservation_violations"] = sum(
        not s["conserved"] for s in out.values() if isinstance(s, dict))
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: minimal sizes")
    ap.add_argument("--out", default=str(Path(__file__).resolve().parent
                                         .parent / "BENCH_scheduler.json"))
    args = ap.parse_args(argv)

    gateway = {
        "overload": bench_overload(args.smoke),
        "faults": bench_faults(args.smoke),
    }
    path = Path(args.out)
    doc = json.loads(path.read_text()) if path.exists() else {}
    doc["gateway"] = gateway
    path.write_text(json.dumps(doc, indent=2, sort_keys=True) + "\n")
    print(json.dumps(gateway, indent=2, sort_keys=True))
    return gateway


if __name__ == "__main__":
    main()
