"""Paper Fig. 7: end-to-end mean TTLT/TTFT on mixed datasets vs RPS,
every scheduler with its paper-faithful predictor."""

from .common import emit, run_policy, seed_records, workload

POLICIES = ("fcfs", "fastserve", "ssjf", "ltr", "trail", "sagesched",
            "sagesched_aged")  # last = beyond-paper (§Beyond)


def run(n=600, quick=False):
    rows = []
    records = seed_records()
    rates = (4.0, 8.0) if quick else (2.0, 4.0, 6.0, 8.0)
    for rps in rates:
        reqs = workload(n=n, rps=rps)
        for pol in POLICIES:
            res = run_policy(pol, reqs, records=records)
            rows.append((f"fig7.ttlt.rps{rps:g}.{pol}",
                         round(res.mean_ttlt(), 3), "mean_ttlt_s"))
            rows.append((f"fig7.ttft.rps{rps:g}.{pol}",
                         round(res.mean_ttft(), 3), "mean_ttft_s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
