"""Paper Fig. 11 (Sec. 4.3.3): scheduling-policy ablation — Mean vs
Gittins-no-refresh vs SageSched (Gittins+refresh), with and without the
1:4 uniform prediction-noise injection."""

from .common import emit, run_policy, seed_records, workload


def run(n=600, rps=8.0, quick=False):
    rows = []
    reqs = workload(n=n, rps=rps)
    records = seed_records()
    for pol in ("ssjf", "mean", "gittins", "sagesched"):
        for noise, tag in ((0.0, "clean"), (0.2, "noisy")):
            res = run_policy(pol, reqs, predictor_kind="semantic",
                             noise=noise, records=records)
            rows.append((f"fig11.ttlt.{pol}.{tag}",
                         round(res.mean_ttlt(), 3), "mean_ttlt_s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
