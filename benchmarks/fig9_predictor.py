"""Paper Fig. 9 (Sec. 4.3.1): predictor ablation — semantic-aware
history-based vs semantic-unaware history-based vs LLM(proxy)-based
distribution predictor, all under the SageSched policy."""

import numpy as np

from repro.core import LengthHistoryPredictor, Scheduler, make_policy
from repro.simulator import simulate

from .common import emit, make_predictor, seed_records, workload

def run(n=600, rps=8.0, quick=False):
    rows = []
    reqs = workload(n=n, rps=rps)
    records = seed_records()
    cases = {
        "semantic_history": make_predictor("semantic", records),
        "length_history": None,     # built below (needs observe() seeding)
        "proxy_distribution": make_predictor("proxy", records),
    }
    lh = LengthHistoryPredictor()
    for pr, il, ol in zip(*records):
        lh.observe(pr, il, ol)
    cases["length_history"] = lh
    for name, pred in cases.items():
        res = simulate(reqs, Scheduler(policy=make_policy("sagesched"),
                                       predictor=pred))
        rows.append((f"fig9.ttlt.{name}", round(res.mean_ttlt(), 3),
                     "mean_ttlt_s"))
    # prediction accuracy + latency microbenchmark (paper Sec. 4.3.1 text)
    import time
    pred = make_predictor("semantic", records)
    t0 = time.perf_counter()
    for r in reqs[:200]:
        pred.predict(r.prompt, r.input_len)
    per_req_ms = (time.perf_counter() - t0) / 200 * 1e3
    rows.append(("fig9.predict_latency_ms", round(per_req_ms, 4),
                 "per_request_ms"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
