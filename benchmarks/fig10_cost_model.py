"""Paper Fig. 10 (Sec. 4.3.2): cost-model ablation — resource-bound
(O^2/2 + I*O) vs output-length-only vs weighted overall-length."""

from .common import emit, run_policy, seed_records, workload


def run(n=600, rps=8.0, quick=False):
    rows = []
    reqs = workload(n=n, rps=rps)
    records = seed_records()
    for cm in ("resource_bound", "output_length", "overall_length"):
        res = run_policy("sagesched", reqs, predictor_kind="semantic",
                         cost_model=cm, records=records)
        rows.append((f"fig10.ttlt.{cm}", round(res.mean_ttlt(), 3),
                     "mean_ttlt_s"))
    emit(rows)
    return rows


if __name__ == "__main__":
    run()
