"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import numpy as np

from repro.core import (PointPredictor, ProxyModelPredictor, Scheduler,
                        SemanticHistoryPredictor, make_cost_model,
                        make_policy)
from repro.simulator import generate_workload, make_profile, simulate

PROFILES = {n: make_profile(n) for n in ("sharegpt", "alpaca", "write")}
ALL_PROFILES = list(PROFILES.values())

# Paper Sec. 4.1 baselines with their OWN prediction methods:
#   SSJF/LTR use a fine-tuned proxy-model point prediction (DistillBERT /
#   OPT-125M stand-in); TRAIL re-predicts from model features (proxy
#   distribution); SageSched uses the semantic history predictor.
PAPER_PREDICTORS = {
    "fcfs": None,
    "fastserve": None,
    "ssjf": "proxy_point",
    "ltr": "proxy_point",
    "trail": "proxy",
    "mean": "semantic",
    "gittins": "semantic",
    "sagesched": "semantic",
    "sagesched_aged": "semantic",
    "hedged": "semantic",
}


def seed_records(profiles=None, per_cluster: int = 60, seed: int = 5):
    rng = np.random.default_rng(seed)
    prompts, ils, ols = [], [], []
    for prof in (profiles or ALL_PROFILES):
        for c in prof.clusters:
            for _ in range(per_cluster):
                prompts.append(c.sample_prompt(rng))
                ils.append(c.sample_input_len(rng))
                ols.append(c.sample_output_len(rng))
    return prompts, ils, ols


def make_predictor(kind: str | None, records=None):
    if kind is None:
        return None
    records = records or seed_records()
    if kind == "semantic":
        p = SemanticHistoryPredictor()
        p.seed(*records)
        return p
    if kind in ("proxy", "proxy_point"):
        p = ProxyModelPredictor()
        for pr, il, ol in zip(*records):
            p.observe(pr, il, ol)
        p._fit()
        return PointPredictor(p) if kind == "proxy_point" else p
    raise KeyError(kind)


def run_policy(policy: str, reqs, *, predictor_kind="paper",
               cost_model="resource_bound", noise=0.0, records=None,
               bucket_size=200, similarity_threshold=None):
    if predictor_kind == "paper":
        predictor_kind = PAPER_PREDICTORS[policy]
    pred = make_predictor(predictor_kind, records)
    if similarity_threshold is not None and \
            isinstance(pred, SemanticHistoryPredictor):
        pred.similarity_threshold = similarity_threshold
    sched = Scheduler(policy=make_policy(policy), predictor=pred,
                      cost_model=make_cost_model(cost_model),
                      noise_weight=noise, bucket_size=bucket_size)
    return simulate(reqs, sched)


def workload(n=600, rps=8.0, seed=1, datasets=("sharegpt", "alpaca",
                                               "write")):
    return generate_workload([PROFILES[d] for d in datasets], n, rps=rps,
                             seed=seed)


def emit(rows):
    """name,us_per_call,derived CSV convention (harness contract)."""
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")
