"""Attention: chunked causal (flash-style reference), sliding window, and
single-token decode over a KV cache.

The chunked implementation is the pure-jnp twin of the Pallas flash kernel
(repro.kernels.flash_attention): online softmax over KV blocks, so peak
memory is O(S * block) instead of O(S^2) — this is what the dry-run
compiles, keeping 32k-prefill activation memory sane.  On TPU the Pallas
kernel replaces it via repro.kernels.flash_attention.ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gqa_attention", "decode_attention", "decode_attention_paged",
           "encoder_attention", "combine_lse_partials"]

_NEG = -1e30


def combine_lse_partials(outs, lses, axis: int = 0):
    """Merge flash-style partial attention results along ``axis``.

    ``outs``: stacked *normalized* partial outputs (each partial is
    softmax-complete over its own KV stripe), with a trailing head_dim
    axis; ``lses``: the matching log-sum-exp values, shaped like
    ``outs`` minus that trailing axis.  The merged result equals the
    softmax over the union of the stripes (up to f32 reassociation):

        w_i = exp(lse_i - max_j lse_j);  out = sum_i w_i out_i / sum_i w_i

    An all-masked stripe contributes lse = log(l) + m ~ -inf and weight
    exactly 0.  This is the reduction the sharded paged-decode path and
    the Pallas ``(out, lse)`` kernel variant share — the property test
    in tests/test_tolerance.py pins merge == dense softmax.
    """
    m = jax.lax.stop_gradient(lses).max(axis=axis, keepdims=True)
    # clamp: if every stripe is empty (lse = -inf everywhere) the merge
    # must return 0, not NaN
    m = jnp.maximum(m, _NEG)
    w = jnp.exp(lses - m)                       # (..., n, ...)
    den = jnp.maximum(w.sum(axis=axis), 1e-30)
    num = (outs * jnp.expand_dims(w, -1)).sum(axis=axis)
    out = num / jnp.expand_dims(den, -1)
    lse = jnp.squeeze(m, axis) + jnp.log(den)
    return out, lse


def _repeat_kv(k, n_rep: int):
    """(B,S,KV,dh) -> (B,S,KV*n_rep,dh) by head repetition (GQA)."""
    if n_rep == 1:
        return k
    b, s, kv, dh = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, dh)
                            ).reshape(b, s, kv * n_rep, dh)


def gqa_attention(q, k, v, *, causal: bool = True, window: int = 0,
                  block: int = 512, positions=None, kv_positions=None):
    """Chunked multi-head (self or cross) attention.

    q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh) with H % KV == 0.
    window > 0 enables sliding-window causal masking.
    Returns (B, Sq, H, dh).
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    k = _repeat_kv(k, h // kv)
    v = _repeat_kv(v, h // kv)
    scale = dh ** -0.5
    if positions is None:
        positions = jnp.arange(sq)
    if kv_positions is None:
        kv_positions = positions if sk == sq else jnp.arange(sk)
    q_pos = positions            # (Sq,)

    blk = min(block, sk)
    n_blocks = -(-sk // blk)
    pad = n_blocks * blk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kt = k.reshape(b, n_blocks, blk, h, dh)
    vt = v.reshape(b, n_blocks, blk, h, dh)
    k_pos = jnp.pad(kv_positions, (0, pad), constant_values=-(10 ** 9)
                    ).reshape(n_blocks, blk)

    def step(carry, xs):
        m, l, acc = carry            # (B,Sq,H), (B,Sq,H), (B,Sq,H,dh)
        kb, vb, kp = xs              # (B,blk,H,dh), (B,blk,H,dh), (blk,)
        # f32 accumulation (not bf16-rounded-then-upcast): the decode path
        # accumulates scores in f32, and any systematic rounding gap
        # between the two paths is amplified by discrete MoE routing
        scores = jnp.einsum("bshd,bthd->bsth", q, kb,
                            preferred_element_type=jnp.float32)
        scores = scores * scale      # (B,Sq,blk,H)
        mask = jnp.ones((sq, blk), bool)
        if causal:
            mask &= q_pos[:, None] >= kp[None, :]
        if window > 0:
            mask &= q_pos[:, None] - kp[None, :] < window
        mask &= kp[None, :] >= 0     # padding
        scores = jnp.where(mask[None, :, :, None], scores, _NEG)
        m_new = jnp.maximum(m, scores.max(axis=2))
        p = jnp.exp(scores - m_new[:, :, None, :])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=2)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bsth,bthd->bshd", p, vb, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), _NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    a0 = jnp.zeros((b, sq, h, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kt, 1, 0), jnp.moveaxis(vt, 1, 0), k_pos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention_paged(q, k_pool, v_pool, block_tables, cache_len, *,
                           window: int = 0, n_splits: int = 1,
                           constrain_split=None):
    """One-token decode attention over a *paged* KV pool (vLLM block-table
    indirection, jnp twin of repro.kernels.decode_attention's paged
    kernel).

    q: (B, 1, H, dh); k_pool/v_pool: (n_pages, page, KV, dh) — one pool
    shared by the whole batch; block_tables: (B, P) int32 mapping logical
    page ``j`` of row ``b`` to its physical page (page 0 is the engine's
    scratch block); cache_len: (B,) valid tokens.  Logical capacity per
    row is P * page.  ``window > 0`` is a *logical* sliding window
    (positions in [cache_len - window, cache_len)) — paged caches keep
    every block resident instead of ring-wrapping.
    Returns (B, 1, H, dh).

    Bucket-stable by construction: P may be padded to a pow2 bucket with
    scratch-page rows (they sit past ``cache_len`` and mask to exact
    zeros under the unnormalized-exp softmax), and batch rows are
    independent lanes — so the fused engine step can gather active slots
    into pow2 batch buckets without perturbing any real lane's logits.

    Shard-invariant too: every einsum batches over the KV dim and
    contracts only dh/sequence, so a pool sharded over kv-heads
    (serving.sharded) computes per-shard slices of the identical GEMMs —
    the mesh engine's bit-identity rests on this.

    ``n_splits > 1`` (the efficient-mode LSE fallback, installed via
    ``sharding.context`` when kv heads don't divide the mesh) splits the
    *logical page* axis into ``n_splits`` stripes: each stripe runs its
    own softmax to flash-style (m, l, acc) partials and the stripes
    merge by log-sum-exp combining — numerically the
    ``combine_lse_partials`` reduction.  ``constrain_split`` (optional)
    pins the stripe axis to the mesh so GSPMD assigns stripe i to shard
    i and the merge lowers to one small psum over (m, l, acc)-sized
    tensors instead of replicating the pool gather.  NOT bit-identical
    to the unsplit path (different reduction order) — tolerance
    contract applies.
    """
    if n_splits > 1:
        return _decode_attention_paged_split(
            q, k_pool, v_pool, block_tables, cache_len, window=window,
            n_splits=n_splits, constrain_split=constrain_split)
    b = q.shape[0]
    n_pages, page, kvh, dh = k_pool.shape
    h = q.shape[2]
    rep = h // kvh
    p_max = block_tables.shape[1]
    s_log = p_max * page
    scale = dh ** -0.5
    # gather each row's logical cache from the pool (reference path; the
    # Pallas kernel streams physical pages instead of materializing this)
    tok = (block_tables.astype(jnp.int32) * page)[:, :, None] \
        + jnp.arange(page, dtype=jnp.int32)[None, None, :]   # (B, P, page)
    tok = tok.reshape(b, s_log)
    k = k_pool.reshape(n_pages * page, kvh, dh)[tok]         # (B, S, KV, dh)
    v = v_pool.reshape(n_pages * page, kvh, dh)[tok]
    qg = q.reshape(b, 1, kvh, rep, dh)
    # numerics mirror decode_attention exactly (f32 scores, unnormalized
    # exp, late divide) so paged and dense decode are step-parity equal
    scores = jnp.einsum("bqkrd,bskd->bqkrs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(s_log)
    valid = idx[None, :] < cache_len[:, None]                # (B, S)
    if window > 0:
        valid &= idx[None, :] >= cache_len[:, None] - window
    scores = jnp.where(valid[:, None, None, None, :], scores, _NEG)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    out = jnp.einsum("bqkrs,bskd->bqkrd", p, v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(p.sum(axis=-1), 1e-30)[..., None]
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def _decode_attention_paged_split(q, k_pool, v_pool, block_tables,
                                  cache_len, *, window: int,
                                  n_splits: int, constrain_split):
    """LSE page-split paged decode: stripe s owns logical pages
    [s*P/n, (s+1)*P/n), computes flash-style (m, l, acc) partials over
    its stripe, and the stripes merge via log-sum-exp combining.  The
    jnp twin of running the Pallas ``(out, lse)`` kernel variant per
    stripe and reducing with ``combine_lse_partials``."""
    b = q.shape[0]
    n_pages, page, kvh, dh = k_pool.shape
    h = q.shape[2]
    rep = h // kvh
    p_max = block_tables.shape[1]
    scale = dh ** -0.5
    pad = (-p_max) % n_splits
    if pad:
        # scratch-page rows past every cache_len — masked like any other
        # tail padding
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pad)))
    pp = p_max + pad
    per = pp // n_splits                 # logical pages per stripe
    s_per = per * page                   # tokens per stripe
    tok = (block_tables.astype(jnp.int32) * page)[:, :, None] \
        + jnp.arange(page, dtype=jnp.int32)[None, None, :]  # (B, pp, page)
    tok = tok.reshape(b, n_splits, s_per)
    if constrain_split is not None:
        # stripe axis -> 'model': the gather below pulls only this
        # shard's stripe from the (replicated-fallback) pool, and the
        # final stripe reduction becomes the cross-shard LSE combine
        tok = constrain_split(tok)
    k = k_pool.reshape(n_pages * page, kvh, dh)[tok]   # (B, n, S, KV, dh)
    v = v_pool.reshape(n_pages * page, kvh, dh)[tok]
    qg = q.reshape(b, 1, kvh, rep, dh)
    scores = jnp.einsum("bqkrd,bnskd->bnqkrs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    idx = (jnp.arange(n_splits) * s_per)[None, :, None] \
        + jnp.arange(s_per)[None, None, :]             # (1, n, S) global pos
    valid = idx < cache_len[:, None, None]
    if window > 0:
        valid &= idx >= cache_len[:, None, None] - window
    scores = jnp.where(valid[:, :, None, None, None, :], scores, _NEG)
    # per-stripe flash partials (m, l, acc), then the LSE merge over the
    # stripe axis — same reduction as combine_lse_partials, kept in
    # unnormalized (l, acc) form to skip one divide
    m = scores.max(axis=-1)                            # (B, n, 1, KV, rep)
    p = jnp.exp(scores - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bnqkrs,bnskd->bnqkrd", p, v,
                     preferred_element_type=jnp.float32)
    m_tot = m.max(axis=1, keepdims=True)               # (B, 1, 1, KV, rep)
    w = jnp.exp(m - m_tot)
    l_tot = (l * w).sum(axis=1)                        # (B, 1, KV, rep)
    acc_tot = (acc * w[..., None]).sum(axis=1)
    out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def encoder_attention(q, k, v, *, kv_mask=None):
    """Bidirectional (encoder / cross) attention — chunked (flash-style).

    q: (B,Sq,H,dh); k,v: (B,Sk,KV,dh).  kv_mask (B,Sk) is not supported in
    the chunked path; padding is handled by the caller's kv_positions.
    """
    assert kv_mask is None, "use kv_positions-based masking"
    return gqa_attention(q, k, v, causal=False)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """One-token decode attention over a (possibly ring-buffer) KV cache.

    q: (B, 1, H, dh); k_cache/v_cache: (B, S_max, KV, dh);
    cache_len: (B,) number of valid tokens (for ring buffers, the write
    cursor — all S_max slots valid once wrapped).
    Returns (B, 1, H, dh).

    Under the serving mesh the cache shards over KV (a batch dim of both
    einsums — exact); the train/serve rule sets may instead shard S over
    'model' for MQA/low-KV models, where GSPMD inserts the partial-max/sum
    all-reduces — the LSE-combine flash-decode pattern.
    """
    b, s_max, kvh, dh = k_cache.shape
    h = q.shape[2]
    rep = h // kvh
    scale = dh ** -0.5
    # grouped-head contraction — no materialized KV head repetition (a
    # (B,S,H,dh) broadcast of the cache would be GSPMD-resharded at full
    # size; measured collective-bound decode before this, §Perf).
    qg = q.reshape(b, 1, kvh, rep, dh)
    # bf16 reads + f32 accumulation (flash-decode numerics): casting the
    # cache to f32 doubles its HBM traffic for nothing (§Perf A.2)
    scores = jnp.einsum("bqkrd,bskd->bqkrs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(s_max)
    valid = idx[None, :] < cache_len[:, None]            # (B, S_max)
    if window > 0:
        # ring buffer: every slot holds one of the last `window` tokens
        valid = valid | (cache_len[:, None] >= s_max)
    scores = jnp.where(valid[:, None, None, None, :], scores, _NEG)
    # unnormalized-exp then late divide, mirroring gqa_attention's online
    # softmax step for step-parity with the prefill/full-forward path:
    # p stays f32 into the value contraction, normalizer applied last
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    out = jnp.einsum("bqkrs,bskd->bqkrd", p, v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(p.sum(axis=-1), 1e-30)[..., None]
    return out.reshape(b, 1, h, dh).astype(q.dtype)
