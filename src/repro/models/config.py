"""Model configuration covering every assigned architecture family."""

from __future__ import annotations

from dataclasses import dataclass, field, replace

__all__ = ["ModelConfig", "FAMILIES"]

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Fields cover dense GQA decoders, MoE, Mamba2 SSD,
    hybrid SSM+attention, encoder-decoder, and VLM backbones."""

    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    citation: str = ""

    # activations / layout
    activation: str = "swiglu"       # swiglu | squared_relu | gelu
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # attention variant (long-context)
    attention_kind: str = "full"     # full | sliding_window
    window: int = 8192

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_k_dense: int = 0           # leading dense layers (DeepSeek-MoE)
    dense_d_ff: int = 0              # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_kernel: int = 4

    # hybrid (Zamba2): a shared attention+MLP block applied every k layers
    hybrid_attn_every: int = 6

    # encoder-decoder
    n_encoder_layers: int = 0

    # modality frontend stub (audio frames / vision patches)
    frontend: str = ""               # "" | "audio_frames" | "patch_embed"
    n_frontend_tokens: int = 0       # patches/frames consumed at prefill

    # distribution / memory knobs
    fsdp: bool = False               # shard stacked-layer params over data
    grad_accum: int = 1
    remat: bool = True
    moment_dtype: str = "float32"    # adam moments ("bfloat16" for >=100B)

    # model-parallel submesh size these configs assume (mesh 'model' axis)
    model_parallel: int = 16

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"bad family {self.family!r}")
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ----------------------------------------------------------- derived

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the 'vocab' axis shards
        evenly over any mesh (MaxText-style logits padding)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Can serve 500k-token contexts sub-quadratically?"""
        return (self.family in ("ssm", "hybrid")
                or self.attention_kind == "sliding_window")

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        D, V = self.d_model, self.vocab_size
        emb = V * D * (1 if self.tie_embeddings else 2)
        n = emb
        dh = self.head_dim
        attn = D * self.n_heads * dh + 2 * D * self.n_kv_heads * dh \
            + self.n_heads * dh * D
        gate = 3 if self.activation == "swiglu" else 2
        dense_ffn = gate * D * self.d_ff

        def moe_ffn(layers):
            per = gate * D * self.moe_d_ff
            shared = self.n_shared_experts * per
            routed = self.n_experts * per
            router = D * self.n_experts
            return layers * (routed + shared + router)

        if self.family == "dense" or self.family == "vlm":
            n += self.n_layers * (attn + dense_ffn)
        elif self.family == "moe":
            moe_layers = self.n_layers - self.first_k_dense
            n += self.n_layers * attn
            n += self.first_k_dense * gate * D * (self.dense_d_ff or self.d_ff)
            n += moe_ffn(moe_layers)
        elif self.family == "ssm":
            per = (D * 2 * self.d_inner            # in_proj (x, z)
                   + 2 * D * self.ssm_state        # B, C proj
                   + D * self.ssm_heads            # dt
                   + self.d_inner * D)             # out_proj
            n += self.n_layers * per
        elif self.family == "hybrid":
            per = (D * 2 * self.d_inner + 2 * D * self.ssm_state
                   + D * self.ssm_heads + self.d_inner * D)
            n += self.n_layers * per + (attn + dense_ffn)  # one shared block
        elif self.family == "encdec":
            n += self.n_encoder_layers * (attn + dense_ffn)
            n += self.n_layers * (2 * attn + dense_ffn)  # self + cross
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D = self.d_model
        gate = 3 if self.activation == "swiglu" else 2
        per = gate * D * self.moe_d_ff
        moe_layers = self.n_layers - self.first_k_dense
        inactive = moe_layers * (self.n_experts - self.experts_per_token) * per
        return self.param_count() - inactive

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=256, <=4 experts."""
        dh = 64
        heads = max(2, min(4, self.n_heads))
        kv = 1 if self.n_kv_heads == 1 else (heads if self.n_kv_heads >= self.n_heads else max(1, heads // 2))
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2, d_model=256, n_heads=heads, n_kv_heads=kv,
            head_dim=dh, d_ff=512, vocab_size=512,
            n_encoder_layers=min(2, self.n_encoder_layers),
            window=64, fsdp=False, grad_accum=1, model_parallel=1,
            n_frontend_tokens=min(16, self.n_frontend_tokens),
        )
        if self.family == "moe":
            kw.update(n_experts=4, experts_per_token=2,
                      n_shared_experts=min(1, self.n_shared_experts),
                      moe_d_ff=128, first_k_dense=min(1, self.first_k_dense),
                      dense_d_ff=256)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16,
                      hybrid_attn_every=1)
        return replace(self, **kw)
