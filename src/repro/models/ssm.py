"""Mamba2 SSD (state-space duality) block.

Implements the chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):
within a chunk the recurrence is computed quadratically with matmuls
(MXU-friendly), across chunks a compact (H, P, N) state is carried by a
scan — O(S) work, constant decode state.  The chunked form here is the
pure-jnp oracle of the Pallas kernel in repro.kernels.ssd_scan.

Shapes: x (B,S,H,P) with H = d_inner/P heads, B/C projections shared
across heads (n_groups=1), per-head scalar decay a_t = exp(dt_t * -exp(A_log)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.context import constrain_inner, constrain_ssm_state
from .layers import ParamSpec

__all__ = ["ssm_template", "ssd_chunked", "ssd_decode_step", "mamba2_block",
           "mamba2_decode_step", "ssm_state_shape"]


def ssm_template(cfg, layers: int | None = None):
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    L = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    return {
        "in_proj_x": ParamSpec(L + (D, DI), jnp.bfloat16,
                               la + ("embed", "ssm_inner")),
        "in_proj_z": ParamSpec(L + (D, DI), jnp.bfloat16,
                               la + ("embed", "ssm_inner")),
        "bc_proj": ParamSpec(L + (D, 2 * N), jnp.bfloat16,
                             la + ("embed", None)),
        "dt_proj": ParamSpec(L + (D, H), jnp.bfloat16, la + ("embed", None)),
        "dt_bias": ParamSpec(L + (H,), jnp.float32, la + (None,), "zeros"),
        "a_log": ParamSpec(L + (H,), jnp.float32, la + (None,), "ssm_a"),
        "d_skip": ParamSpec(L + (H,), jnp.float32, la + (None,), "ones"),
        "conv_w": ParamSpec(L + (cfg.conv_kernel, DI), jnp.float32,
                            la + (None, "ssm_inner"), "normal"),
        "out_proj": ParamSpec(L + (DI, D), jnp.bfloat16,
                              la + ("ssm_inner", "embed")),
    }


def ssm_state_shape(cfg, batch: int):
    """Recurrent state (B, H, P, N) + conv tail (B, K-1, DI)."""
    return {
        "ssd": (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
        "conv": (batch, cfg.conv_kernel - 1, cfg.d_inner),
    }


def _causal_conv(x, w, tail=None, lengths=None):
    """Depthwise causal conv1d. x: (B,S,DI); w: (K,DI); tail: (B,K-1,DI).

    ``lengths`` (B,) marks each row's true length when ``x`` is padded at
    the end: the returned tail is then the last K-1 *valid* inputs
    (positions [length-K+1, length)), not the padded stream's physical
    tail, so a later decode step resumes from the same conv state the
    unpadded scan would have left."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)    # (B,S+K-1,DI)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    if k <= 1:
        new_tail = tail
    elif lengths is None:
        new_tail = xp[:, -(k - 1):, :]
    else:
        # xp index i holds x position i - (k-1): the last K-1 valid
        # inputs sit at xp[length .. length+K-2]
        new_tail = jax.vmap(
            lambda row, n: jax.lax.dynamic_slice_in_dim(row, n, k - 1))(
            xp, lengths)
    return out, new_tail


def ssd_chunked(x, dt, a_decay, Bmat, Cmat, init_state=None, chunk: int = 256):
    """Chunked SSD scan.

    x: (B,S,H,P) inputs; dt: (B,S,H) step sizes (post-softplus);
    a_decay: (B,S,H) per-step decay in (0,1); Bmat/Cmat: (B,S,N).
    Returns y (B,S,H,P), final_state (B,H,P,N).
    """
    b, s, h, p = x.shape
    n = Bmat.shape[-1]
    q = min(chunk, s)
    n_chunks = -(-s // q)
    pad = n_chunks * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a_decay = jnp.pad(a_decay, ((0, 0), (0, pad), (0, 0)),
                          constant_values=1.0)
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))

    # chunked views: (n_chunks, B, q, ...)
    def chunkify(t):
        return jnp.moveaxis(t.reshape(b, n_chunks, q, *t.shape[2:]), 1, 0)

    xc, dtc, ac = chunkify(x), chunkify(dt), chunkify(a_decay)
    Bc, Cc = chunkify(Bmat), chunkify(Cmat)

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    init_state = constrain_ssm_state(init_state)

    def chunk_step(state, xs):
        xq, dtq, aq, bq, cq = xs
        # log-decay prefix sums within the chunk
        la = jnp.log(jnp.maximum(aq.astype(jnp.float32), 1e-20))  # (B,q,H)
        cum = jnp.cumsum(la, axis=1)                              # (B,q,H)
        # intra-chunk quadratic term: L[i,j] = prod_{j<k<=i} a_k (causal)
        seg = cum[:, :, None, :] - cum[:, None, :, :]             # (B,q,q,H)
        causal = jnp.tril(jnp.ones((q, q), bool))
        Lmat = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cq.astype(jnp.float32),
                            bq.astype(jnp.float32))               # (B,q,q)
        w = scores[..., None] * Lmat                              # (B,q,q,H)
        xdt = xq.astype(jnp.float32) * dtq.astype(jnp.float32)[..., None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xdt)
        # contribution of the carried-in state
        decay_in = jnp.exp(cum)                                   # (B,q,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp",
                             cq.astype(jnp.float32), state, decay_in)
        # state update: decay over whole chunk + weighted outer products
        decay_out = jnp.exp(cum[:, -1:, :] - cum)                 # (B,q,H)
        dstate = jnp.einsum("bjn,bjhp,bjh->bhpn",
                            bq.astype(jnp.float32), xdt, decay_out)
        total = jnp.exp(cum[:, -1, :])                            # (B,H)
        new_state = state * total[:, :, None, None] + dstate
        return constrain_ssm_state(new_state), y_intra + y_inter

    # checkpoint each chunk: the backward otherwise saves the (B,q,q,H)
    # decay/score temporaries of EVERY chunk (~8.6 GiB/layer on zamba2
    # train_4k, EXPERIMENTS.md §Perf B) — recomputing them is cheap matmuls
    final_state, ys = jax.lax.scan(jax.checkpoint(chunk_step), init_state,
                                   (xc, dtc, ac, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, n_chunks * q, h, p)[:, :s]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, a_decay, Bvec, Cvec):
    """One recurrent step. state: (B,H,P,N); x: (B,H,P); dt,a: (B,H);
    Bvec/Cvec: (B,N).  Returns (y (B,H,P), new_state)."""
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    outer = jnp.einsum("bhp,bn->bhpn", xdt, Bvec.astype(jnp.float32))
    new_state = state * a_decay[..., None, None] + outer
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cvec.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def mamba2_block(params, u, cfg, state=None, lengths=None):
    """Full Mamba2 block over a sequence. u: (B,S,D).
    Returns (out (B,S,D), new_state dict).

    ``lengths`` (B,) int32 enables *true-length masking* for end-padded
    inputs: pad positions get dt = 0, hence per-step decay
    a = exp(-exp(A_log) * 0) = 1 exactly and input contribution
    x * dt = 0 — the recurrence carries the state through pads untouched,
    so the final state (and every valid position's output, the scan being
    causal) is bit-identical to running the unpadded sequence.  This is
    what lets the serving engine pad SSM/hybrid prefills to pow2 buckets
    instead of compiling once per distinct context length."""
    b, s, d = u.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin = constrain_inner(
        jnp.einsum("bsd,df->bsf", u, params["in_proj_x"]))     # (B,S,DI)
    z = constrain_inner(jnp.einsum("bsd,df->bsf", u, params["in_proj_z"]))
    conv_tail = None if state is None else state["conv"]
    xc, new_tail = _causal_conv(xin, params["conv_w"], conv_tail,
                                lengths=lengths)
    xc = jax.nn.silu(xc)
    bc = jnp.einsum("bsd,dn->bsn", u, params["bc_proj"])       # (B,S,2N)
    Bmat, Cmat = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, params["dt_proj"]).astype(jnp.float32)
        + params["dt_bias"])
    if lengths is not None:
        valid = jnp.arange(s)[None, :] < lengths[:, None]      # (B,S)
        dt = jnp.where(valid[..., None], dt, 0.0)
    a_decay = jnp.exp(-jnp.exp(params["a_log"]) * dt)          # (B,S,H)
    x_heads = xc.reshape(b, s, h, p)
    init = None if state is None else state["ssd"]
    y, final = ssd_chunked(x_heads, dt, a_decay, Bmat, Cmat,
                           init_state=init, chunk=cfg.ssm_chunk)
    y = y + x_heads * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = (y.reshape(b, s, h * p) * jax.nn.silu(z))
    out = jnp.einsum("bsf,fd->bsd", y, params["out_proj"])
    return out, {"ssd": final, "conv": new_tail}


def mamba2_decode_step(params, u, cfg, state):
    """One-token decode. u: (B,1,D); state from ssm_state_shape."""
    b = u.shape[0]
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    xin = jnp.einsum("bsd,df->bsf", u, params["in_proj_x"])    # (B,1,DI)
    z = jnp.einsum("bsd,df->bsf", u, params["in_proj_z"])
    xc, new_tail = _causal_conv(xin, params["conv_w"], state["conv"])
    xc = jax.nn.silu(xc)[:, 0]                                 # (B,DI)
    bc = jnp.einsum("bsd,dn->bsn", u, params["bc_proj"])[:, 0]
    Bvec, Cvec = bc[..., :n], bc[..., n:]
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", u, params["dt_proj"]
                   ).astype(jnp.float32)[:, 0] + params["dt_bias"])
    a_decay = jnp.exp(-jnp.exp(params["a_log"]) * dt)          # (B,H)
    x_heads = xc.reshape(b, h, p)
    y, new_ssd = ssd_decode_step(state["ssd"], x_heads, dt, a_decay,
                                 Bvec, Cvec)
    y = y + x_heads * params["d_skip"][None, :, None].astype(y.dtype)
    y = (y.reshape(b, 1, h * p) * jax.nn.silu(z))
    out = jnp.einsum("bsf,fd->bsd", y, params["out_proj"])
    return out, {"ssd": new_ssd, "conv": new_tail}
