"""Mixture-of-Experts FFN with capacity-based dense dispatch.

TPU adaptation (DESIGN.md): instead of NCCL all-to-all with ragged token
routing (the GPU idiom), tokens are scatter-packed into a per-expert
capacity buffer (E, C, D) and the expert FFNs run as one batched einsum —
dense, MXU-friendly, and shardable over the 'model' axis (expert
parallelism) with GSPMD inserting the (all-to-all-equivalent) collectives.
Overflow beyond capacity is dropped (standard Switch/GShard semantics);
the router carries the usual load-balance auxiliary loss.

Supports DeepSeek-MoE fine-grained layout: shared experts (always-on)
+ routed experts with top-k normalized gates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sharding.context import constrain_expert_buf, gather_model
from .layers import ParamSpec, mlp, mlp_template

__all__ = ["moe_template", "moe_ffn"]


def moe_template(cfg, layers: int | None = None):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    L = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    gate = cfg.activation == "swiglu"
    t = {
        "router": ParamSpec(L + (D, E), jnp.float32, la + ("embed", "router")),
        "w_in": ParamSpec(L + (E, D, F), jnp.bfloat16,
                          la + ("expert", "embed", "expert_mlp")),
        "w_out": ParamSpec(L + (E, F, D), jnp.bfloat16,
                           la + ("expert", "expert_mlp", "embed")),
    }
    if gate:
        t["w_gate"] = ParamSpec(L + (E, D, F), jnp.bfloat16,
                                la + ("expert", "embed", "expert_mlp"))
    if cfg.n_shared_experts > 0:
        t["shared"] = mlp_template(D, cfg.n_shared_experts * F,
                                   cfg.activation, layers)
    return t


def _expert_mlp(params, buf, activation: str):
    """buf: (E, C, D) -> (E, C, D) through per-expert FFNs."""
    h_in = jnp.einsum("ecd,edf->ecf", buf, params["w_in"])
    if activation == "swiglu":
        h_gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        h = jax.nn.silu(h_gate) * h_in
    elif activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h_in))
    else:
        h = jax.nn.gelu(h_in)
    return jnp.einsum("ecf,efd->ecd", h, params["w_out"])


def moe_ffn(params, x, cfg, *, decode: bool = False):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar).

    decode=True gives every assignment capacity (no token dropping):
    decode batches are small and dropping at decode corrupts generation.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    N = B * S
    if decode:
        C = N * K
    else:
        C = min(N * K, max(1, int(N * K * cfg.capacity_factor / E)))

    xf = x.reshape(N, D)
    logits = (xf.astype(jnp.float32) @ params["router"])        # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                      # (N, K)
    gates = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) assignment within its expert's capacity
    eflat = top_i.reshape(-1)                                   # (N*K,)
    onehot = jax.nn.one_hot(eflat, E, dtype=jnp.int32)          # (N*K, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot                 # exclusive
    pos = jnp.take_along_axis(ranks, eflat[:, None], axis=1)[:, 0]
    keep = pos < C                                              # drop overflow

    src = jnp.repeat(xf, K, axis=0)                             # (N*K, D)
    safe_pos = jnp.where(keep, pos, 0)
    buf = jnp.zeros((E, C, D), x.dtype).at[eflat, safe_pos].add(
        jnp.where(keep[:, None], src, 0).astype(x.dtype),
        mode="drop")
    buf = constrain_expert_buf(buf)

    out_buf = constrain_expert_buf(
        _expert_mlp(params, buf, cfg.activation))               # (E, C, D)

    # under expert parallelism the pick is a gather whose off-shard
    # contributions are exact zeros; in exact serving mode gather_model
    # then leaves the sharded regime so the K-way weighted sum runs
    # replicated in a fixed order.  In efficient mode the hook is the
    # identity: GSPMD lowers the pick itself to the cross-shard gather
    # and the weighted sum's order is whatever the partitioner picks —
    # part of why efficient mode is tolerance-based, not bit-identical
    # (routing flips amplify last-ulp drift; docs/sharded_serving.md)
    picked = gather_model(out_buf[eflat, safe_pos])             # (N*K, D)
    w = (gates.reshape(-1) * keep).astype(picked.dtype)
    out = (picked * w[:, None]).reshape(N, K, D).sum(axis=1)

    if cfg.n_shared_experts > 0:
        out = out + mlp(params["shared"], xf, cfg.activation)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs) * cfg.router_aux_weight
    return out.reshape(B, S, D), aux
