"""Model facade: one object per architecture with a uniform API.

    model = build_model(cfg)
    params = model.init(key)
    loss, aux = model.loss_fn(params, batch)              # training
    logits, cache = model.prefill(params, batch)          # serving prefill
    logits, cache = model.decode_step(params, tok, cache, cache_len)

Batches are dicts:
    decoder LMs:  {"tokens": (B,S), "labels": (B,S)}
    VLM:          + {"patches": (B,P,D)}   (stubbed frontend embeddings)
    audio encdec: {"frames": (B,S_enc,D), "tokens": (B,S_dec), "labels": ...}
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .encdec import (encdec_cache_shapes, encdec_decode_step, encdec_forward,
                     encdec_template)
from .layers import init_from_template, specs_from_template
from .transformer import (decoder_decode_step, decoder_decode_step_paged,
                          decoder_forward, decoder_prefill_chunk,
                          decoder_template, init_cache_shapes,
                          lm_loss, paged_cache_shapes)

__all__ = ["Model", "build_model"]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params

    def template(self):
        if self.cfg.family == "encdec":
            return encdec_template(self.cfg)
        return decoder_template(self.cfg)

    def init(self, key):
        return init_from_template(self.template(), key)

    def param_specs(self):
        return specs_from_template(self.template())

    # ------------------------------------------------------------ forward

    def forward(self, params, batch, *, collect_cache=False, remat=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_forward(params, cfg, batch["frames"],
                                  batch["tokens"],
                                  collect_cache=collect_cache, remat=remat)
        fe = batch.get("patches") if cfg.family == "vlm" else None
        return decoder_forward(params, cfg, batch["tokens"],
                               frontend_embeds=fe,
                               collect_cache=collect_cache, remat=remat,
                               lengths=batch.get("lengths"))

    def loss_fn(self, params, batch, remat=None):
        """Scalar LM loss (+ router aux)."""
        logits, _, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]
        if self.cfg.family == "vlm" and "patches" in batch:
            logits = logits[:, batch["patches"].shape[1]:]
        # next-token shift
        loss = lm_loss(logits[:, :-1], labels[:, 1:],
                       batch.get("loss_mask"))
        return loss + aux, {"lm_loss": loss, "aux_loss": aux}

    # ------------------------------------------------------------ serving

    def prefill(self, params, batch):
        """Returns (last-position logits (B,V), cache dict).

        ``batch`` may carry ``"lengths"`` (B,) int32 true row lengths for
        end-padded token buffers: recurrent families mask the scan so the
        returned state is bit-identical to an unpadded prefill (the engine
        pads to pow2 buckets for a bounded compile set).  Note the
        last-position logits are then pad-position logits — the serving
        engine never uses them (rewind-one-position trick)."""
        logits, cache, _ = self.forward(params, batch, collect_cache=True,
                                        remat=False)
        return logits[:, -1, :], cache

    def decode_step(self, params, token, cache, cache_len):
        """token: (B,1); cache_len: (B,). Returns ((B,V) logits, cache)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, cache = encdec_decode_step(params, cfg, token, cache,
                                               cache_len)
        else:
            logits, cache = decoder_decode_step(params, cfg, token, cache,
                                                cache_len)
        return logits[:, -1, :], cache

    def cache_shapes(self, batch: int, max_len: int, enc_len: int = 0):
        if self.cfg.family == "encdec":
            return encdec_cache_shapes(self.cfg, batch, max_len, enc_len)
        return init_cache_shapes(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, max_len, enc_len))

    # ----------------------------------------------------- paged serving

    @property
    def supports_paged(self) -> bool:
        """Can this model decode through a block-table KV pool?  Every
        decoder family qualifies (SSM state is per-slot, not paged);
        encdec needs its encoder cross-cache and stays dense."""
        return self.cfg.family != "encdec"

    @property
    def supports_chunked_prefill(self) -> bool:
        """Sarathi-style chunk-at-a-time prefill needs attention KV for
        the prefix — SSM/hybrid recurrent state can't replay a chunk."""
        return self.cfg.family in ("dense", "vlm", "moe")

    def paged_cache_shapes(self, n_pages: int, page_size: int,
                           n_slots: int):
        return paged_cache_shapes(self.cfg, n_pages, page_size, n_slots)

    def init_paged_cache(self, n_pages: int, page_size: int, n_slots: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.paged_cache_shapes(n_pages, page_size,
                                                    n_slots))

    def decode_step_paged(self, params, token, cache, cache_len,
                          block_tables, *, page_size: int):
        """Paged decode step.  token: (B,1); cache_len: (B,);
        block_tables: (B, P) int32.  Returns ((B,V) logits, cache)."""
        logits, cache = decoder_decode_step_paged(
            params, self.cfg, token, cache, cache_len, block_tables,
            page_size=page_size)
        return logits[:, -1, :], cache

    def prefill_chunk(self, params, tokens, past_k, past_v, start):
        """One prefill chunk against the cached prefix; returns the
        chunk's (k, v): (L, 1, C, KV, dh) for the engine to scatter."""
        return decoder_prefill_chunk(params, self.cfg, tokens,
                                     past_k, past_v, start)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
