"""Model facade: one object per architecture with a uniform API.

    model = build_model(cfg)
    params = model.init(key)
    loss, aux = model.loss_fn(params, batch)              # training
    logits, cache = model.prefill(params, batch)          # serving prefill
    logits, cache = model.decode_step(params, tok, cache, cache_len)

Batches are dicts:
    decoder LMs:  {"tokens": (B,S), "labels": (B,S)}
    VLM:          + {"patches": (B,P,D)}   (stubbed frontend embeddings)
    audio encdec: {"frames": (B,S_enc,D), "tokens": (B,S_dec), "labels": ...}
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .encdec import (encdec_cache_shapes, encdec_decode_step, encdec_forward,
                     encdec_template)
from .layers import init_from_template, specs_from_template
from .transformer import (decoder_decode_step, decoder_forward,
                          decoder_template, init_cache_shapes, lm_loss)

__all__ = ["Model", "build_model"]


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- params

    def template(self):
        if self.cfg.family == "encdec":
            return encdec_template(self.cfg)
        return decoder_template(self.cfg)

    def init(self, key):
        return init_from_template(self.template(), key)

    def param_specs(self):
        return specs_from_template(self.template())

    # ------------------------------------------------------------ forward

    def forward(self, params, batch, *, collect_cache=False, remat=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_forward(params, cfg, batch["frames"],
                                  batch["tokens"],
                                  collect_cache=collect_cache, remat=remat)
        fe = batch.get("patches") if cfg.family == "vlm" else None
        return decoder_forward(params, cfg, batch["tokens"],
                               frontend_embeds=fe,
                               collect_cache=collect_cache, remat=remat)

    def loss_fn(self, params, batch, remat=None):
        """Scalar LM loss (+ router aux)."""
        logits, _, aux = self.forward(params, batch, remat=remat)
        labels = batch["labels"]
        if self.cfg.family == "vlm" and "patches" in batch:
            logits = logits[:, batch["patches"].shape[1]:]
        # next-token shift
        loss = lm_loss(logits[:, :-1], labels[:, 1:],
                       batch.get("loss_mask"))
        return loss + aux, {"lm_loss": loss, "aux_loss": aux}

    # ------------------------------------------------------------ serving

    def prefill(self, params, batch):
        """Returns (last-position logits (B,V), cache dict)."""
        logits, cache, _ = self.forward(params, batch, collect_cache=True,
                                        remat=False)
        return logits[:, -1, :], cache

    def decode_step(self, params, token, cache, cache_len):
        """token: (B,1); cache_len: (B,). Returns ((B,V) logits, cache)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            logits, cache = encdec_decode_step(params, cfg, token, cache,
                                               cache_len)
        else:
            logits, cache = decoder_decode_step(params, cfg, token, cache,
                                                cache_len)
        return logits[:, -1, :], cache

    def cache_shapes(self, batch: int, max_len: int, enc_len: int = 0):
        if self.cfg.family == "encdec":
            return encdec_cache_shapes(self.cfg, batch, max_len, enc_len)
        return init_cache_shapes(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, max_len, enc_len))


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
