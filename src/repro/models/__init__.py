"""JAX model zoo: every assigned architecture family."""

from .config import FAMILIES, ModelConfig
from .model import Model, build_model

__all__ = ["FAMILIES", "ModelConfig", "Model", "build_model"]
