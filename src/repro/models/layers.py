"""Parameter templates + elementary layers (pure-functional JAX).

Every parameter is declared via a ``ParamSpec(shape, dtype, axes)`` in a
nested-dict *template*; ``init_from_template`` materializes weights and
``specs_from_template`` yields the logical-axis tree that
``repro.sharding.partitioning`` resolves into PartitionSpecs.  This keeps
shape declaration, initialization, and sharding in one place — the pattern
MaxText uses with flax metadata, without the flax dependency.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ParamSpec", "init_from_template", "specs_from_template",
    "shapes_from_template", "rms_norm", "linear", "rope_freqs",
    "apply_rope", "mlp", "mlp_template", "attention_template",
    "norm_template", "activation_fn",
]


class ParamSpec(NamedTuple):
    shape: tuple
    dtype: jnp.dtype
    axes: tuple          # logical axis name per dim (None allowed)
    init: str = "normal"  # normal | zeros | ones


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_from_template(template, key, scale: float = 0.02):
    """Materialize parameters from a template tree."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))

    def mk(spec: ParamSpec, k):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.dtype)
        if spec.init == "ssm_a":  # mamba2 A_log in [log 1, log 16]
            u = jax.random.uniform(k, spec.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(spec.dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = min(scale, float(np.sqrt(1.0 / max(1, fan_in))))
        return (jax.random.normal(k, spec.shape, jnp.float32) * std
                ).astype(spec.dtype)

    return treedef.unflatten([mk(s, k) for s, k in zip(leaves, keys)])


def specs_from_template(template):
    """Logical-axis tree mirroring the parameter tree."""
    return jax.tree.map(lambda s: s.axes, template, is_leaf=_is_spec)


def shapes_from_template(template):
    """ShapeDtypeStruct tree (for eval_shape-free dry runs)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                        template, is_leaf=_is_spec)


# ---------------------------------------------------------------- templates

def norm_template(d: int, layers: int | None = None):
    shape, axes = (d,), ("embed",)
    if layers is not None:
        shape, axes = (layers, d), ("layers", "embed")
    return {"scale": ParamSpec(shape, jnp.float32, axes, "ones")}


def attention_template(cfg, layers: int | None = None, bias: bool | None = None):
    D, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    bias = cfg.qkv_bias if bias is None else bias
    L = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    t = {
        "wq": ParamSpec(L + (D, H * dh), jnp.bfloat16, la + ("embed", "heads")),
        "wk": ParamSpec(L + (D, KV * dh), jnp.bfloat16, la + ("embed", "kv")),
        "wv": ParamSpec(L + (D, KV * dh), jnp.bfloat16, la + ("embed", "kv")),
        # wo's input dim gets its own logical axis: training and the
        # serving engine's parallel="efficient" rules shard it over
        # 'model' (Megatron row-parallel, psum after), but the exact
        # serving-decode rules must keep wo replicated — a row-parallel
        # output projection forces a psum of partial sums, whose
        # reduction order breaks bit-identity with the unsharded engine.
        "wo": ParamSpec(L + (H * dh, D), jnp.bfloat16,
                        la + ("heads_out", "embed")),
    }
    if bias:
        t["bq"] = ParamSpec(L + (H * dh,), jnp.float32, la + ("heads",), "zeros")
        t["bk"] = ParamSpec(L + (KV * dh,), jnp.float32, la + ("kv",), "zeros")
        t["bv"] = ParamSpec(L + (KV * dh,), jnp.float32, la + ("kv",), "zeros")
    return t


def mlp_template(d_model: int, d_ff: int, activation: str,
                 layers: int | None = None, mlp_axis: str = "mlp"):
    L = (layers,) if layers is not None else ()
    la = ("layers",) if layers is not None else ()
    t = {
        "w_in": ParamSpec(L + (d_model, d_ff), jnp.bfloat16,
                          la + ("embed", mlp_axis)),
        "w_out": ParamSpec(L + (d_ff, d_model), jnp.bfloat16,
                           la + (mlp_axis, "embed")),
    }
    if activation == "swiglu":
        t["w_gate"] = ParamSpec(L + (d_model, d_ff), jnp.bfloat16,
                                la + ("embed", mlp_axis))
    return t


# ------------------------------------------------------------------- layers

def rms_norm(params, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def linear(w, x, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def activation_fn(name: str):
    if name == "swiglu":          # handled by caller (gated)
        return jax.nn.silu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(f"unknown activation {name!r}")


def mlp(params, x, activation: str):
    if activation == "swiglu":
        h = jax.nn.silu(linear(params["w_gate"], x)) * linear(params["w_in"], x)
    else:
        h = activation_fn(activation)(linear(params["w_in"], x))
    return linear(params["w_out"], h)


# --------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                         # (dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,dh/2)
    cos = jnp.cos(angles)[..., None, :]                   # (...,S,1,dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x32_1 * cos - x32_2 * sin, x32_2 * cos + x32_1 * sin], axis=-1)
    return out.astype(x.dtype)
