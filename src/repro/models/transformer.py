"""Decoder-only transformer assembly: dense GQA, MoE, Mamba2 SSD, hybrid,
and VLM (frontend-embedding) variants — one code path per family, all with
scan-over-layers (+ optional remat) so 96-layer configs lower to compact
HLO for the multi-pod dry-run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..sharding.context import (attn_split_count, constrain_activations,
                                constrain_attn_split, constrain_heads,
                                constrain_kv_heads, constrain_q_heads,
                                gather_model)
from .attention import decode_attention, decode_attention_paged, gqa_attention
from .config import ModelConfig
from .layers import (ParamSpec, apply_rope, attention_template, linear, mlp,
                     mlp_template, norm_template, rms_norm)
from .moe import moe_ffn, moe_template
from .ssm import (mamba2_block, mamba2_decode_step, ssm_state_shape,
                  ssm_template)

__all__ = ["decoder_template", "decoder_forward", "decoder_decode_step",
           "decoder_decode_step_paged", "decoder_prefill_chunk",
           "init_cache_shapes", "paged_cache_shapes", "lm_loss"]


# ------------------------------------------------------------------ template

def _block_template(cfg: ModelConfig, kind: str, layers: int | None):
    """kind: dense | moe | ssm."""
    if kind == "ssm":
        return {"ln": norm_template(cfg.d_model, layers),
                "ssm": ssm_template(cfg, layers)}
    t = {"ln1": norm_template(cfg.d_model, layers),
         "ln2": norm_template(cfg.d_model, layers),
         "attn": attention_template(cfg, layers)}
    if kind == "moe":
        t["moe"] = moe_template(cfg, layers)
    else:
        t["mlp"] = mlp_template(cfg.d_model, cfg.d_ff, cfg.activation, layers)
    return t


def decoder_template(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.padded_vocab
    t = {
        "embed": ParamSpec((V, D), jnp.bfloat16, ("vocab", "embed")),
        "final_norm": norm_template(D),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamSpec((D, V), jnp.bfloat16, ("embed", "vocab"))

    if cfg.family in ("dense", "vlm"):
        t["layers"] = _block_template(cfg, "dense", cfg.n_layers)
    elif cfg.family == "moe":
        n_moe = cfg.n_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            dense_cfg = cfg.with_overrides(d_ff=cfg.dense_d_ff or cfg.d_ff)
            t["dense_layers"] = _block_template(dense_cfg, "dense",
                                                cfg.first_k_dense)
        t["layers"] = _block_template(cfg, "moe", n_moe)
    elif cfg.family == "ssm":
        t["layers"] = _block_template(cfg, "ssm", cfg.n_layers)
    elif cfg.family == "hybrid":
        t["layers"] = _block_template(cfg, "ssm", cfg.n_layers)
        t["shared_attn"] = _block_template(cfg, "dense", None)  # one block
    else:
        raise ValueError(f"decoder_template: bad family {cfg.family}")
    return t


# ----------------------------------------------------------------- blocks

def _wo_proj(cfg, p, o):
    """Attention output projection, decomposed per kv-head group.

    o: (B, S, H, dh) -> (B, S, D).  A single (H*dh)-long contraction fed
    by an all-gathered ``o`` is NOT shard-stable: GSPMD rewrites
    all-gather+dot into partial-dot+all-reduce (psum ordering), and even
    a blocked gather leaves the GEMM consuming a collective's buffer,
    whose layout steers the backend to a different accumulation order —
    both flip the last bf16 bit, which MoE routing amplifies into token
    divergence.  Instead: per-group partial dots (contraction never
    crosses a group, so never crosses a shard), all-gather the f32
    partials, then a fixed-order group sum on replicated data.  Under
    the training rules — and the serving engine's
    ``parallel="efficient"`` plan (wo row-sharded over 'model', gather
    hook = identity) — the same code reduces over a sharded axis and
    GSPMD emits the standard Megatron row-parallel psum: the "single
    psum per block" of the efficient decode plan falls out of this
    decomposition for free.
    """
    b, s, h, dh = o.shape
    g = cfg.n_kv_heads
    w = p["wo"].reshape(g, (h // g) * dh, -1)
    partial = jnp.einsum("bsgk,gkf->bsgf", o.reshape(b, s, g, (h // g) * dh),
                         w, preferred_element_type=jnp.float32)
    return gather_model(partial).sum(axis=2).astype(o.dtype)


def _pin_qkv(q, k, v):
    """Pin freshly projected (and rope'd) q/k/v to the serving context's
    layout (identity outside a serving context).

    Exact mode: pin REPLICATED (``gather_model`` is a P() constraint).
    Without the pin, the engine's KV-pool output constraints
    back-propagate through the cache writes into the wq/wk/wv GEMMs,
    re-sharding their output columns — and a column-split GEMM takes a
    different accumulation path on the backend, wobbling the last bf16
    bit (see decode_rule_table).  A user annotation stops the backward
    inference; sharded consumers (the paged-attention einsums) slice
    these replicated values locally, which is exact and collective-free.

    Efficient mode: ``gather_model`` is the identity and the
    ``constrain_*_heads`` hooks pin q/k/v HEAD-SHARDED instead — the
    column-parallel wq/wk/wv outputs stay split, k/v match the sharded
    pool's layout so the page scatter is shard-local, and the attention
    einsums run on per-shard head stripes."""
    q = constrain_q_heads(q)
    k = constrain_kv_heads(k)
    v = constrain_kv_heads(v)
    return gather_model(q), gather_model(k), gather_model(v)


def _attn_seq(cfg, p, h, positions, *, window: int):
    """Full-sequence attention sub-block. Returns (out, (k, v))."""
    b, s, d = h.shape
    q = constrain_heads(
        linear(p["wq"], h, p.get("bq")).reshape(b, s, cfg.n_heads, cfg.head_dim))
    k = linear(p["wk"], h, p.get("bk")).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], h, p.get("bv")).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q, k, v = _pin_qkv(q, k, v)
    o = gqa_attention(q, k, v, causal=True, window=window, positions=positions)
    o = constrain_heads(o)
    return _wo_proj(cfg, p, o), (k, v)


def _dense_block_seq(cfg, p, h, positions, *, window: int, with_moe: bool):
    h = constrain_activations(h)
    attn_out, kv = _attn_seq(cfg, p["attn"], rms_norm(p["ln1"], h, cfg.norm_eps),
                             positions, window=window)
    h = h + attn_out
    hn = rms_norm(p["ln2"], h, cfg.norm_eps)
    if with_moe:
        ffn_out, aux = moe_ffn(p["moe"], hn, cfg)
    else:
        ffn_out, aux = mlp(p["mlp"], hn, cfg.activation), 0.0
    return h + ffn_out, kv, aux


def _ssm_block_seq(cfg, p, h, state=None, lengths=None):
    h = constrain_activations(h)
    out, new_state = mamba2_block(p["ssm"], rms_norm(p["ln"], h, cfg.norm_eps),
                                  cfg, state, lengths=lengths)
    return h + out, new_state


# ------------------------------------------------------- sequence forward

def decoder_forward(params, cfg: ModelConfig, tokens, positions=None,
                    frontend_embeds=None, *, collect_cache: bool = False,
                    remat: bool | None = None, lengths=None):
    """Full-sequence forward (training and prefill).

    tokens: (B, S_text) int32.  frontend_embeds: (B, P, D) optional patch /
    audio-frame embeddings prepended to the text sequence (VLM stub).
    lengths: (B,) int32 true row lengths for end-padded batches — threaded
    into the SSM recurrence (true-length mask, bit-identical to unpadded)
    so recurrent families can prefill over pow2-bucketed padding; the
    attention families are causal, so end-pads never reach valid
    positions and need no mask.
    Returns (logits (B,S,V), cache_or_None, aux_loss).
    """
    remat = cfg.remat if remat is None else remat
    h = params["embed"][tokens]                           # (B, S_text, D)
    if frontend_embeds is not None:
        h = jnp.concatenate([frontend_embeds.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    if positions is None:
        positions = jnp.arange(s)
    window = cfg.window if cfg.attention_kind == "sliding_window" else 0

    aux_total = jnp.zeros((), jnp.float32)
    cache = {}

    def scan_blocks(h, stacked, body):
        nonlocal aux_total
        fn = jax.checkpoint(body) if remat else body

        def step(carry, layer_params):
            hh, aux = carry
            hh, kv, aux_l = fn(hh, layer_params)
            return (hh, aux + aux_l), kv

        (h, aux), kvs = jax.lax.scan(step, (h, aux_total), stacked)
        aux_total = aux
        return h, kvs

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.first_k_dense:
            def dense_body(hh, lp):
                hh, kv, aux = _dense_block_seq(cfg.with_overrides(
                    d_ff=cfg.dense_d_ff or cfg.d_ff), lp, hh, positions,
                    window=window, with_moe=False)
                return hh, kv, aux
            h, kv_d = scan_blocks(h, params["dense_layers"], dense_body)

        def body(hh, lp):
            return _dense_block_seq(cfg, lp, hh, positions, window=window,
                                    with_moe=cfg.family == "moe")
        h, kv_m = scan_blocks(h, params["layers"], body)
        if collect_cache:
            if cfg.family == "moe" and cfg.first_k_dense:
                k = jnp.concatenate([kv_d[0], kv_m[0]], axis=0)
                v = jnp.concatenate([kv_d[1], kv_m[1]], axis=0)
            else:
                k, v = kv_m
            cache = {"k": k, "v": v}                      # (L,B,S,KV,dh)

    elif cfg.family == "ssm":
        def body(carry, lp):
            hh = _ssm_block_seq(cfg, lp, carry, lengths=lengths)
            return hh[0], hh[1]
        fn = jax.checkpoint(body) if remat else body
        h, states = jax.lax.scan(fn, h, params["layers"])
        if collect_cache:
            cache = {"ssm": states}                       # dict of (L,...)

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        L = cfg.n_layers
        bounds = list(range(0, L, every))
        attn_caches = []
        mamba_states = []

        def body(carry, lp):
            hh = _ssm_block_seq(cfg, lp, carry, lengths=lengths)
            return hh[0], hh[1]
        fn = jax.checkpoint(body) if remat else body
        for gi, start in enumerate(bounds):
            end = min(start + every, L)
            seg = jax.tree.map(lambda x: x[start:end], params["layers"])
            h, st = jax.lax.scan(fn, h, seg)
            mamba_states.append(st)
            # shared attention block after each group
            sh = params["shared_attn"]
            h2, kv, _ = _dense_block_seq(cfg, sh, h, positions,
                                         window=window, with_moe=False)
            h = h2
            attn_caches.append(kv)
        if collect_cache:
            states = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                                  *mamba_states)
            k = jnp.stack([kv[0] for kv in attn_caches])  # (G,B,S,KV,dh)
            v = jnp.stack([kv[1] for kv in attn_caches])
            cache = {"ssm": states, "k": k, "v": v}
    else:
        raise ValueError(cfg.family)

    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    return logits, (cache if collect_cache else None), aux_total


# ----------------------------------------------------------------- caches

def init_cache_shapes(cfg: ModelConfig, batch: int, max_len: int):
    """Shapes (not arrays) of the decode cache, as jax.ShapeDtypeStruct."""
    dh, kv = cfg.head_dim, cfg.n_kv_heads
    out = {}
    if cfg.family in ("dense", "vlm", "moe"):
        out["k"] = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, max_len, kv, dh), jnp.bfloat16)
        out["v"] = jax.ShapeDtypeStruct(
            (cfg.n_layers, batch, max_len, kv, dh), jnp.bfloat16)
    if cfg.family in ("ssm", "hybrid"):
        ss = ssm_state_shape(cfg, batch)
        out["ssm"] = {
            "ssd": jax.ShapeDtypeStruct((cfg.n_layers,) + ss["ssd"],
                                        jnp.float32),
            "conv": jax.ShapeDtypeStruct((cfg.n_layers,) + ss["conv"],
                                         jnp.bfloat16),
        }
    if cfg.family == "hybrid":
        groups = -(-cfg.n_layers // cfg.hybrid_attn_every)
        out["k"] = jax.ShapeDtypeStruct((groups, batch, max_len, kv, dh),
                                        jnp.bfloat16)
        out["v"] = jax.ShapeDtypeStruct((groups, batch, max_len, kv, dh),
                                        jnp.bfloat16)
    return out


def _update_cache(cache_l, new, pos):
    """cache_l: (B,S,KV,dh); new: (B,1,KV,dh); pos: (B,) write index."""
    def upd(c, n, p):
        return jax.lax.dynamic_update_slice(c, n, (p, 0, 0))
    return jax.vmap(upd)(cache_l, new, pos)


def _attn_decode(cfg, p, h, k_cache, v_cache, cache_len, *, window: int):
    """h: (B,1,D). Updates cache at cache_len (mod ring for windows)."""
    b = h.shape[0]
    q = linear(p["wq"], h, p.get("bq")).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = linear(p["wk"], h, p.get("bk")).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], h, p.get("bv")).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    pos = cache_len[:, None]                              # (B,1) true position
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q, k, v = _pin_qkv(q, k, v)
    s_max = k_cache.shape[1]
    write = cache_len % s_max if window > 0 else cache_len
    k_cache = _update_cache(k_cache, k, write)
    v_cache = _update_cache(v_cache, v, write)
    o = decode_attention(q, k_cache, v_cache, cache_len + 1, window=window)
    return _wo_proj(cfg, p, o), k_cache, v_cache


def decoder_decode_step(params, cfg: ModelConfig, token, cache, cache_len):
    """One decode step.  token: (B,1) int32; cache_len: (B,) int32 (tokens
    already in cache).  Returns (logits (B,1,V), new_cache)."""
    h = params["embed"][token]                            # (B,1,D)
    window = cfg.window if cfg.attention_kind == "sliding_window" else 0
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.first_k_dense:
            fk = cfg.first_k_dense
            stacked = (params["dense_layers"], cache["k"][:fk],
                       cache["v"][:fk])

            def dense_body(hh, xs):
                lp, kc, vc = xs
                a, kc, vc = _attn_decode(
                    cfg, lp["attn"], rms_norm(lp["ln1"], hh, cfg.norm_eps),
                    kc, vc, cache_len, window=window)
                hh = hh + a
                dcfg = cfg.with_overrides(d_ff=cfg.dense_d_ff or cfg.d_ff)
                hh = hh + mlp(lp["mlp"], rms_norm(lp["ln2"], hh, cfg.norm_eps),
                              dcfg.activation)
                return hh, (kc, vc)
            h, (kd, vd) = jax.lax.scan(dense_body, h, stacked)
            moe_k, moe_v = cache["k"][fk:], cache["v"][fk:]
        else:
            fk = 0
            moe_k, moe_v = cache["k"], cache["v"]

        def body(hh, xs):
            lp, kc, vc = xs
            a, kc, vc = _attn_decode(
                cfg, lp["attn"], rms_norm(lp["ln1"], hh, cfg.norm_eps),
                kc, vc, cache_len, window=window)
            hh = hh + a
            hn = rms_norm(lp["ln2"], hh, cfg.norm_eps)
            if cfg.family == "moe":
                f, _ = moe_ffn(lp["moe"], hn, cfg, decode=True)
            else:
                f = mlp(lp["mlp"], hn, cfg.activation)
            return hh + f, (kc, vc)

        h, (km, vm) = jax.lax.scan(body, h, (params["layers"], moe_k, moe_v))
        if fk:
            new_cache["k"] = jnp.concatenate([kd, km], axis=0)
            new_cache["v"] = jnp.concatenate([vd, vm], axis=0)
        else:
            new_cache["k"], new_cache["v"] = km, vm

    elif cfg.family == "ssm":
        def body(hh, xs):
            lp, st = xs
            out, new_st = mamba2_decode_step(
                lp["ssm"], rms_norm(lp["ln"], hh, cfg.norm_eps), cfg, st)
            return hh + out, new_st
        h, new_states = jax.lax.scan(body, h, (params["layers"], cache["ssm"]))
        new_cache["ssm"] = new_states

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        L = cfg.n_layers
        bounds = list(range(0, L, every))
        new_states, new_ks, new_vs = [], [], []

        def body(hh, xs):
            lp, st = xs
            out, new_st = mamba2_decode_step(
                lp["ssm"], rms_norm(lp["ln"], hh, cfg.norm_eps), cfg, st)
            return hh + out, new_st
        for gi, start in enumerate(bounds):
            end = min(start + every, L)
            seg = jax.tree.map(lambda x: x[start:end], params["layers"])
            st = jax.tree.map(lambda x: x[start:end], cache["ssm"])
            h, ns = jax.lax.scan(body, h, (seg, st))
            new_states.append(ns)
            sh = params["shared_attn"]
            a, kc, vc = _attn_decode(
                cfg, sh["attn"], rms_norm(sh["ln1"], h, cfg.norm_eps),
                cache["k"][gi], cache["v"][gi], cache_len, window=window)
            h = h + a
            h = h + mlp(sh["mlp"], rms_norm(sh["ln2"], h, cfg.norm_eps),
                        cfg.activation)
            new_ks.append(kc)
            new_vs.append(vc)
        new_cache["ssm"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_states)
        new_cache["k"] = jnp.stack(new_ks)
        new_cache["v"] = jnp.stack(new_vs)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # vocab-sharded lm_head: column-parallel, no contraction over the
    # sharded dim.  Exact mode gathers (pure relayout — sampling sees
    # the exact single-device logits); efficient mode leaves the hook
    # as identity so the logits STAY vocab-sharded and the fused step's
    # argmax/categorical runs partitioned — only the winning token
    # crosses shards, never the logits
    logits = gather_model(jnp.einsum("bsd,dv->bsv", h, head))
    return logits, new_cache


# -------------------------------------------------------------------- loss

def lm_loss(logits, labels, mask=None):
    """Mean next-token cross-entropy.  logits: (B,S,V); labels: (B,S)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------- paged serving

def paged_cache_shapes(cfg: ModelConfig, n_pages: int, page_size: int,
                       n_slots: int):
    """Decode-cache shapes for the *paged* serving layout: attention KV
    lives in one (L, n_pages, page, KV, dh) pool shared by the whole
    batch (block-table indirection maps logical positions to physical
    pages; page 0 is the engine's scratch block), while SSM state — a
    constant-size recurrence, nothing to page — stays per decode slot."""
    dh, kv = cfg.head_dim, cfg.n_kv_heads
    out = {}
    if cfg.family in ("dense", "vlm", "moe"):
        shape = (cfg.n_layers, n_pages, page_size, kv, dh)
        out["k"] = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
        out["v"] = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
    if cfg.family in ("ssm", "hybrid"):
        ss = ssm_state_shape(cfg, n_slots)
        out["ssm"] = {
            "ssd": jax.ShapeDtypeStruct((cfg.n_layers,) + ss["ssd"],
                                        jnp.float32),
            "conv": jax.ShapeDtypeStruct((cfg.n_layers,) + ss["conv"],
                                         jnp.bfloat16),
        }
    if cfg.family == "hybrid":
        groups = -(-cfg.n_layers // cfg.hybrid_attn_every)
        shape = (groups, n_pages, page_size, kv, dh)
        out["k"] = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
        out["v"] = jax.ShapeDtypeStruct(shape, jnp.bfloat16)
    if cfg.family == "encdec":
        raise ValueError("paged serving does not support encdec")
    return out


def _attn_decode_paged(cfg, p, h, k_pool, v_pool, cache_len, block_tables,
                       *, window: int, page: int):
    """h: (B,1,D); pools (n_pages, page, KV, dh).  Writes this step's KV
    at each row's logical position through its block table (inactive rows
    point at the scratch page), then reads via paged flash-decode.
    ``window`` is a logical sliding window — no ring wrap."""
    b = h.shape[0]
    n_pages = k_pool.shape[0]
    q = linear(p["wq"], h, p.get("bq")).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    k = linear(p["wk"], h, p.get("bk")).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = linear(p["wv"], h, p.get("bv")).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
    pos = cache_len[:, None]                              # (B,1) true position
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    q, k, v = _pin_qkv(q, k, v)
    logical = cache_len.astype(jnp.int32)
    phys = block_tables[jnp.arange(b), logical // page] * page \
        + logical % page                                  # (B,) flat token idx
    flat = (n_pages * page,) + k_pool.shape[2:]
    k_pool = k_pool.reshape(flat).at[phys].set(
        k[:, 0].astype(k_pool.dtype)).reshape(k_pool.shape)
    v_pool = v_pool.reshape(flat).at[phys].set(
        v[:, 0].astype(v_pool.dtype)).reshape(v_pool.shape)
    # efficient-mode LSE fallback (sharding.context): when kv heads
    # don't divide the mesh, the logical page axis is split instead and
    # partial softmaxes merge via log-sum-exp combining
    o = decode_attention_paged(q, k_pool, v_pool, block_tables,
                               cache_len + 1, window=window,
                               n_splits=attn_split_count(),
                               constrain_split=constrain_attn_split)
    return _wo_proj(cfg, p, o), k_pool, v_pool


def decoder_decode_step_paged(params, cfg: ModelConfig, token, cache,
                              cache_len, block_tables, *, page_size: int):
    """One decode step over a paged KV pool.  token: (B,1) int32;
    cache_len: (B,) int32; block_tables: (B, P) int32 physical-page ids.
    Returns (logits (B,1,V), new_cache).  SSM families carry their
    (unpaged, per-slot) recurrent state unchanged in layout."""
    if cfg.family == "ssm":
        # attention-free: nothing to page — identical to the dense step
        return decoder_decode_step(params, cfg, token, cache, cache_len)
    h = params["embed"][token]                            # (B,1,D)
    window = cfg.window if cfg.attention_kind == "sliding_window" else 0
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.first_k_dense:
            fk = cfg.first_k_dense
            stacked = (params["dense_layers"], cache["k"][:fk],
                       cache["v"][:fk])

            def dense_body(hh, xs):
                lp, kc, vc = xs
                a, kc, vc = _attn_decode_paged(
                    cfg, lp["attn"], rms_norm(lp["ln1"], hh, cfg.norm_eps),
                    kc, vc, cache_len, block_tables,
                    window=window, page=page_size)
                hh = hh + a
                dcfg = cfg.with_overrides(d_ff=cfg.dense_d_ff or cfg.d_ff)
                hh = hh + mlp(lp["mlp"], rms_norm(lp["ln2"], hh, cfg.norm_eps),
                              dcfg.activation)
                return hh, (kc, vc)
            h, (kd, vd) = jax.lax.scan(dense_body, h, stacked)
            moe_k, moe_v = cache["k"][fk:], cache["v"][fk:]
        else:
            fk = 0
            moe_k, moe_v = cache["k"], cache["v"]

        def body(hh, xs):
            lp, kc, vc = xs
            a, kc, vc = _attn_decode_paged(
                cfg, lp["attn"], rms_norm(lp["ln1"], hh, cfg.norm_eps),
                kc, vc, cache_len, block_tables,
                window=window, page=page_size)
            hh = hh + a
            hn = rms_norm(lp["ln2"], hh, cfg.norm_eps)
            if cfg.family == "moe":
                f, _ = moe_ffn(lp["moe"], hn, cfg, decode=True)
            else:
                f = mlp(lp["mlp"], hn, cfg.activation)
            return hh + f, (kc, vc)

        h, (km, vm) = jax.lax.scan(body, h, (params["layers"], moe_k, moe_v))
        if fk:
            new_cache["k"] = jnp.concatenate([kd, km], axis=0)
            new_cache["v"] = jnp.concatenate([vd, vm], axis=0)
        else:
            new_cache["k"], new_cache["v"] = km, vm

    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        L = cfg.n_layers
        bounds = list(range(0, L, every))
        new_states, new_ks, new_vs = [], [], []

        def body(hh, xs):
            lp, st = xs
            out, new_st = mamba2_decode_step(
                lp["ssm"], rms_norm(lp["ln"], hh, cfg.norm_eps), cfg, st)
            return hh + out, new_st
        for gi, start in enumerate(bounds):
            end = min(start + every, L)
            seg = jax.tree.map(lambda x: x[start:end], params["layers"])
            st = jax.tree.map(lambda x: x[start:end], cache["ssm"])
            h, ns = jax.lax.scan(body, h, (seg, st))
            new_states.append(ns)
            sh = params["shared_attn"]
            a, kc, vc = _attn_decode_paged(
                cfg, sh["attn"], rms_norm(sh["ln1"], h, cfg.norm_eps),
                cache["k"][gi], cache["v"][gi], cache_len, block_tables,
                window=window, page=page_size)
            h = h + a
            h = h + mlp(sh["mlp"], rms_norm(sh["ln2"], h, cfg.norm_eps),
                        cfg.activation)
            new_ks.append(kc)
            new_vs.append(vc)
        new_cache["ssm"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_states)
        new_cache["k"] = jnp.stack(new_ks)
        new_cache["v"] = jnp.stack(new_vs)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # vocab-sharded lm_head: column-parallel, no contraction over the
    # sharded dim.  Exact mode gathers (pure relayout — sampling sees
    # the exact single-device logits); efficient mode leaves the hook
    # as identity so the logits STAY vocab-sharded and the fused step's
    # argmax/categorical runs partitioned — only the winning token
    # crosses shards, never the logits
    logits = gather_model(jnp.einsum("bsd,dv->bsv", h, head))
    return logits, new_cache


# -------------------------------------------------------- chunked prefill

def decoder_prefill_chunk(params, cfg: ModelConfig, tokens, past_k, past_v,
                          start):
    """One Sarathi-style prefill chunk: run the chunk's tokens against the
    already-cached prefix and return ONLY the chunk's new KV (the engine
    scatters it into the paged pool; logits come later from the shared
    decode path via the rewind-one-position trick).

    tokens: (1, C) int32 chunk (C may be padded; pad rows' KV is simply
    not scattered); past_k/past_v: (L, 1, S_past, KV, dh) gathered prefix
    KV — S_past may exceed the true prefix, ``start`` masks the tail;
    start: scalar int32, true prefix length = the chunk's first position.
    Returns (k_chunk, v_chunk): (L, 1, C, KV, dh).
    """
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError(f"chunked prefill unsupported for {cfg.family}")
    h = params["embed"][tokens]                           # (1, C, D)
    b, c, _ = h.shape
    s_past = past_k.shape[2]
    positions = start + jnp.arange(c)
    past_pos = jnp.arange(s_past)
    # invalid prefix rows get position -1e9: masked by gqa_attention's
    # kp >= 0 padding test, exactly like its internal end-padding
    kv_positions = jnp.concatenate([
        jnp.where(past_pos < start, past_pos, -(10 ** 9)), positions])
    window = cfg.window if cfg.attention_kind == "sliding_window" else 0

    def attn(acfg, p, hh, pk, pv):
        q = linear(p["wq"], hh, p.get("bq")).reshape(
            b, c, acfg.n_heads, acfg.head_dim)
        k = linear(p["wk"], hh, p.get("bk")).reshape(
            b, c, acfg.n_kv_heads, acfg.head_dim)
        v = linear(p["wv"], hh, p.get("bv")).reshape(
            b, c, acfg.n_kv_heads, acfg.head_dim)
        q = apply_rope(q, positions, acfg.rope_theta)
        k = apply_rope(k, positions, acfg.rope_theta)
        q, k, v = _pin_qkv(q, k, v)
        kf = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        vf = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        o = gqa_attention(q, kf, vf, causal=True, window=window,
                          positions=positions, kv_positions=kv_positions)
        return _wo_proj(acfg, p, o), (k, v)

    def block(acfg, lp, hh, pk, pv, *, with_moe):
        hh = constrain_activations(hh)
        a, kv = attn(acfg, lp["attn"], rms_norm(lp["ln1"], hh, acfg.norm_eps),
                     pk, pv)
        hh = hh + a
        hn = rms_norm(lp["ln2"], hh, acfg.norm_eps)
        if with_moe:
            f, _ = moe_ffn(lp["moe"], hn, acfg)
        else:
            f = mlp(lp["mlp"], hn, acfg.activation)
        return hh + f, kv

    def scan_blocks(hh, stacked, pk_all, pv_all, body):
        def step(carry, xs):
            lp, pk, pv = xs
            out, kv = body(carry, lp, pk, pv)
            return out, kv
        return jax.lax.scan(step, hh, (stacked, pk_all, pv_all))

    if cfg.family == "moe" and cfg.first_k_dense:
        fk = cfg.first_k_dense
        dcfg = cfg.with_overrides(d_ff=cfg.dense_d_ff or cfg.d_ff)
        h, (kd, vd) = scan_blocks(
            h, params["dense_layers"], past_k[:fk], past_v[:fk],
            lambda hh, lp, pk, pv: block(dcfg, lp, hh, pk, pv,
                                         with_moe=False))
        h, (km, vm) = scan_blocks(
            h, params["layers"], past_k[fk:], past_v[fk:],
            lambda hh, lp, pk, pv: block(cfg, lp, hh, pk, pv, with_moe=True))
        k_new = jnp.concatenate([kd, km], axis=0)
        v_new = jnp.concatenate([vd, vm], axis=0)
    else:
        h, (k_new, v_new) = scan_blocks(
            h, params["layers"], past_k, past_v,
            lambda hh, lp, pk, pv: block(cfg, lp, hh, pk, pv,
                                         with_moe=cfg.family == "moe"))
    return k_new, v_new
