"""Encoder-decoder backbone (Seamless-M4T medium language/decoder side).

The audio frontend (mel-spectrogram + conv feature extractor) is stubbed
per the assignment: the encoder consumes precomputed frame *embeddings*
(B, S_enc, D).  Everything downstream — bidirectional encoder stack,
causal decoder with cross-attention, KV caches for both — is fully built.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import decode_attention, encoder_attention, gqa_attention
from .config import ModelConfig
from .layers import (ParamSpec, apply_rope, attention_template, linear, mlp,
                     mlp_template, norm_template, rms_norm)
from .transformer import _update_cache

__all__ = ["encdec_template", "encode", "encdec_forward",
           "encdec_decode_step", "encdec_cache_shapes"]


def _enc_block_template(cfg, layers):
    return {"ln1": norm_template(cfg.d_model, layers),
            "ln2": norm_template(cfg.d_model, layers),
            "attn": attention_template(cfg, layers),
            "mlp": mlp_template(cfg.d_model, cfg.d_ff, cfg.activation, layers)}


def _dec_block_template(cfg, layers):
    t = _enc_block_template(cfg, layers)
    t["ln_cross"] = norm_template(cfg.d_model, layers)
    t["cross"] = attention_template(cfg, layers)
    return t


def encdec_template(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.padded_vocab
    return {
        "embed": ParamSpec((V, D), jnp.bfloat16, ("vocab", "embed")),
        "enc_layers": _enc_block_template(cfg, cfg.n_encoder_layers),
        "enc_norm": norm_template(D),
        "dec_layers": _dec_block_template(cfg, cfg.n_layers),
        "final_norm": norm_template(D),
        "lm_head": ParamSpec((D, V), jnp.bfloat16, ("embed", "vocab")),
    }


def encode(params, cfg: ModelConfig, frames, remat: bool | None = None):
    """frames: (B, S_enc, D) precomputed embeddings -> (B, S_enc, D)."""
    remat = cfg.remat if remat is None else remat
    h = frames.astype(jnp.bfloat16)
    b, s, _ = h.shape

    def body(hh, lp):
        hn = rms_norm(lp["ln1"], hh, cfg.norm_eps)
        q = linear(lp["attn"]["wq"], hn).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = linear(lp["attn"]["wk"], hn).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = linear(lp["attn"]["wv"], hn).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        pos = jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        o = encoder_attention(q, k, v)
        hh = hh + linear(lp["attn"]["wo"], o.reshape(b, s, -1))
        hh = hh + mlp(lp["mlp"], rms_norm(lp["ln2"], hh, cfg.norm_eps),
                      cfg.activation)
        return hh, None

    fn = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(fn, h, params["enc_layers"])
    return rms_norm(params["enc_norm"], h, cfg.norm_eps)


def _cross_kv(params_stacked, cfg, enc_out):
    """Precompute cross-attention K/V for all decoder layers.
    Returns (L, B, S_enc, KV, dh) pair."""
    b, s, _ = enc_out.shape

    def per_layer(lp):
        k = linear(lp["cross"]["wk"], enc_out).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim)
        v = linear(lp["cross"]["wv"], enc_out).reshape(
            b, s, cfg.n_kv_heads, cfg.head_dim)
        return k, v

    return jax.vmap(per_layer)(params_stacked)


def encdec_forward(params, cfg: ModelConfig, frames, dec_tokens,
                   *, collect_cache: bool = False, remat: bool | None = None):
    """Teacher-forced forward. Returns (logits, cache_or_None, aux=0)."""
    remat = cfg.remat if remat is None else remat
    enc_out = encode(params, cfg, frames, remat)
    h = params["embed"][dec_tokens]
    b, s, _ = h.shape
    positions = jnp.arange(s)
    ck, cv = _cross_kv(params["dec_layers"], cfg, enc_out)

    def body(hh, xs):
        lp, ckl, cvl = xs
        hn = rms_norm(lp["ln1"], hh, cfg.norm_eps)
        q = linear(lp["attn"]["wq"], hn).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = linear(lp["attn"]["wk"], hn).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = linear(lp["attn"]["wv"], hn).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = gqa_attention(q, k, v, causal=True, positions=positions)
        hh = hh + linear(lp["attn"]["wo"], o.reshape(b, s, -1))
        # cross attention
        hc = rms_norm(lp["ln_cross"], hh, cfg.norm_eps)
        qc = linear(lp["cross"]["wq"], hc).reshape(b, s, cfg.n_heads, cfg.head_dim)
        oc = encoder_attention(qc, ckl, cvl)
        hh = hh + linear(lp["cross"]["wo"], oc.reshape(b, s, -1))
        hh = hh + mlp(lp["mlp"], rms_norm(lp["ln2"], hh, cfg.norm_eps),
                      cfg.activation)
        return hh, (k, v)

    fn = jax.checkpoint(body) if remat else body
    h, (sk, sv) = jax.lax.scan(fn, h, (params["dec_layers"], ck, cv))
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    cache = None
    if collect_cache:
        cache = {"k": sk, "v": sv, "cross_k": ck, "cross_v": cv}
    return logits, cache, jnp.zeros((), jnp.float32)


def encdec_cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                        enc_len: int):
    dh, kv, L = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
    return {
        "k": jax.ShapeDtypeStruct((L, batch, max_len, kv, dh), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((L, batch, max_len, kv, dh), jnp.bfloat16),
        "cross_k": jax.ShapeDtypeStruct((L, batch, enc_len, kv, dh),
                                        jnp.bfloat16),
        "cross_v": jax.ShapeDtypeStruct((L, batch, enc_len, kv, dh),
                                        jnp.bfloat16),
    }


def encdec_decode_step(params, cfg: ModelConfig, token, cache, cache_len):
    """One decoder step with cached self KV + precomputed cross KV."""
    h = params["embed"][token]                            # (B,1,D)
    b = h.shape[0]

    def body(hh, xs):
        lp, kc, vc, ckl, cvl = xs
        hn = rms_norm(lp["ln1"], hh, cfg.norm_eps)
        q = linear(lp["attn"]["wq"], hn).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = linear(lp["attn"]["wk"], hn).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = linear(lp["attn"]["wv"], hn).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        pos = cache_len[:, None]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        kc = _update_cache(kc, k, cache_len)
        vc = _update_cache(vc, v, cache_len)
        o = decode_attention(q, kc, vc, cache_len + 1)
        hh = hh + linear(lp["attn"]["wo"], o.reshape(b, 1, -1))
        hc = rms_norm(lp["ln_cross"], hh, cfg.norm_eps)
        qc = linear(lp["cross"]["wq"], hc).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        oc = encoder_attention(qc, ckl, cvl)
        hh = hh + linear(lp["cross"]["wo"], oc.reshape(b, 1, -1))
        hh = hh + mlp(lp["mlp"], rms_norm(lp["ln2"], hh, cfg.norm_eps),
                      cfg.activation)
        return hh, (kc, vc)

    h, (nk, nv) = jax.lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    h = rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = nk, nv
    return logits, new_cache
