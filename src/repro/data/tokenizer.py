"""Deterministic byte-level tokenizer (no external vocab files).

Tokens: 0 = <eos>, 1 = <pad>, 2 = <bos>, bytes map to 3..258.  For models
with larger vocabularies the byte ids simply occupy the low end; synthetic
training data (repro.data.datasets) samples the full range.
"""

from __future__ import annotations

__all__ = ["ByteTokenizer"]


class ByteTokenizer:
    eos_id = 0
    pad_id = 1
    bos_id = 2
    offset = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.offset

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = False) -> list[int]:
        ids = [b + self.offset for b in text.encode("utf-8")]
        if add_bos:
            ids = [self.bos_id] + ids
        if add_eos:
            ids = ids + [self.eos_id]
        return ids

    def decode(self, ids) -> str:
        bs = bytes(i - self.offset for i in ids
                   if i >= self.offset and i - self.offset < 256)
        return bs.decode("utf-8", errors="replace")
