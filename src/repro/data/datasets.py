"""Synthetic token datasets + batching pipeline for training examples.

``lm_batches`` yields an infinite stream of (tokens, labels) LM batches
with a learnable structure (copy/induction patterns + Zipfian unigrams),
so a ~100M model visibly reduces loss within a few hundred steps — the
end-to-end training example's acceptance signal.
"""

from __future__ import annotations

import numpy as np

__all__ = ["lm_batches", "zipf_tokens"]


def zipf_tokens(rng: np.random.Generator, n: int, vocab: int,
                alpha: float = 1.1) -> np.ndarray:
    """Zipf-distributed token ids in [3, vocab)."""
    raw = rng.zipf(alpha, size=n).astype(np.int64)
    return 3 + (raw - 1) % (vocab - 3)


def lm_batches(vocab_size: int, batch: int, seq_len: int, seed: int = 0):
    """Infinite iterator of {"tokens","labels"} int32 arrays (B, S).

    Each sequence mixes Zipf unigrams with repeated motifs (induction
    heads' favorite snack), so next-token loss has learnable signal.
    """
    rng = np.random.default_rng(seed)
    while True:
        toks = zipf_tokens(rng, batch * seq_len, vocab_size
                           ).reshape(batch, seq_len)
        # plant copy motifs: seq[i : i+k] = seq[j : j+k]
        for b in range(batch):
            for _ in range(max(1, seq_len // 64)):
                k = int(rng.integers(4, 12))
                if seq_len <= 2 * k + 2:
                    continue
                j = int(rng.integers(0, seq_len - 2 * k - 1))
                i = int(rng.integers(j + k, seq_len - k))
                toks[b, i:i + k] = toks[b, j:j + k]
        toks = toks.astype(np.int32)
        yield {"tokens": toks, "labels": toks.copy()}
