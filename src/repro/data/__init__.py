"""Data pipeline: tokenizer + synthetic dataset generators."""

from .datasets import lm_batches, zipf_tokens
from .tokenizer import ByteTokenizer

__all__ = ["lm_batches", "zipf_tokens", "ByteTokenizer"]
