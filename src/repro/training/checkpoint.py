"""npz-based pytree checkpointing (flat-key format, no external deps)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):  # jax.tree flattens dicts in sorted-key order
            v = tree[k]
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif hasattr(tree, "_fields"):  # NamedTuple (AdamWState)
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}{_SEP}"))
    else:
        arr = np.asarray(tree)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = np.asarray(jnp.asarray(tree).astype(jnp.float32))
        out[prefix.rstrip(_SEP)] = arr
    return out


def save_checkpoint(path: str, params, opt_state=None, step: int = 0) -> None:
    flat = {"__step__": np.asarray(step)}
    flat.update({f"params{_SEP}{k}": v
                 for k, v in _flatten(params).items()})
    if opt_state is not None:
        flat.update({f"opt{_SEP}{k}": v
                     for k, v in _flatten(opt_state).items()})
    tmp = path + ".tmp.npz"
    np.savez(tmp, **flat)
    os.replace(tmp, path)


def load_checkpoint(path: str, params_like, opt_like=None):
    """Restore into the structure of params_like/opt_like templates."""
    data = np.load(path)
    step = int(data["__step__"])

    def restore(template, prefix):
        flat_keys = _flatten(template)
        leaves, treedef = jax.tree.flatten(template)
        keys = list(flat_keys.keys())
        assert len(keys) == len(leaves)
        vals = [jnp.asarray(data[f"{prefix}{_SEP}{k}"]).astype(leaf.dtype)
                for k, leaf in zip(keys, leaves)]
        return treedef.unflatten(vals)

    params = restore(params_like, "params")
    if opt_like is None:
        return params, None, step
    return params, restore(opt_like, "opt"), step
