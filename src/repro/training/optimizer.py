"""AdamW in pure JAX (pytree-native, shardable moments).

Moments inherit the parameter's sharding (same tree structure), so FSDP
configs get ZeRO-sharded optimizer state for free.  ``moment_dtype``
(ModelConfig) lets >=100B models keep m/v in bf16 — the DESIGN.md memory
budget for nemotron-340b on v5e.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "AdamW"]


class AdamWState(NamedTuple):
    count: jnp.ndarray
    m: dict
    v: dict


@dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
        return AdamWState(count=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    # leaves bigger than this are updated layer-by-layer (lax.map over the
    # stacked leading axis) so the f32 math temporaries stay one-layer-sized
    # — measured ~20 GiB of f32 temp stacks on nemotron-340b otherwise
    # (EXPERIMENTS.md §Perf).
    chunk_threshold: int = 32 * 2**20  # elements

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        """Returns (new_params, new_state).  All math in f32, cast back."""
        count = state.count + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self.learning_rate * lr_scale

        def math(p, g, m, v, decay):
            g32 = g.astype(jnp.float32)
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            mhat = m32 / b1c
            vhat = v32 / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if decay:  # decoupled weight decay on matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return newp, m32.astype(m.dtype), v32.astype(v.dtype)

        def upd(p, g, m, v):
            decay = p.ndim >= 2
            if p.size > self.chunk_threshold and p.ndim >= 3:
                return jax.lax.map(
                    lambda x: math(*x, decay), (p, g, m, v))
            return math(p, g, m, v, decay)

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        newp = treedef.unflatten([t[0] for t in leaves])
        newm = treedef.unflatten([t[1] for t in leaves])
        newv = treedef.unflatten([t[2] for t in leaves])
        return newp, AdamWState(count=count, m=newm, v=newv)
