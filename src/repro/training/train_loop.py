"""Training step factory: grad accumulation + AdamW, pjit-ready.

``make_train_step(model, optimizer)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` that
scans over ``cfg.grad_accum`` microbatches (accumulating grads in the
parameter dtype — the DESIGN.md memory budget), then applies one AdamW
update.  The same function is what the multi-pod dry-run lowers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .optimizer import AdamW, AdamWState

__all__ = ["make_train_step", "make_lr_schedule"]


def make_lr_schedule(base_lr: float = 3e-4, warmup: int = 100,
                     total: int = 10_000, min_frac: float = 0.1):
    """Linear warmup + cosine decay, as a scale factor on base_lr."""
    def scale(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(1.0, warmup), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return scale


def make_train_step(model, optimizer: AdamW, lr_schedule=None):
    cfg = model.cfg
    accum = max(1, cfg.grad_accum)
    lr_schedule = lr_schedule or (lambda step: 1.0)

    def loss_for_grad(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])
            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)

            def body(acc, mb):
                (l, mt), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype),
                                   acc, g)
                return acc, (l, mt)

            grads, (losses, metricses) = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), metricses)
        params, opt_state = optimizer.update(
            grads, opt_state, params, lr_scale=lr_schedule(opt_state.count))
        metrics = dict(metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
