"""Training substrate: optimizer, schedules, train step, checkpointing."""

from .checkpoint import load_checkpoint, save_checkpoint
from .optimizer import AdamW, AdamWState
from .train_loop import make_lr_schedule, make_train_step

__all__ = ["load_checkpoint", "save_checkpoint", "AdamW", "AdamWState",
           "make_lr_schedule", "make_train_step"]
