"""OLMoE-1B-7B [arXiv:2409.02060] — 64-expert top-8 MoE, MHA.

16L, d_model=2048, 16 heads (kv=16), per-expert d_ff=1024, vocab=50304.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    n_experts=64, experts_per_token=8, moe_d_ff=1024,
    activation="swiglu", rope_theta=500_000.0,
    citation="arXiv:2409.02060",
)

LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
