"""Granite-34B-Code [arXiv:2405.04324] — llama-arch code model with MQA.

88L, d_model=6144, 48 heads (GQA kv=1 = multi-query), d_ff=24576 (4x, GELU
non-gated per GPTBigCode lineage), vocab=49152.
NOTE: upstream uses learned absolute positions; we use RoPE uniformly
(recorded deviation, DESIGN.md Sec. 7).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab_size=49152, head_dim=128,
    activation="gelu", rope_theta=100_000.0,
    fsdp=True, grad_accum=4,
    citation="arXiv:2405.04324",
)

LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
