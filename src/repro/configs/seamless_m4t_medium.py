"""SeamlessM4T-medium [arXiv:2308.11596] — enc-dec multimodal backbone.

12L encoder + 12L decoder, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=256206.  Audio frontend (mel + conv) is STUBBED: the encoder
consumes precomputed frame embeddings (assignment carve-out).
long_500k: SKIPPED — full cross-attention over a 500k-frame encoding has
no sub-quadratic decoder path without changing the architecture
(DESIGN.md Sec. 5).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_encoder_layers=12, d_model=1024, n_heads=16,
    n_kv_heads=16, d_ff=4096, vocab_size=256206, head_dim=64,
    activation="gelu", rope_theta=10_000.0,
    frontend="audio_frames", n_frontend_tokens=4096,
    citation="arXiv:2308.11596",
)
# NOTE: no LONG_CONTEXT defined — long_500k skip is intentional.
