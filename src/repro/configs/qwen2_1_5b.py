"""Qwen2-1.5B [arXiv:2407.10671] — dense GQA decoder with QKV bias.

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    activation="swiglu", qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="arXiv:2407.10671",
)

# long_500k: sliding-window variant (DESIGN.md Sec. 5)
LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
