"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSD (state-space
duality). 64L, d_model=2560, ssm_state=128, head_dim=64, expand=2.

long_500k: native (constant-size recurrent state).
SageSched cost model: 'linear' (DESIGN.md Sec. 4 — no KV growth).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280, head_dim=64,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    citation="arXiv:2405.21060",
)

LONG_CONTEXT = CONFIG  # natively sub-quadratic
