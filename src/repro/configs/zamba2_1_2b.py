"""Zamba2-1.2B [arXiv:2411.15242] — hybrid: Mamba2 backbone + shared
attention block applied periodically.

38 Mamba2 layers, d_model=2048; shared attn block: 32 heads (kv=32,
MHA), d_ff=8192; ssm_state=64; vocab=32000.
long_500k: native for the SSM path; the shared attention applications use
the sliding-window variant at 500k (DESIGN.md Sec. 5).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    hybrid_attn_every=6,
    activation="swiglu", rope_theta=10_000.0,
    citation="arXiv:2411.15242",
)

LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
