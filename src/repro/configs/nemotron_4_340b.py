"""Nemotron-4-340B [arXiv:2402.16819] — dense GQA with squared-ReLU MLP.

96L, d_model=18432, 96 heads (GQA kv=8), d_ff=73728, vocab=256000.
Distribution: FSDP (layers over 'data') + tensor parallel; grad_accum=16;
bf16 Adam moments (DESIGN.md memory budget).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab_size=256000, head_dim=192,
    activation="squared_relu", rope_theta=500_000.0,
    fsdp=True, grad_accum=16, moment_dtype="bfloat16",
    citation="arXiv:2402.16819",
)

LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
