"""Registry of the 10 assigned architectures and 4 input shapes."""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from ..models.config import ModelConfig

__all__ = ["ARCH_IDS", "SHAPE_IDS", "InputShape", "get_config", "get_shape",
           "iter_configs"]

ARCH_IDS = (
    "qwen2-1.5b",
    "olmoe-1b-7b",
    "nemotron-4-340b",
    "deepseek-moe-16b",
    "seamless-m4t-medium",
    "mamba2-2.7b",
    "llama3.2-1b",
    "internvl2-76b",
    "granite-34b",
    "zamba2-1.2b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
SHAPE_IDS = tuple(_SHAPES)


def get_shape(name: str) -> InputShape:
    return _SHAPES[name]


def get_config(arch: str, *, reduced: bool = False,
               long_context: bool = False) -> ModelConfig:
    """Load an architecture config.

    reduced: smoke-test variant (2 layers, d_model<=256, <=4 experts).
    long_context: apply the arch's documented long_500k variant (sliding-
        window attention for dense/MoE/VLM; native for SSM/hybrid).
    """
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch])
    cfg: ModelConfig = mod.CONFIG
    if long_context:
        if not hasattr(mod, "LONG_CONTEXT"):
            raise ValueError(
                f"{arch} has no long-context variant (see DESIGN.md skips)")
        cfg = mod.LONG_CONTEXT
    if reduced:
        cfg = cfg.reduced()
    return cfg


def iter_configs(reduced: bool = False):
    for a in ARCH_IDS:
        yield a, get_config(a, reduced=reduced)
