"""InternVL2-76B [arXiv:2404.16821] — VLM: InternViT + InternLM2/Llama3-70B
language model.  Vision encoder is STUBBED (assignment carve-out): the LM
consumes precomputed patch embeddings.

LM backbone: 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672,
vocab=128256.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    activation="swiglu", rope_theta=500_000.0,
    frontend="patch_embed", n_frontend_tokens=1024,
    fsdp=True, grad_accum=8,
    citation="arXiv:2404.16821",
)

LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
