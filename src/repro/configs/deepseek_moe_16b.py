"""DeepSeek-MoE-16B [arXiv:2401.06066] — fine-grained MoE:
2 shared + 64 routed experts, top-6, first layer dense.

28L, d_model=2048, 16 heads (kv=16), per-expert d_ff=1408, vocab=102400.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=102400, head_dim=128,
    n_experts=64, experts_per_token=6, n_shared_experts=2,
    moe_d_ff=1408, first_k_dense=1, dense_d_ff=10944,
    activation="swiglu", rope_theta=10_000.0,
    citation="arXiv:2401.06066",
)

LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
