"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B] — small llama3 dense GQA.

16L, d_model=2048, 32 heads (GQA kv=8), d_ff=8192, vocab=128256.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=64,
    activation="swiglu", rope_theta=500_000.0, tie_embeddings=True,
    citation="hf:meta-llama/Llama-3.2-1B",
)

LONG_CONTEXT = CONFIG.with_overrides(attention_kind="sliding_window",
                                     window=8192)
