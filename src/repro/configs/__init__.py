"""Assigned architecture configs + input shapes (see each module's citation).

Usage:  from repro.configs import get_config, ARCH_IDS, get_shape, SHAPE_IDS
"""

from .registry import (ARCH_IDS, SHAPE_IDS, InputShape, get_config,
                       get_shape, iter_configs)

__all__ = ["ARCH_IDS", "SHAPE_IDS", "InputShape", "get_config", "get_shape",
           "iter_configs"]
