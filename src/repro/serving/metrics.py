"""Engine-level serving metrics (TTFT / ITL / throughput accounting).

Swap IO is accounted in *modeled* seconds through the SAME
``ServiceModel.swap_time`` / block-table math the simulator charges, so
the real engine and the discrete-event simulator report preemption cost
from one model (asserted in tests/test_serving_engine.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["EngineMetrics"]


def _pct(values: np.ndarray, q: float) -> float:
    return float(np.quantile(values, q))


@dataclass
class EngineMetrics:
    prefills: int = 0            # completed prefill passes (swap-ins skip)
    prefill_chunks: int = 0      # chunk forwards run (== prefills if atomic)
    prefill_tokens: int = 0      # true (unpadded) prompt tokens prefilled
    prefill_tokens_reused: int = 0  # prompt tokens adopted from the prefix
                                 # index instead of being re-prefilled
                                 # (copy-on-write sharing; 0 when off)
    decode_iterations: int = 0   # device decode forwards executed
    decode_tokens: int = 0       # tokens actually sampled (masked lanes
                                 # and post-finish fori_loop steps excluded)
    fused_steps: int = 0         # fused jitted (multi-)step calls issued;
                                 # each is ONE device dispatch + ONE
                                 # device->host bookkeeping transfer
    completed: int = 0
    preemptions: int = 0
    forced_evictions: int = 0    # capacity-forced (decode-growth) evictions
    grow_failures: int = 0       # KVCacheManager.grow() returned False
    swap_outs: int = 0
    swap_ins: int = 0
    swapped_out_tokens: int = 0
    swapped_in_tokens: int = 0
    modeled_swap_s: float = 0.0  # ServiceModel.swap_time over swap events
    # ----- overload / failure accounting (goodput != throughput) -----
    aborted: int = 0             # requests ended by abort() (any reason)
    shed: int = 0                # gateway load-shed verdicts (terminal)
    retries: int = 0             # gateway re-admission attempts
    timeout_aborts: int = 0      # TTFT/TTLT deadline-triggered aborts
    wasted_tokens: int = 0       # tokens decoded for requests that were
                                 # later aborted / shed / timed out
    swap_in_faults: int = 0      # unexpected swap_in failures that fell
                                 # back to recompute (pool had room)
    # per-tenant rolling calibration table (coverage@q, CRPS, observed/
    # predicted length) — refreshed by the engine on every completion
    # from the scheduler's CalibrationMonitor; empty when untracked
    calibration: dict = field(default_factory=dict)

    def _failure_counters(self) -> dict:
        return {
            "aborted": self.aborted,
            "shed": self.shed,
            "retries": self.retries,
            "timeout_aborts": self.timeout_aborts,
            "wasted_tokens": self.wasted_tokens,
            "goodput_tokens": self.decode_tokens - self.wasted_tokens,
        }

    def summary(self, requests) -> dict:
        done = [r for r in requests
                if np.isfinite(getattr(r, "ttlt", np.nan))]
        if not done:
            return {"completed": 0, "calibration": self.calibration,
                    **self._failure_counters()}
        ttft = np.array([r.ttft for r in done])
        ttlt = np.array([r.ttlt for r in done])
        gen = np.array([r.generated for r in done], np.float64)
        # inter-token latency: decode-phase spacing, excluding the first
        # token (that is TTFT's job); single-token requests contribute 0
        itl = (ttlt - ttft) / np.maximum(gen - 1, 1)
        arrivals = np.array([r.arrival for r in done])
        span = float((arrivals + ttlt).max() - arrivals.min())
        return {
            "completed": len(done),
            "mean_ttft_s": float(ttft.mean()),
            "p50_ttft_s": _pct(ttft, 0.50),
            "p95_ttft_s": _pct(ttft, 0.95),
            "p99_ttft_s": _pct(ttft, 0.99),
            "mean_ttlt_s": float(ttlt.mean()),
            "mean_itl_s": float(itl.mean()),
            "p50_itl_s": _pct(itl, 0.50),
            "p95_itl_s": _pct(itl, 0.95),
            "p99_itl_s": _pct(itl, 0.99),
            "output_tokens_per_s": float(gen.sum() / max(span, 1e-9)),
            "mean_output_len": float(gen.mean()),
            "prefills": self.prefills,
            "prefill_chunks": self.prefill_chunks,
            "prefill_tokens": self.prefill_tokens,
            "prefill_tokens_reused": self.prefill_tokens_reused,
            "decode_iterations": self.decode_iterations,
            "decode_tokens": self.decode_tokens,
            "fused_steps": self.fused_steps,
            "preemptions": self.preemptions,
            "forced_evictions": self.forced_evictions,
            "grow_failures": self.grow_failures,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "modeled_swap_s": self.modeled_swap_s,
            "calibration": self.calibration,
            **self._failure_counters(),
        }
