"""Engine-level serving metrics (TTFT / TTLT / throughput accounting)."""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["EngineMetrics"]


@dataclass
class EngineMetrics:
    prefills: int = 0
    decode_iterations: int = 0
    completed: int = 0
    preemptions: int = 0

    def summary(self, requests) -> dict:
        done = [r for r in requests if np.isfinite(getattr(r, "ttlt", np.nan))]
        if not done:
            return {"completed": 0}
        return {
            "completed": len(done),
            "mean_ttft_s": float(np.mean([r.ttft for r in done])),
            "mean_ttlt_s": float(np.mean([r.ttlt for r in done])),
            "mean_output_len": float(np.mean([r.generated for r in done])),
            "prefills": self.prefills,
            "decode_iterations": self.decode_iterations,
            "preemptions": self.preemptions,
        }
