"""Overload-hardened front door for the serving engine.

``ServingEngine.submit_batch`` accepts unboundedly: under sustained
overload the waiting queue grows without limit, every request's deadline
blows, and goodput collapses even though throughput looks fine.  The
``Gateway`` puts an event-driven admission layer in front of the engine
(the design skeleton is the classic bounded-queue gateway: per-tenant
bounded queues, explicit backpressure verdicts, stale-signal fallback to
static limits, clear overload behavior):

  * **Verdicts** — every ``offer()`` returns ACCEPT (submitted to the
    engine now), QUEUE (held in the tenant's bounded queue), or SHED
    (rejected under pressure; retried with exponential backoff until
    ``max_retries``, then terminal).
  * **Bounded queues** — one FIFO per tenant, ``max_queue_per_tenant``
    deep, drained round-robin across tenants so one tenant's burst
    cannot starve the rest; a global ``max_total_queue`` bound caps the
    aggregate backlog.
  * **Deadlines** — per-request TTFT/TTLT budgets (request-level fields
    override the config defaults).  A request that misses its budget is
    aborted through ``ServingEngine.abort``, which releases every device
    block, the slot, and any host swap payload (the block-leak
    regression in tests/test_faults.py aborts in every lifecycle state);
    a queued request whose deadline already passed is shed without
    wasting engine work.
  * **Uncertainty-aware shedding** — SageSched's core asset is the
    predicted cost *distribution*; under pressure the gateway drops the
    admissions with the worst goodput-per-predicted-cost, scoring each
    request by its ``CostDistribution`` upper quantile
    (``shed_quantile``): a wide right tail makes a request expensive in
    exactly the uncertainty-adjusted sense, so it is shed first.
  * **Degraded mode** — when the predictor / history store is
    unavailable (the scheduler's ``degraded`` flag, or a failed
    route-time prediction here), shedding falls back to FCFS tail-drop
    and admission to a conservative static in-flight limit: no request
    is ranked on information the gateway no longer trusts.

Every offered request ends with a terminal disposition — FINISHED,
SHED, or ABORTED, each with a reason — recorded in ``dispositions``;
``check_invariants()`` re-asserts KV block conservation and the
no-request-silently-lost ledger (the fault-injection harness calls it
after every injected fault).  See docs/serving_engine.md, "Overload &
failure semantics".
"""

from __future__ import annotations

import enum
import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .engine import EngineStallError, ServingEngine
from .request import RequestState, ServeRequest

__all__ = ["Gateway", "GatewayConfig", "Verdict"]


class Verdict(enum.Enum):
    ACCEPT = "accept"     # submitted to the engine in this call
    QUEUE = "queue"       # held in the tenant's bounded queue
    SHED = "shed"         # rejected under pressure (retried with backoff
                          # until max_retries, then terminal)


@dataclass
class GatewayConfig:
    max_queue_per_tenant: int = 64
    max_total_queue: int = 256
    # engine-resident bound (submitted, not yet terminal); None = 4x the
    # engine's slot count — enough backlog to keep the batch full without
    # letting the engine-side queue grow unboundedly
    max_inflight: int | None = None
    # static in-flight limit while degraded; None = the engine's n_slots
    degraded_max_inflight: int | None = None
    ttft_deadline_s: float | None = None   # default; request field overrides
    ttlt_deadline_s: float | None = None
    max_retries: int = 2
    retry_backoff_s: float = 0.05          # doubles per attempt
    shed_policy: str = "cost"              # "cost" | "tail"
    shed_quantile: float = 0.9             # CostDistribution upper quantile
    # exit hysteresis for the gateway-side degraded flag: this many
    # consecutive successful predictions before leaving the static
    # degraded_max_inflight limit (one lucky call must not flap it)
    degraded_exit_successes: int = 4


@dataclass
class _Entry:
    request: ServeRequest
    score: float = 0.0           # predicted-cost quantile (cost policy)
    length_dist: object = None   # forwarded to submit_batch (predict once)
    retries: int = 0


class Gateway:
    """Bounded-admission front door over one ``ServingEngine``."""

    def __init__(self, engine: ServingEngine,
                 config: GatewayConfig | None = None,
                 clock: Callable[[], float] | None = None):
        self.engine = engine
        self.config = config or GatewayConfig()
        if self.config.shed_policy not in ("cost", "tail"):
            raise ValueError(f"bad shed_policy {self.config.shed_policy!r}")
        # share the engine's clock by default so deadline math and
        # TTFT/TTLT stamps read the same time source (tests drive both
        # with one virtual clock)
        self.clock = clock or engine.clock
        self._queues: dict[str, deque[_Entry]] = {}
        self._rr: deque[str] = deque()          # round-robin tenant order
        self._retry: list[tuple[float, int, _Entry]] = []   # heap by due
        self._retry_seq = 0
        self._inflight: dict[str, ServeRequest] = {}
        self._offered: dict[str, ServeRequest] = {}
        self.dispositions: dict[str, tuple[str, str]] = {}
        self._degraded = False   # last gateway-side prediction failed
        self._ok_streak = 0      # consecutive successes (exit hysteresis)

    # ------------------------------------------------------------- state

    @property
    def degraded(self) -> bool:
        return self._degraded or getattr(self.engine.scheduler,
                                         "degraded", False)

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def inflight(self) -> int:
        return len(self._inflight)

    @property
    def drained(self) -> bool:
        return (not self._inflight and not self._retry and self.queued == 0
                and not self.engine.has_work)

    def _max_inflight(self) -> int:
        if self.degraded:
            return (self.config.degraded_max_inflight
                    or self.engine.n_slots)
        return self.config.max_inflight or 4 * self.engine.n_slots

    # ------------------------------------------------------------ scoring

    def _score(self, r: ServeRequest) -> tuple[float, object]:
        """Predicted-cost shed score: the ``shed_quantile`` of the
        request's cost distribution (uncertainty-aware — heavy right
        tails score high and are shed first).  A predictor failure flips
        the gateway into degraded mode and scores 0 (FCFS fallback);
        leaving degraded mode requires ``degraded_exit_successes``
        consecutive clean predictions (exit hysteresis — a single lucky
        call after an outage must not flap the static limits)."""
        sched = self.engine.scheduler
        try:
            dist = sched.predictor.predict(r.prompt, r.input_len)
            cost = sched.cost_model.distribution_batch(
                [r.input_len], [dist])[0]
            self._ok_streak += 1
            if self._degraded \
                    and self._ok_streak >= self.config.degraded_exit_successes:
                self._degraded = False
            return float(cost.quantile(self.config.shed_quantile)), dist
        except Exception:
            self._degraded = True
            self._ok_streak = 0
            return 0.0, None

    # -------------------------------------------------------------- offer

    def offer(self, request: ServeRequest) -> Verdict:
        """Admission decision for one request — the B = 1 case of
        ``offer_batch``."""
        return self.offer_batch([request])[0]

    def offer_batch(self, requests: list[ServeRequest]) -> list[Verdict]:
        """One admission decision per request; accepted requests are
        coalesced into a single ``submit_batch`` call (batch-first
        ingress all the way down)."""
        entries, verdicts = [], []
        for r in requests:
            if r.request_id in self._offered:
                raise KeyError(f"request {r.request_id!r} already offered")
            self._offered[r.request_id] = r
            score, dist = (self._score(r) if self.config.shed_policy
                           == "cost" else (0.0, None))
            entries.append(_Entry(r, score=score, length_dist=dist))
        accept: list[_Entry] = []
        for e in entries:
            verdicts.append(self._place(e, accept))
        self._submit(accept)
        return verdicts

    def _place(self, e: _Entry, accept: list[_Entry]) -> Verdict:
        """Route one entry to the engine, a queue, or the shed path."""
        tenant = e.request.tenant
        q = self._queues.get(tenant)
        if (self.inflight + len(accept) < self._max_inflight()
                and self.queued == 0):
            accept.append(e)
            return Verdict.ACCEPT
        if q is None:
            q = self._queues[tenant] = deque()
            self._rr.append(tenant)
        if (len(q) < self.config.max_queue_per_tenant
                and self.queued < self.config.max_total_queue):
            q.append(e)
            return Verdict.QUEUE
        # pressure: the tenant queue (or the global backlog) is full.
        # Cost policy sheds the worst goodput-per-predicted-cost request
        # among {queued} + {incoming}; degraded / tail policy sheds the
        # incoming request (FCFS tail-drop — no ranking on predictions)
        if self.config.shed_policy == "cost" and not self.degraded and q:
            worst = max(q, key=lambda x: x.score)
            if worst.score > e.score:
                q.remove(worst)
                q.append(e)
                self._shed(worst, "displaced_by_cheaper")
                return Verdict.QUEUE
        self._shed(e, "queue_full")
        return Verdict.SHED

    # --------------------------------------------------------------- shed

    def _shed(self, e: _Entry, reason: str, retryable: bool = True) -> None:
        """Reject an entry: back into the retry heap while attempts
        remain (exponential backoff), terminal SHED after that."""
        if retryable and e.retries < self.config.max_retries:
            due = self.clock() + self.config.retry_backoff_s * (2 ** e.retries)
            e.retries += 1
            self._retry_seq += 1
            heapq.heappush(self._retry, (due, self._retry_seq, e))
            return
        r = e.request
        r.state = RequestState.SHED
        r.finish_reason = reason
        self.dispositions[r.request_id] = ("SHED", reason)
        self.engine.metrics.shed += 1

    # --------------------------------------------------------------- pump

    def _submit(self, entries: list[_Entry]) -> None:
        if not entries:
            return
        reqs = [e.request for e in entries]
        self.engine.submit_batch(
            reqs, length_dists=[e.length_dist for e in entries])
        for r in reqs:
            self._inflight[r.request_id] = r

    def _reap(self) -> None:
        """Record terminal dispositions for engine-side completions."""
        for rid in [rid for rid, r in self._inflight.items() if r.done]:
            r = self._inflight.pop(rid)
            kind = ("FINISHED" if r.state == RequestState.FINISHED
                    else "ABORTED")
            self.dispositions[rid] = (kind, r.finish_reason or kind.lower())

    def _deadline(self, r: ServeRequest, which: str) -> float | None:
        own = getattr(r, f"{which}_deadline_s")
        return own if own is not None \
            else getattr(self.config, f"{which}_deadline_s")

    def _enforce_deadlines(self, now: float) -> None:
        # engine-resident requests: abort releases blocks + swap payloads
        for rid, r in list(self._inflight.items()):
            if r.done:
                continue
            ttlt = self._deadline(r, "ttlt")
            if ttlt is not None and now - r.arrival > ttlt:
                self.engine.abort(rid, reason="ttlt_deadline")
                continue
            ttft = self._deadline(r, "ttft")
            if ttft is not None and np.isnan(r.ttft) \
                    and now - r.arrival > ttft:
                self.engine.abort(rid, reason="ttft_deadline")
        # queued requests past any deadline are shed without engine work;
        # arrival is unstamped (0.0) until submit, so measure from offer
        # only when the caller stamped it
        for tenant, q in self._queues.items():
            for e in [e for e in q
                      if self._queued_expired(e.request, now)]:
                q.remove(e)
                self._shed(e, "deadline", retryable=False)

    def _queued_expired(self, r: ServeRequest, now: float) -> bool:
        if r.arrival == 0.0:
            return False
        for which in ("ttft", "ttlt"):
            d = self._deadline(r, which)
            if d is not None and now - r.arrival > d:
                return True
        return False

    def tick(self) -> None:
        """One gateway event-loop turn: reap completions, enforce
        deadlines, replay due retries, and pump the queues into the
        engine (one coalesced ``submit_batch``)."""
        now = self.clock()
        self._reap()
        self._enforce_deadlines(now)
        self._reap()
        # due retries re-enter admission (counted as retry attempts)
        while self._retry and self._retry[0][0] <= now:
            _, _, e = heapq.heappop(self._retry)
            self.engine.metrics.retries += 1
            accept: list[_Entry] = []
            self._place(e, accept)
            self._submit(accept)
        # round-robin pump: fill the engine up to the in-flight bound
        accept = []
        bound = self._max_inflight()
        while self.inflight + len(accept) < bound and self.queued > 0:
            for _ in range(len(self._rr)):
                tenant = self._rr[0]
                self._rr.rotate(-1)
                q = self._queues.get(tenant)
                if q:
                    accept.append(q.popleft())
                    break
            else:
                break
        self._submit(accept)

    def step(self) -> int:
        """tick + one engine iteration."""
        self.tick()
        return self.engine.step() if self.engine.has_work else 0

    def run_until_drained(self, max_steps: int = 100_000,
                          step_dt: float = 0.0) -> None:
        """Drive tick+step until every offered request is terminal.
        ``step_dt`` advances a virtual clock per step (deterministic
        deadline storms); with an idle engine and pending retries the
        virtual clock jumps to the next retry's due time."""
        advance = getattr(self.clock, "advance", None)
        for _ in range(max_steps):
            if self.drained:
                return
            self.step()
            if advance is not None:
                if step_dt:
                    advance(step_dt)
                elif not self.engine.has_work and self._retry:
                    advance(max(0.0, self._retry[0][0] - self.clock()))
        raise EngineStallError(
            f"gateway: drain budget ({max_steps}) exhausted — "
            f"queued={self.queued} retrying={len(self._retry)} "
            f"inflight={self.inflight}; engine={self.engine.stall_report()}")

    # ------------------------------------------------------------- summary

    def summary(self) -> dict:
        """Operator-facing gateway snapshot: live admission state, the
        disposition ledger rolled up by (kind, reason), and the adaptive-
        robustness surfaces — per-tenant calibration statistics from the
        scheduler's ``CalibrationMonitor`` and the hedge-weight snapshot
        when the engine schedules with ``HedgedPolicy``."""
        kinds: dict[str, int] = {}
        reasons: dict[str, int] = {}
        for kind, reason in self.dispositions.values():
            kinds[kind] = kinds.get(kind, 0) + 1
            key = f"{kind.lower()}:{reason}"
            reasons[key] = reasons.get(key, 0) + 1
        out = {
            "queued": self.queued,
            "inflight": self.inflight,
            "retrying": len(self._retry),
            "degraded": self.degraded,
            "dispositions": kinds,
            "disposition_reasons": reasons,
        }
        sched = self.engine.scheduler
        if hasattr(sched, "calibration_summary"):
            out["calibration"] = sched.calibration_summary()
        pol = getattr(sched, "policy", None)
        if hasattr(pol, "snapshot"):
            out["hedge"] = pol.snapshot()
        return out

    # ---------------------------------------------------------- invariants

    def check_invariants(self) -> None:
        """Fault-harness postconditions: KV block/slot conservation and
        the no-request-silently-lost ledger (every offered id is either
        still live — queued, retrying, in flight — or has a terminal
        disposition with a reason)."""
        self.engine.kv.assert_conserved()
        live = set(self._inflight) | {
            e.request.request_id
            for q in self._queues.values() for e in q}
        live |= {e.request.request_id for _, _, e in self._retry}
        for rid in self._offered:
            if rid in self.dispositions:
                kind, reason = self.dispositions[rid]
                if kind not in ("FINISHED", "SHED", "ABORTED") or not reason:
                    raise RuntimeError(
                        f"{rid}: bad disposition {kind!r}/{reason!r}")
            elif rid not in live:
                raise RuntimeError(f"request {rid} silently lost")

    def assert_all_terminal(self) -> None:
        """Post-drain: every offered id has a terminal disposition."""
        self.check_invariants()
        missing = [rid for rid in self._offered
                   if rid not in self.dispositions]
        if missing:
            raise RuntimeError(
                f"{len(missing)} requests lack terminal dispositions: "
                f"{missing[:5]}")
