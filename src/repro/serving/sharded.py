"""Mesh-parallel serving execution: the sharded fused decode plan.

``ShardingPlan`` turns the (previously dry-run-only) partitioning rules
in ``repro.sharding.partitioning`` into a *serving* execution layout for
one engine:

  * a sharded paged KV pool — the (L, n_pages, page, KV, dh) tensors
    split over the kv-head dim, so every physical page is striped
    across shards while the page *grid* (and the host-side block tables
    in ``KVCacheManager``, which stay fully authoritative) is
    shard-invariant.  Swap payloads gather/scatter per-shard slices
    transparently: a payload is the full-head numpy array, so swap-mode
    preemption, CoW prefix sharing, and cluster migration are untouched.
    The attention einsums inherit the pool's sharding and parallelize
    over the kv-head batch dim — the decode bottleneck (pool bandwidth)
    scales with the mesh;
  * expert-parallel MoE — the (E, C, D) capacity buffer and per-expert
    weights shard over 'model'; the router and the K-way weighted
    combine stay replicated;
  * replicated projections — wq/wk/wv, wo, mlp, lm_head/embed run
    full-shape on every shard.

The plan is deliberately *exact*: only batch-like einsum dims are
sharded, so no floating-point contraction crosses a shard boundary and
every per-slice GEMM keeps the exact shape it has in the unsharded
program (see ``repro.sharding.partitioning.decode_rules`` for why
column-/row-parallel projections forfeit bit-identity).  This makes the
sharded engine bit-identical to the single-device one — the parity
suite asserts token-identical streams, not tolerances.  Components
whose dimensions don't divide the mesh axis fall back to replicated
(correct, just not parallel) and are reported by ``describe()``.

Execution model: jit + ``NamedSharding`` (GSPMD), not a hand-written
``shard_map`` — the engine's host loop, global logical shapes, pow2
bucket ladders, and buffer donation all carry over unchanged; the plan
only (a) places params and pool once, (b) installs trace-scoped hooks
(``gather_model`` / ``constrain_expert_buf``) around the engine's jit
call sites, and (c) pins cache-typed jit outputs back to the pool
layout so donation round-trips shard-stable.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding.context import serving_sharding
from ..sharding.partitioning import (decode_rules, named_shardings,
                                     paged_kv_pool_spec, resolve_specs)

__all__ = ["ShardingPlan"]


@dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    tp: int
    rules: dict
    report: dict
    param_shardings: Any          # pytree of NamedSharding
    kv_pool: NamedSharding        # (L, n_pages, page, KV, dh) layout
    replicated: NamedSharding
    expert_buf: NamedSharding | None

    @classmethod
    def build(cls, model, mesh: Mesh) -> "ShardingPlan":
        """Resolve the exact serving-decode rules for ``model`` on
        ``mesh`` (raises if any non-'model' axis is bigger than 1)."""
        rules, report = decode_rules(model.cfg, mesh)
        specs = resolve_specs(model.param_specs(), rules)
        return cls(
            mesh=mesh,
            tp=int(mesh.shape["model"]),
            rules=rules,
            report=report,
            param_shardings=named_shardings(mesh, specs),
            kv_pool=NamedSharding(mesh, paged_kv_pool_spec(rules)),
            replicated=NamedSharding(mesh, P()),
            expert_buf=(NamedSharding(mesh, P("model", None, None))
                        if rules.get("expert") else None),
        )

    # ------------------------------------------------------------ placement

    def place_params(self, params):
        return jax.device_put(params, self.param_shardings)

    def place_cache(self, cache: dict) -> dict:
        """Commit a paged-cache dict to the plan layout.  Also used to
        re-pin the pool after eager host-side updates (swap restore)
        whose sharding propagation is XLA's choice, not ours — a no-op
        copy when the layout already matches."""
        out = {}
        for key, val in cache.items():
            if key in ("k", "v"):
                out[key] = jax.device_put(val, self.kv_pool)
            else:
                out[key] = jax.tree.map(
                    lambda a: jax.device_put(a, self.replicated), val)
        return out

    # ------------------------------------------------- trace-time constraints

    def gather(self, x):
        """The ``gather_model`` hook body: all-gather the model-sharded
        axis back to replicated (pure relayout, exact)."""
        return jax.lax.with_sharding_constraint(x, self.replicated)

    def constrain_kv(self, x):
        """Pin a rank-5 (..., KV, dh) KV tensor — pool, prefill cache,
        chunk output, or gathered prefix — to the kv-head sharding."""
        return jax.lax.with_sharding_constraint(x, self.kv_pool)

    def constrain_cache(self, cache: dict) -> dict:
        """Pin a cache dict's outputs inside a traced function: k/v to
        the pool layout, recurrent state replicated.  Keeps the donated
        fused-step round-trip shard-stable (input sharding == output
        sharding is what lets XLA alias the donated pool buffers)."""
        out = {}
        for key, val in cache.items():
            if key in ("k", "v"):
                out[key] = self.constrain_kv(val)
            else:
                out[key] = jax.tree.map(
                    lambda a: jax.lax.with_sharding_constraint(
                        a, self.replicated), val)
        return out

    def context(self):
        """Trace-scoped hook installation (see sharding.context): only
        the engine's own jit calls see the constraints, so unsharded
        engines in the same process are unaffected."""
        return serving_sharding(self.gather, self.expert_buf)

    def wrap_jit(self, fn, **jit_kwargs):
        """jax.jit that traces under ``context()``.  Forwards the
        private compile counter and ``lower`` so the engine's
        compile-bound checks and the roofline bench's HLO dump work
        identically on the wrapped function."""
        jitted = jax.jit(fn, **jit_kwargs)
        plan = self

        @functools.wraps(fn)
        def call(*args, **kwargs):
            with plan.context():
                return jitted(*args, **kwargs)

        def lower(*args, **kwargs):
            # lowering must trace under the same hooks as execution or
            # the dumped HLO loses the sharding constraints (and with
            # them the collectives the roofline bench prices)
            with plan.context():
                return jitted.lower(*args, **kwargs)

        call._cache_size = getattr(jitted, "_cache_size", None)
        call.lower = lower
        return call

    # -------------------------------------------------------------- reporting

    def describe(self) -> dict:
        """What actually sharded (per component) on this mesh — the
        divisibility fallbacks make this the source of truth, not the
        requested tp."""
        n_dev = 1
        for a in self.mesh.axis_names:
            n_dev *= int(self.mesh.shape[a])
        return {"devices": n_dev, "tp": self.tp, **self.report}
