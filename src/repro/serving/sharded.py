"""Mesh-parallel serving execution: the sharded fused decode plan.

``ShardingPlan`` turns the (previously dry-run-only) partitioning rules
in ``repro.sharding.partitioning`` into a *serving* execution layout for
one engine:

  * a sharded paged KV pool — the (L, n_pages, page, KV, dh) tensors
    split over the kv-head dim, so every physical page is striped
    across shards while the page *grid* (and the host-side block tables
    in ``KVCacheManager``, which stay fully authoritative) is
    shard-invariant.  Swap payloads gather/scatter per-shard slices
    transparently: a payload is the full-head numpy array, so swap-mode
    preemption, CoW prefix sharing, and cluster migration are untouched.
    The attention einsums inherit the pool's sharding and parallelize
    over the kv-head batch dim — the decode bottleneck (pool bandwidth)
    scales with the mesh;
  * expert-parallel MoE — the (E, C, D) capacity buffer and per-expert
    weights shard over 'model'; the router and the K-way weighted
    combine stay replicated;
  * replicated projections — wq/wk/wv, wo, mlp, lm_head/embed run
    full-shape on every shard.

The default plan (``parallel="exact"``) is deliberately *exact*: only
batch-like einsum dims are sharded, so no floating-point contraction
crosses a shard boundary and every per-slice GEMM keeps the exact shape
it has in the unsharded program (see
``repro.sharding.partitioning.decode_rule_table`` for why
column-/row-parallel projections forfeit bit-identity).  This makes the
sharded engine bit-identical to the single-device one — the parity
suite asserts token-identical streams, not tolerances.  Components
whose dimensions don't divide the mesh axis fall back to replicated
(correct, just not parallel) and are reported by ``describe()``.

``parallel="efficient"`` flips the Megatron axes on: column-parallel
wq/wk/wv and MLP up/gate, row-parallel wo/down (one psum per attention
block and one per MLP), vocab-sharded lm_head with a partitioned
argmax/categorical, and kv-head-striped paged attention (or, when the
heads don't divide, an explicit log-sum-exp split of the logical page
axis).  Remarkably little model code changes: the plan's ``gather``
hook becomes the identity and the weight rules flip, and GSPMD derives
the whole dataflow from sharding propagation.  Per-token FLOPs shrink
~tp-fold; bit-identity is replaced by the tolerance contract
(``repro.testing.assert_tokens_close``, docs/sharded_serving.md) —
bit-identical at tp=1, greedy-token match >= 0.999 at tp>1.

Execution model: jit + ``NamedSharding`` (GSPMD), not a hand-written
``shard_map`` — the engine's host loop, global logical shapes, pow2
bucket ladders, and buffer donation all carry over unchanged; the plan
only (a) places params and pool once, (b) installs trace-scoped hooks
(``gather_model`` / ``constrain_expert_buf``) around the engine's jit
call sites, and (c) pins cache-typed jit outputs back to the pool
layout so donation round-trips shard-stable.
"""

from __future__ import annotations

import functools
import warnings as _warnings
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels.bucketing import pow2_bucket
from ..sharding.context import serving_sharding
from ..sharding.partitioning import (decode_rule_table, decode_rules,
                                     named_shardings, paged_kv_pool_spec,
                                     resolve_specs, shard_bytes_table)

__all__ = ["ShardingPlan", "estimate_device_bytes",
           "REPLICATION_WARN_BYTES"]

# sharding_report() warns when a weight at least this big silently hit
# the replication fallback (its logical axis didn't divide the mesh) —
# below this, replication is noise; above it, it's the difference
# between fitting and OOM.
REPLICATION_WARN_BYTES = 32 << 20


@dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    tp: int
    parallel: str                 # "exact" | "efficient"
    rules: dict
    report: dict
    param_shardings: Any          # pytree of NamedSharding
    kv_pool: NamedSharding        # (L, n_pages, page, KV, dh) layout
    replicated: NamedSharding
    expert_buf: NamedSharding | None
    q_heads: NamedSharding | None   # (B, S, H, dh) column-parallel q
    kv_heads: NamedSharding | None  # (B, S, KV, dh) column-parallel k/v
    attn_splits: int                # LSE page-splits (1 = no split)
    split_spec: NamedSharding | None
    tensor_rows: tuple            # per-tensor byte/spec accounting rows
    warnings: tuple               # big-weight replication-fallback notes

    @classmethod
    def build(cls, model, mesh: Mesh,
              parallel: str = "exact") -> "ShardingPlan":
        """Resolve the serving-decode rules for ``model`` on ``mesh``
        (raises if any non-'model' axis is bigger than 1).

        ``parallel="exact"`` (default) is the bit-identical plan from
        PR 8; ``parallel="efficient"`` flips the Megatron axes on —
        column/row-parallel projections, vocab-sharded lm_head,
        kv-head-striped attention (or the LSE page-split fallback) —
        trading bit-identity for per-token FLOPs that shrink ~tp-fold
        (tolerance contract: docs/sharded_serving.md)."""
        rules, report = decode_rules(model.cfg, mesh, parallel=parallel)
        specs = resolve_specs(model.param_specs(), rules)
        tp = int(mesh.shape["model"])
        rows = tuple(shard_bytes_table(model.template(), rules, tp,
                                       fallbacks=report["fallbacks"]))
        warns = tuple(
            f"{r['name']} ({r['bytes'] / 2**20:.0f} MiB, axes {r['axes']}) "
            "hit the replication fallback — its sharding axis does not "
            f"divide tp={tp}; every device holds a full copy"
            for r in rows
            if r["fallback"] and r["bytes"] >= REPLICATION_WARN_BYTES)
        for w in warns:
            _warnings.warn(w, RuntimeWarning, stacklevel=3)
        efficient = parallel == "efficient"
        heads_sharded = rules.get("heads") is not None
        attn_splits = int(report.get("attn_splits", 1))
        return cls(
            mesh=mesh,
            tp=tp,
            parallel=parallel,
            rules=rules,
            report=report,
            param_shardings=named_shardings(mesh, specs),
            kv_pool=NamedSharding(mesh, paged_kv_pool_spec(rules)),
            replicated=NamedSharding(mesh, P()),
            expert_buf=(NamedSharding(mesh, P("model", None, None))
                        if rules.get("expert") else None),
            q_heads=(NamedSharding(mesh, P(None, None, "model", None))
                     if efficient and heads_sharded else None),
            kv_heads=(NamedSharding(mesh, P(None, None, "model", None))
                      if efficient and heads_sharded else None),
            attn_splits=attn_splits if efficient else 1,
            split_spec=(NamedSharding(mesh, P(None, "model", None))
                        if efficient and attn_splits > 1 else None),
            tensor_rows=rows,
            warnings=warns,
        )

    # ------------------------------------------------------------ placement

    def place_params(self, params):
        return jax.device_put(params, self.param_shardings)

    def place_cache(self, cache: dict) -> dict:
        """Commit a paged-cache dict to the plan layout.  Also used to
        re-pin the pool after eager host-side updates (swap restore)
        whose sharding propagation is XLA's choice, not ours — a no-op
        copy when the layout already matches."""
        out = {}
        for key, val in cache.items():
            if key in ("k", "v"):
                out[key] = jax.device_put(val, self.kv_pool)
            else:
                out[key] = jax.tree.map(
                    lambda a: jax.device_put(a, self.replicated), val)
        return out

    # ------------------------------------------------- trace-time constraints

    def gather(self, x):
        """The ``gather_model`` hook body.  Exact mode: all-gather the
        model-sharded axis back to replicated (pure relayout, exact).
        Efficient mode: IDENTITY — leaving the hook's call sites
        unconstrained is precisely what lets GSPMD emit the Megatron
        dataflow through the unchanged model code: ``_wo_proj``'s
        post-hook ``.sum(axis=2)`` over the group-sharded partials
        becomes the row-parallel psum, ``_pin_qkv`` leaves q/k/v
        head-sharded off the column-parallel projections, the final
        logits stay vocab-sharded into a partitioned argmax/categorical
        (only the winning token crosses shards), and the MoE
        capacity-buffer pick becomes a cross-shard gather."""
        if self.parallel == "efficient":
            return x
        return jax.lax.with_sharding_constraint(x, self.replicated)

    def constrain_kv(self, x):
        """Pin a rank-5 (..., KV, dh) KV tensor — pool, prefill cache,
        chunk output, or gathered prefix — to the kv-head sharding."""
        return jax.lax.with_sharding_constraint(x, self.kv_pool)

    def constrain_cache(self, cache: dict) -> dict:
        """Pin a cache dict's outputs inside a traced function: k/v to
        the pool layout, recurrent state replicated.  Keeps the donated
        fused-step round-trip shard-stable (input sharding == output
        sharding is what lets XLA alias the donated pool buffers)."""
        out = {}
        for key, val in cache.items():
            if key in ("k", "v"):
                out[key] = self.constrain_kv(val)
            else:
                out[key] = jax.tree.map(
                    lambda a: jax.lax.with_sharding_constraint(
                        a, self.replicated), val)
        return out

    def context(self):
        """Trace-scoped hook installation (see sharding.context): only
        the engine's own jit calls see the constraints, so unsharded
        engines in the same process are unaffected."""
        return serving_sharding(self.gather, self.expert_buf,
                                q_heads_spec=self.q_heads,
                                kv_heads_spec=self.kv_heads,
                                attn_splits=self.attn_splits,
                                split_spec=self.split_spec)

    def wrap_jit(self, fn, **jit_kwargs):
        """jax.jit that traces under ``context()``.  Forwards the
        private compile counter and ``lower`` so the engine's
        compile-bound checks and the roofline bench's HLO dump work
        identically on the wrapped function."""
        jitted = jax.jit(fn, **jit_kwargs)
        plan = self

        @functools.wraps(fn)
        def call(*args, **kwargs):
            with plan.context():
                return jitted(*args, **kwargs)

        def lower(*args, **kwargs):
            # lowering must trace under the same hooks as execution or
            # the dumped HLO loses the sharding constraints (and with
            # them the collectives the roofline bench prices)
            with plan.context():
                return jitted.lower(*args, **kwargs)

        call._cache_size = getattr(jitted, "_cache_size", None)
        call.lower = lower
        return call

    # -------------------------------------------------------------- reporting

    def describe(self) -> dict:
        """What actually sharded (per component) on this mesh — the
        divisibility fallbacks make this the source of truth, not the
        requested tp.  Includes the per-tensor byte/spec rows, the
        ``replicated_bytes`` total (what every device pays again), and
        any big-weight replication-fallback warnings."""
        n_dev = 1
        for a in self.mesh.axis_names:
            n_dev *= int(self.mesh.shape[a])
        rows = [dict(r) for r in self.tensor_rows]
        return {
            "devices": n_dev, "tp": self.tp, **self.report,
            "tensors": rows,
            "param_bytes": sum(r["bytes"] for r in rows),
            "param_bytes_per_device":
                sum(r["bytes_per_device"] for r in rows),
            "replicated_bytes":
                sum(r["bytes"] for r in rows if not r["sharded"]),
            "warnings": list(self.warnings),
        }


# ------------------------------------------------------ memory preflight

def _struct_bytes(s) -> int:
    return int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize


def estimate_device_bytes(model, *, tp: int, parallel: str = "exact",
                          n_pages: int, page_size: int,
                          n_slots: int) -> dict:
    """Per-device byte budget for serving ``model`` at width ``tp``:
    weights shard + paged-KV-pool shard + fused-step workspace.

    Pure arithmetic over the parameter template and the mesh-free rule
    table (``decode_rule_table``) — no mesh, no device allocation — so
    the engine preflight prices the layout *before* touching HBM and
    the dry-run min-tp report sweeps tp ladders over 300B-param configs
    instantly.

    The workspace term is a deliberate over-estimate of the fused
    step's dominant transients: two f32 logits-sized buffers (the
    lm_head output + the categorical's scaled copy) at the largest
    batch bucket, plus one f32 MLP hidden buffer — each divided by tp
    when its producing GEMM is sharded.
    """
    cfg = model.cfg
    rules, report = decode_rule_table(cfg, tp, parallel=parallel)
    rows = shard_bytes_table(model.template(), rules, tp,
                             fallbacks=report["fallbacks"])
    weights = sum(r["bytes_per_device"] for r in rows)

    pool_div = tp if rules.get("pool_kv") else 1
    kv_pool = 0
    cache_shapes = model.paged_cache_shapes(n_pages, page_size, n_slots)
    for key, val in cache_shapes.items():
        leaves = jax.tree.leaves(val)
        nbytes = sum(_struct_bytes(s) for s in leaves)
        kv_pool += nbytes // pool_div if key in ("k", "v") else nbytes

    # largest fused batch bucket the engine can trace (floor 8, capped
    # at n_slots — mirrors _decode_fused's pow2 ladder)
    nb = pow2_bucket(n_slots, floor=8, cap=max(n_slots, 1))
    vocab_div = tp if rules.get("vocab") else 1
    mlp_div = tp if rules.get("mlp") else 1
    workspace = 2 * nb * cfg.padded_vocab * 4 // vocab_div \
        + nb * max(cfg.d_ff // mlp_div, cfg.d_model) * 4
    return {
        "tp": tp,
        "parallel": parallel,
        "weights_bytes": int(weights),
        "kv_pool_bytes": int(kv_pool),
        "workspace_bytes": int(workspace),
        "total_bytes": int(weights + kv_pool + workspace),
        "replicated_bytes": int(sum(r["bytes"] for r in rows
                                    if not r["sharded"])),
        "report": report,
    }
