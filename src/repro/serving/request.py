"""Request lifecycle for the real serving engine."""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

__all__ = ["RequestState", "ServeRequest"]


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    SWAPPED = "swapped"
    FINISHED = "finished"
    ABORTED = "aborted"


@dataclass
class ServeRequest:
    request_id: str
    prompt: str
    prompt_tokens: list[int]
    max_new_tokens: int = 512
    eos_token: int = 0
    temperature: float = 0.6          # the paper's default sampling temp
    arrival: float = 0.0

    state: RequestState = RequestState.WAITING
    output_tokens: list[int] = field(default_factory=list)
    slot: int = -1                    # engine batch slot while RUNNING
    prefill_pos: int = 0              # context tokens whose KV is resident
    ttft: float = float("nan")
    ttlt: float = float("nan")
    n_preemptions: int = 0
    n_swap_restores: int = 0          # readmissions that skipped re-prefill

    @property
    def input_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def generated(self) -> int:
        return len(self.output_tokens)

    @property
    def context_len(self) -> int:
        return self.input_len + self.generated

    @property
    def done(self) -> bool:
        return self.state in (RequestState.FINISHED, RequestState.ABORTED)
