"""Request lifecycle for the real serving engine."""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

__all__ = ["RequestState", "ServeRequest"]


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    SWAPPED = "swapped"
    FINISHED = "finished"
    ABORTED = "aborted"
    SHED = "shed"          # rejected by gateway load-shedding (terminal)


@dataclass
class ServeRequest:
    request_id: str
    prompt: str
    prompt_tokens: list[int]
    max_new_tokens: int = 512
    eos_token: int = 0
    temperature: float = 0.6          # the paper's default sampling temp
    arrival: float = 0.0

    # SLO deadlines (seconds from arrival); None defers to the gateway's
    # configured defaults.  The bare engine never enforces them — deadline
    # aborts are the gateway's job, so engine-only users see no change.
    ttft_deadline_s: float | None = None
    ttlt_deadline_s: float | None = None
    tenant: str = "default"           # gateway per-tenant queue key
    session_id: str = ""              # multi-turn chain key ("" = one-shot);
                                      # turns of one session share a growing
                                      # prompt prefix the engine's prefix
                                      # index can adopt instead of re-
                                      # prefilling

    state: RequestState = RequestState.WAITING
    output_tokens: list[int] = field(default_factory=list)
    slot: int = -1                    # engine batch slot while RUNNING
    prefill_pos: int = 0              # context tokens whose KV is resident
    ttft: float = float("nan")
    ttlt: float = float("nan")
    n_preemptions: int = 0
    n_swap_restores: int = 0          # readmissions that skipped re-prefill
    finish_reason: str = ""           # why the request reached its terminal
                                      # state ("eos", "length", "truncated",
                                      # "infeasible_prompt", deadline/shed
                                      # reasons, or a caller-supplied one)

    @property
    def input_len(self) -> int:
        return len(self.prompt_tokens)

    @property
    def generated(self) -> int:
        return len(self.output_tokens)

    @property
    def context_len(self) -> int:
        return self.input_len + self.generated

    @property
    def done(self) -> bool:
        return self.state in (RequestState.FINISHED, RequestState.ABORTED,
                              RequestState.SHED)
