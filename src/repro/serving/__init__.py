"""Real serving substrate: engine, gateway, KV manager, requests, metrics."""

from .engine import EngineStallError, ServingEngine
from .gateway import Gateway, GatewayConfig, Verdict
from .kv_cache import KVCacheManager
from .metrics import EngineMetrics
from .request import RequestState, ServeRequest
from .sharded import ShardingPlan

__all__ = ["ServingEngine", "EngineStallError", "Gateway", "GatewayConfig",
           "Verdict", "KVCacheManager", "EngineMetrics", "RequestState",
           "ServeRequest", "ShardingPlan"]
