"""Real serving substrate: engine, KV manager, requests, metrics."""

from .engine import ServingEngine
from .kv_cache import KVCacheManager
from .metrics import EngineMetrics
from .request import RequestState, ServeRequest

__all__ = ["ServingEngine", "KVCacheManager", "EngineMetrics",
           "RequestState", "ServeRequest"]
