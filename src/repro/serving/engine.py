"""Continuous-batching serving engine driving a real JAX model.

vLLM-style iteration loop, scheduled by repro.core.Scheduler (SageSched or
any baseline policy).  The execution layer is *memory-hybrid* (the
paper's second axis): KV residency, capacity-forced eviction and swap IO
are first-class, shared with the discrete-event simulator.

    submit() -> scheduler.admit (predict + cost + Gittins)
    each step() builds an iteration plan:
        1. select the running set: scheduler priority order under the
           KVCacheManager *block* budget (one authoritative accessor,
           shared with can_admit) + slot limit, with hysteresis against
           priority thrashing (Sec. 3.3);
        2. preempt displaced requests — swap mode gathers their KV blocks
           to the host pool (modeled cost: ServiceModel.swap_time over
           block-aligned tokens, the SAME function the simulator
           charges); recompute mode drops them;
        3. admit newcomers: swapped requests are restored by scattering
           their saved blocks back (NO re-prefill); fresh/recompute
           requests prefill — Sarathi-style chunks mixed with the decode
           batch under one token budget (``max_tokens_per_step``);
        4. relieve capacity pressure: decode growth that found no free
           block (grow() -> False) forces eviction, victims picked by
           ``Scheduler.eviction_order`` — priority *plus* the memory
           term (held KV ~ predicted swap cost);
        5. one decode iteration over all decode-ready slots through the
           paged pool (block-table indirection);
        6. ONE vectorized sampling pass over all slots (argmax /
           inverse-CDF categorical), completions fed back to the
           scheduler's history window.

In the default ``step_mode="fused"``, stages 5-6 plus per-lane
EOS/length bookkeeping are ONE jitted, buffer-donated device call: a
``lax.fori_loop`` decodes up to ``decode_steps`` tokens per host
round-trip with on-device sampling, and the host gets back a single
(tokens, emitted, finished) transfer.  Traced shapes ride pow2 bucket
ladders (active lanes, table width, prefill padding) so batch churn
never grows the compile set past ``max_fused_compiles()``.
``step_mode="orchestrated"`` keeps the per-step host loop as the parity
oracle and benchmark baseline.

KV memory is a paged pool: (L, n_pages, page, KV, dh) tensors shared by
the batch, a per-slot block table mapping logical positions to physical
pages (page 0 = scratch, where masked lanes write), and a host swap pool
holding preempted requests' KV.  See docs/serving_engine.md.

The engine is single-host (the real CpuDevice here; a TPU slice in
production — the jitted step functions are the same ones the dry-run
lowers for the production mesh).
"""

from __future__ import annotations

import functools
import math
import time
import zlib
from collections import Counter
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.scheduler import Scheduler
from ..kernels.bucketing import ladder_size as _ladder_size
from ..kernels.bucketing import pow2_bucket as _pow2_bucket
from ..models import Model
from ..simulator.service_model import ServiceModel
from .kv_cache import SCRATCH_BLOCK, KVCacheManager
from .metrics import EngineMetrics
from .request import RequestState, ServeRequest

__all__ = ["ServingEngine", "EngineStallError"]


class EngineStallError(RuntimeError):
    """``run_until_done`` exhausted its step budget with work still live.

    The message carries the full stall diagnosis (per-state request
    counts, queue depth, block-pool occupancy, pressure set) so a
    livelock — e.g. an injected fault that wedged admission — fails
    loudly instead of timing out silently."""


def _pad_len(n: int, quantum: int = 64) -> int:
    """pow2 bucket with a floor — prefill chunk/prefix padding ladder."""
    return _pow2_bucket(n, floor=quantum)


def _rid_seed(request_id: str) -> int:
    """Stable per-request RNG seed: sampling draws depend on (request,
    position), never on slot assignment or preemption history, so swap
    and recompute schedules sample identical streams."""
    return zlib.crc32(request_id.encode())


@dataclass
class ServingEngine:
    model: Model
    scheduler: Scheduler
    n_slots: int = 8
    max_seq_len: int = 512
    capacity_tokens: int | None = None
    preemption_hysteresis: float = 0.5
    seed: int = 0
    params: dict | None = None
    block_size: int = 16                   # KV page size, tokens
    preemption_mode: str = "swap"          # "swap" | "recompute"
    prefill_chunk: int | None = None       # tokens per chunk; None = atomic
    max_tokens_per_step: int | None = None  # mixed prefill+decode budget
    memory_weight: float = 0.5             # eviction memory term (0 = off)
    swap_capacity_tokens: int | None = None
    service_model: ServiceModel | None = None
    step_mode: str = "fused"               # "fused" | "orchestrated"
    decode_steps: int = 1                  # decode tokens per host round-trip
    # Copy-on-write prefix sharing: admission matches an incoming
    # prompt's longest indexed block-chain prefix and adopts those KV
    # pages by refcount instead of re-prefilling them (chunked prefill
    # resumes at the divergence point).  Requires chunked prefill
    # (prefill_chunk set + a family that supports it) — silently inert
    # otherwise, so enabling it on an SSM family changes nothing.
    prefix_sharing: bool = False
    # Injectable time source (TTFT/TTLT stamps, arrival defaults).  The
    # gateway's deadline enforcement shares this clock, so tests and
    # benchmarks drive deadline storms deterministically with a virtual
    # clock instead of racing wall time.
    clock: Callable[[], float] = time.monotonic
    # Mesh-parallel execution (repro.serving.sharded).  Pass a Mesh
    # whose 'model' axis is the tensor/expert-parallel width, or just
    # ``tp=N`` to build a local host-device mesh.  The default
    # (mesh=None, tp=1) is the plain single-device path, unchanged.
    # Sharded output is bit-identical to unsharded (exact decomposition
    # — docs/sharded_serving.md), so every parity/selection invariant
    # holds under the mesh too.
    mesh: object | None = None
    tp: int = 1
    # "exact" (default) shards only what preserves bit-identity (KV pool
    # + expert buffers); "efficient" flips the Megatron weight axes on
    # too (column-parallel qkv/up/gate, row-parallel wo/down, vocab-
    # sharded lm_head, LSE-split attention when heads don't divide) and
    # trades bit-identity for a tolerance contract
    # (testing.assert_tokens_close; docs/sharded_serving.md).
    parallel: str = "exact"
    # Per-device HBM budget for the admission-time memory preflight:
    # when set, __post_init__ refuses to build an engine whose per-shard
    # weights + KV pool + fused-step workspace exceed it, *before* any
    # device allocation happens.  None skips the check.
    device_memory_gb: float | None = None

    _requests: dict[str, ServeRequest] = field(default_factory=dict)
    _running: list[str] = field(default_factory=list)

    def __post_init__(self):
        if self.preemption_mode not in ("swap", "recompute"):
            raise ValueError(f"bad preemption_mode {self.preemption_mode!r}")
        if self.step_mode not in ("fused", "orchestrated"):
            raise ValueError(f"bad step_mode {self.step_mode!r}")
        if self.decode_steps < 1:
            raise ValueError("decode_steps must be >= 1")
        if not self.model.supports_paged:
            raise ValueError(
                f"{self.model.cfg.family} models are not servable through "
                "the paged engine")
        if self.tp < 1:
            raise ValueError(f"tp must be >= 1, got {self.tp}")
        if self.parallel not in ("exact", "efficient"):
            raise ValueError(
                f"bad parallel {self.parallel!r}: expected 'exact' or "
                "'efficient'")
        self.plan = None
        if self.mesh is None and self.tp > 1:
            from ..launch.mesh import make_local_mesh
            self.mesh = make_local_mesh(tp=self.tp)
        if self.mesh is not None:
            from .sharded import ShardingPlan
            self.plan = ShardingPlan.build(self.model, self.mesh,
                                           parallel=self.parallel)
            if self.tp > 1 and self.tp != self.plan.tp:
                raise ValueError(
                    f"tp={self.tp} contradicts mesh model axis "
                    f"{self.plan.tp}")
            self.tp = self.plan.tp
        # KVCacheManager is pure host bookkeeping — built before the
        # memory preflight so pool_blocks feeds the per-shard estimate
        # without having allocated anything on device yet.
        self.kv = KVCacheManager(
            self.n_slots, self.max_seq_len, self.capacity_tokens,
            block_size=self.block_size,
            swap_capacity_tokens=self.swap_capacity_tokens)
        self._preflight_memory()
        if self.params is None:
            self.params = self.model.init(jax.random.PRNGKey(self.seed))
        if self.plan is not None:
            self.params = self.plan.place_params(self.params)
        if self.service_model is None:
            self.service_model = ServiceModel()
        self.metrics = EngineMetrics()
        self._rng = np.random.default_rng(self.seed)
        self._cache = self.model.init_paged_cache(
            self.kv.pool_blocks, self.block_size, self.n_slots)
        if self.plan is not None:
            # pool pages live per-shard from here on (split over the
            # kv-head dim); the host-side block tables below stay
            # authoritative and shard-agnostic
            self._cache = self.plan.place_cache(self._cache)
        self._has_kv = "k" in self._cache
        self._max_pages = -(-self.max_seq_len // self.block_size)
        self._block_tables = np.full((self.n_slots, self._max_pages),
                                     SCRATCH_BLOCK, np.int32)
        # cache_len < 0 marks a slot that is not decode-ready (free, or
        # still prefilling); the decode step masks it to 0
        self._cache_len = np.full(self.n_slots, -1, np.int64)
        self._last_token = np.zeros(self.n_slots, np.int64)
        self._slot_rid: dict[int, str] = {}
        self._needs_grow: set[str] = set()
        page = self.block_size
        # plan-aware jit: on a mesh, traces run under the plan's hook
        # context and cache-typed outputs are pinned back to the pool
        # layout (cc / ckv below), which keeps the donated round-trips
        # shard-stable; on the default path all three are identity/jax.jit
        jit = jax.jit if self.plan is None else self.plan.wrap_jit
        cc = (lambda c: c) if self.plan is None else self.plan.constrain_cache
        ckv = (lambda x: x) if self.plan is None else self.plan.constrain_kv

        def decode_step(p, t, c, cl, bt):
            logits, c2 = self.model.decode_step_paged(p, t, c, cl, bt,
                                                      page_size=page)
            return logits, cc(c2)

        def prefill(p, b):
            logits, c2 = self.model.prefill(p, b)
            return logits, cc(c2)

        def chunk(p, t, pk, pv, s):
            k_c, v_c = self.model.prefill_chunk(p, t, pk, pv, s)
            return ckv(k_c), ckv(v_c)

        self._decode_fn = jit(decode_step, donate_argnums=(2,))
        self._prefill_fn = jit(prefill)
        self._chunk_fn = jit(chunk)

        @functools.partial(jit, donate_argnums=(0, 1))
        def scatter(pk, pv, ks, vs, idx):
            fk = pk.reshape((pk.shape[0], -1) + pk.shape[3:])
            fv = pv.reshape((pv.shape[0], -1) + pv.shape[3:])
            fk = fk.at[:, idx].set(ks[:, 0].astype(fk.dtype))
            fv = fv.at[:, idx].set(vs[:, 0].astype(fv.dtype))
            return ckv(fk.reshape(pk.shape)), ckv(fv.reshape(pv.shape))

        @jit
        def gather(pk, pv, idx):
            fk = pk.reshape((pk.shape[0], -1) + pk.shape[3:])
            fv = pv.reshape((pv.shape[0], -1) + pv.shape[3:])
            return ckv(fk[:, None, idx]), ckv(fv[:, None, idx])

        self._scatter_fn = scatter
        self._gather_fn = gather

        # ------------------------------------------------ fused decode step
        # One jitted, buffer-donated device function per (B bucket, P
        # bucket, n_steps): paged attention over all layers, sampling,
        # KV/state writes, and per-lane length/EOS/finished bookkeeping
        # run on-device inside a lax.fori_loop; the host gets back ONE
        # small (tokens, emitted, finished) transfer per call.  Recurrent
        # families carry per-slot state inside the cache, so their lanes
        # are slot-positional (B = n_slots, a single batch bucket); the
        # attention families bucket active lanes to the pow2 ladder.
        self._slot_state = "ssm" in self._cache
        model = self.model
        base_key = jax.random.PRNGKey(self.seed)

        @functools.partial(jit,
                           static_argnames=("n_steps", "all_greedy"),
                           donate_argnums=(1,))
        def fused_steps(params, cache, last, cl, tables, budgets, caps,
                        eos, temps, seeds, counters, *, n_steps: int,
                        all_greedy: bool):
            nb = last.shape[0]
            greedy = temps <= 0.0
            safe_t = jnp.where(greedy, 1.0, temps)

            def body(i, st):
                cache, last, cl, emitted, fin, buf = st
                act = (~fin) & (i < budgets)
                # inactive lanes (finished mid-loop, budget-paused, pad)
                # ride the scratch page: their KV write lands harmlessly
                bt = jnp.where(act[:, None], tables, SCRATCH_BLOCK)
                old_ssm = cache.get("ssm")
                logits, cache = model.decode_step_paged(
                    params, last[:, None], cache, cl, bt, page_size=page)
                if old_ssm is not None:
                    # recurrent state has no scratch page — freeze the
                    # rows of inactive lanes explicitly
                    cache = dict(cache)
                    cache["ssm"] = jax.tree.map(
                        lambda new, old: jnp.where(
                            act.reshape((1, nb) + (1,) * (new.ndim - 2)),
                            new, old),
                        cache["ssm"], old_ssm)
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                if not all_greedy:
                    # categorical draws are keyed by (request seed,
                    # position) — invariant to slot and preemption
                    # history.  Skipped entirely (statically) when every
                    # lane is greedy: at production vocab sizes the
                    # per-lane Gumbel draw is the single largest cost in
                    # the step after the forward itself.
                    keys = jax.vmap(
                        lambda s, c: jax.random.fold_in(
                            jax.random.fold_in(base_key, s), c)
                    )(seeds, (counters + i).astype(jnp.uint32))
                    st_tok = jax.vmap(jax.random.categorical)(
                        keys, logits.astype(jnp.float32) / safe_t[:, None])
                    tok = jnp.where(greedy, tok, st_tok.astype(jnp.int32))
                emitted = emitted + act.astype(jnp.int32)
                fin = fin | (act & ((tok == eos) | (emitted >= caps)))
                last = jnp.where(act, tok, last)
                cl = cl + act.astype(cl.dtype)
                buf = buf.at[:, i].set(jnp.where(act, tok, -1))
                return (cache, last, cl, emitted, fin, buf)

            st0 = (cache, last, cl, jnp.zeros((nb,), jnp.int32),
                   jnp.zeros((nb,), bool), jnp.full((nb, n_steps), -1,
                                                    jnp.int32))
            cache, last, cl, emitted, fin, buf = jax.lax.fori_loop(
                0, n_steps, body, st0)
            return buf, emitted, fin, cc(cache)

        self._fused_fn = fused_steps
        # abstract (shape/dtype/sharding) args of the last fused call —
        # lower_fused_hlo() re-lowers them for the roofline bench
        self._last_fused_call = None

    def _preflight_memory(self) -> None:
        """Refuse to build an engine that cannot fit one shard on one
        device.  Pure arithmetic over parameter templates and pool
        shapes (``sharded.estimate_device_bytes``) — runs before any
        device allocation, so an over-budget config fails with a
        diagnostic instead of an allocator OOM mid-init."""
        self.preflight = None
        if self.device_memory_gb is None:
            return
        from .sharded import estimate_device_bytes
        est = estimate_device_bytes(
            self.model, tp=self.tp, parallel=self.parallel,
            n_pages=self.kv.pool_blocks, page_size=self.block_size,
            n_slots=self.n_slots)
        budget = int(self.device_memory_gb * (1 << 30))
        if est["total_bytes"] > budget:
            gib = 1 << 30
            fixes = "raise tp or shrink the KV pool" \
                if self.parallel == "efficient" \
                else "raise tp, switch parallel='efficient', or shrink " \
                     "the KV pool"
            raise ValueError(
                f"model {self.model.cfg.name!r} does not fit: per-device "
                f"need {est['total_bytes'] / gib:.2f} GiB "
                f"(weights {est['weights_bytes'] / gib:.2f} + "
                f"KV pool {est['kv_pool_bytes'] / gib:.2f} + "
                f"workspace {est['workspace_bytes'] / gib:.2f}) "
                f"> budget {self.device_memory_gb:.2f} GiB at "
                f"tp={self.tp} parallel={self.parallel!r}; {fixes} "
                f"(replicated bytes: {est['replicated_bytes'] / gib:.2f} "
                "GiB)")
        self.preflight = est

    # ------------------------------------------------------------ frontend

    def submit(self, request: ServeRequest) -> None:
        """Enqueue one request — the B = 1 case of ``submit_batch``."""
        self.submit_batch([request])

    def submit_batch(self, requests: list[ServeRequest],
                     length_dists: list | None = None) -> None:
        """Enqueue a burst of requests through one batched admission:
        a single ``Scheduler.admit_batch`` call (one predict_batch over
        the burst's prompts, one BatchState append).  Unstamped arrivals
        (``arrival == 0.0``) share one clock reading — the burst arrived
        together.  ``length_dists`` forwards caller-side predictions
        (the gateway predicts once for shed scoring and hands the same
        distributions down, instead of predicting twice)."""
        if not requests:
            return
        now = self.clock()
        arrivals = [now if r.arrival == 0.0 else r.arrival
                    for r in requests]
        # admit first: admit_batch rejects duplicates before mutating any
        # state, so a failed burst leaves no ghost entries in _requests
        self.scheduler.admit_batch(
            [r.request_id for r in requests],
            [r.prompt for r in requests],
            [r.input_len for r in requests],
            arrivals=arrivals, length_dists=length_dists,
            tenants=[r.tenant for r in requests])
        for r, arrival in zip(requests, arrivals):
            r.arrival = arrival
            self._requests[r.request_id] = r

    def abort(self, request_id: str, reason: str = "abort") -> None:
        """Terminate a request in ANY non-terminal lifecycle state —
        waiting, mid-chunked-prefill, decoding, pressure-stalled, or
        swapped out — releasing every device block, the slot, and any
        host swap payload.  Tokens already decoded for it are accounted
        as wasted (goodput != throughput)."""
        r = self._requests.get(request_id)
        if r and not r.done:
            self._release(r)
            r.state = RequestState.ABORTED
            r.finish_reason = reason
            self.metrics.aborted += 1
            self.metrics.wasted_tokens += r.generated
            if reason.endswith("_deadline"):
                self.metrics.timeout_aborts += 1
            self.scheduler.on_abort(request_id)

    @property
    def has_work(self) -> bool:
        return any(not r.done for r in self._requests.values())

    # -------------------------------------------------------- slot plumbing

    def _clear_slot(self, r: ServeRequest) -> None:
        if r.slot >= 0:
            self._slot_rid.pop(r.slot, None)
            self._cache_len[r.slot] = -1
            self._block_tables[r.slot] = SCRATCH_BLOCK
            r.slot = -1
        if r.request_id in self._running:
            self._running.remove(r.request_id)
        self._needs_grow.discard(r.request_id)

    def _release(self, r: ServeRequest) -> None:
        """Drop every engine-side resource (completion / abort)."""
        if self.kv.holds(r.request_id):
            self.kv.release(r.request_id)
        self.kv.drop_swapped(r.request_id)
        r.prefill_pos = 0
        self._clear_slot(r)

    def _bind_slot(self, r: ServeRequest, slot: int) -> None:
        r.slot = slot
        self._slot_rid[slot] = r.request_id
        row = np.full(self._max_pages, SCRATCH_BLOCK, np.int32)
        blocks = self.kv.block_table(r.request_id)
        row[:len(blocks)] = blocks
        self._block_tables[slot] = row
        if r.request_id not in self._running:
            self._running.append(r.request_id)
        r.state = RequestState.RUNNING

    def _sync_block_table(self, r: ServeRequest) -> None:
        """Refresh a slot's table row after ``grow`` appended blocks."""
        blocks = self.kv.block_table(r.request_id)
        self._block_tables[r.slot, :len(blocks)] = blocks

    # ------------------------------------------------------------ swap plane

    def _gather_payload(self, r: ServeRequest, blocks: list[int]) -> dict:
        slot = r.slot
        payload = {
            "cache_len": int(self._cache_len[slot]),
            "last_token": int(self._last_token[slot]),
            "prefill_pos": r.prefill_pos,
        }
        if self._has_kv:
            idx = jnp.asarray(blocks)
            payload["k"] = np.asarray(self._cache["k"][:, idx])
            payload["v"] = np.asarray(self._cache["v"][:, idx])
        if "ssm" in self._cache:
            payload["ssm"] = jax.tree.map(
                lambda a: np.asarray(a[:, slot]), self._cache["ssm"])
        return payload

    def _restore_payload(self, r: ServeRequest, payload: dict) -> None:
        slot = r.slot
        blocks = self.kv.block_table(r.request_id)
        # leading blocks re-adopted from the prefix index at swap_in
        # already hold this prefix's KV on device — scatter only the rest
        skip = self.kv.adopted_blocks_of(r.request_id)
        if self._has_kv and len(blocks) > skip:
            idx = jnp.asarray(blocks[skip:])
            self._cache["k"] = self._cache["k"].at[:, idx].set(
                jnp.asarray(payload["k"])[:, skip:])
            self._cache["v"] = self._cache["v"].at[:, idx].set(
                jnp.asarray(payload["v"])[:, skip:])
        if "ssm" in self._cache:
            self._cache["ssm"] = jax.tree.map(
                lambda big, small: big.at[:, slot].set(jnp.asarray(small)),
                self._cache["ssm"], payload["ssm"])
        self._cache_len[slot] = payload["cache_len"]
        self._last_token[slot] = payload["last_token"]
        r.prefill_pos = payload["prefill_pos"]
        # eager scatters above leave sharding propagation to XLA; re-pin
        # the pool so the next jitted call sees the plan layout (no-op
        # copy when it already matches, and always on the plain path)
        self._commit_cache()

    def _commit_cache(self) -> None:
        if self.plan is not None:
            self._cache = self.plan.place_cache(self._cache)

    def _preempt(self, r: ServeRequest) -> None:
        rid = r.request_id
        swapped = False
        if (self.preemption_mode == "swap" and self.kv.holds(rid)
                and self.kv.can_swap_out(rid)):
            blocks = self.kv.block_table(rid)
            payload = self._gather_payload(r, blocks)
            tokens = self.kv.swap_out(rid, payload)
            self.metrics.swap_outs += 1
            self.metrics.swapped_out_tokens += tokens
            self.metrics.modeled_swap_s += self.service_model.swap_time(
                tokens, self.kv.block_size)
            swapped = True
        elif self.kv.holds(rid):
            self.kv.release(rid)
        if not swapped:
            r.prefill_pos = 0      # recompute mode: replay the context
        self._clear_slot(r)
        r.state = RequestState.SWAPPED
        r.n_preemptions += 1
        self.metrics.preemptions += 1

    # --------------------------------------------------------------- select

    def _select_running(self) -> list[str]:
        """Scheduler-priority admission under the slot limit and the
        KVCacheManager's *block* budget (``budget_blocks`` — the same
        accessor ``can_admit`` uses, so engine selection and manager
        admission can never drift).  Ranking happens inside the
        scheduler: preemptive policies scale running priorities by the
        hysteresis factor, non-preemptive ones pin the running set."""
        live = [rid for rid, r in self._requests.items() if not r.done]
        if not live:
            return []
        running = set(self._running)
        if self.scheduler.preemptive:
            order = self.scheduler.order(
                live, running=running,
                hysteresis=self.preemption_hysteresis)
        else:
            order = self.scheduler.order(live, running=running,
                                         pin_running=True)
        selected, used_blocks = [], 0.0
        budget = self.kv.budget_blocks
        for rid in order:
            if len(selected) >= self.n_slots:
                break
            need = float(self.kv.blocks_for(
                self._requests[rid].context_len + 1))
            if self.kv.holds(rid):
                # resident: charge owned (refcount-weighted) blocks, so
                # N requests sharing a prefix pay for it once, not N
                # times (identical to raw held blocks when private)
                need -= self.kv.shared_excess_blocks(rid)
            elif self._sharing:
                # waiting: discount the blocks a prefix match would
                # adopt (kept >= 1 so every request charges something)
                m, _, _ = self.kv.match_prefix(
                    self._requests[rid].prompt_tokens)
                need -= min(m // self.block_size, need - 1)
            if used_blocks + need <= budget:
                selected.append(rid)
                used_blocks += need
        if not selected:
            # nothing fits (e.g. one giant prompt): force the top request
            # so the engine cannot stall; if its context exceeds even the
            # physical pool, step()'s admit guard rejects it outright
            selected = [order[0]]
        return selected

    # --------------------------------------------------------------- admit

    @property
    def _sharing(self) -> bool:
        """Prefix sharing is live only when the family can resume a
        prefill mid-context (chunked prefill) through the paged KV pool.
        Recurrent-state families cannot start at a divergence point, so
        the flag is inert for them — tokens never change either way."""
        return (self.prefix_sharing and self._has_kv
                and self.model.supports_chunked_prefill
                and self.prefill_chunk is not None)

    def _match_prompt(self, r: ServeRequest) -> tuple[int, list[int],
                                                      list[int]]:
        """Longest adoptable shared-block prefix of ``r``'s prompt,
        capped twice: (a) strictly below the last context position — the
        decode path re-emits from ``context_len - 1`` (see
        ``_finalize_prefill``'s rewind), so the block holding it must be
        private, which also makes runtime copy-on-write forks
        unnecessary in the engine (the cap IS the fork point, taken
        before any divergent write exists); (b) down to the prefill
        chunk grid, so the remaining chunks land on exactly the
        boundaries a from-scratch prefill would use and the computed KV
        (and therefore every sampled token) is bit-identical to the
        sharing-off run."""
        if not self._sharing:
            return 0, [], []
        matched, blocks, hashes = self.kv.match_prefix(r.prompt_tokens)
        if not matched:
            return 0, [], []
        grid = self.prefill_chunk * self.block_size \
            // math.gcd(self.prefill_chunk, self.block_size)
        m = (min(matched, r.context_len - 1) // grid) * grid
        k = m // self.block_size
        return m, blocks[:k], hashes[:k]

    def _admit(self, r: ServeRequest) -> None:
        rid = r.request_id
        if self.preemption_mode == "swap" and self.kv.is_swapped(rid):
            try:
                slot, payload = self.kv.swap_in(rid)
            except RuntimeError:
                # capacity shortfalls resolve next step (re-raise: the
                # step loop leaves the request queued) — but a failure
                # while the pool HAD room is a faulty payload/IO path;
                # drop the host copy and recompute instead of
                # livelocking on a restore that can never succeed
                need = self.kv.blocks_for(self.kv.swapped_tokens_of(rid))
                if self.kv.free_slots == 0 or need > self.kv.free_blocks:
                    raise
                self.metrics.swap_in_faults += 1
                self.kv.drop_swapped(rid)
                r.prefill_pos = 0
            else:
                self._restore_swapped(r, slot, payload)
                return
        self.kv.drop_swapped(rid)
        ctx_len = r.context_len      # replay prompt + outputs on recompute
        matched, shared, hashes = self._match_prompt(r)
        if matched:
            slot = self.kv.allocate_shared(rid, ctx_len, shared, hashes)
            r.prefill_pos = matched  # chunks resume at the divergence point
            self.metrics.prefill_tokens_reused += matched
        else:
            slot = self.kv.allocate(rid, ctx_len)
            r.prefill_pos = 0
        self._bind_slot(r, slot)
        self._cache_len[slot] = -1   # not decode-ready until prefilled

    def _restore_swapped(self, r: ServeRequest, slot: int,
                         payload: dict) -> None:
        rid = r.request_id
        tokens = self.kv.tokens_of(rid)
        r.slot = slot
        self._bind_slot(r, slot)
        self._restore_payload(r, payload)
        r.n_swap_restores += 1
        self.metrics.swap_ins += 1
        self.metrics.swapped_in_tokens += tokens
        self.metrics.modeled_swap_s += self.service_model.swap_time(
            tokens, self.kv.block_size)
        # a request preempted while awaiting a growth block comes
        # back one block short of its next write position — re-grow
        # (or re-mark the pressure) before it may decode again
        if self._cache_len[slot] >= 0 \
                and self.kv.tokens_of(rid) <= self._cache_len[slot]:
            if self.kv.grow(rid, 1):
                self._sync_block_table(r)
            else:
                self.metrics.grow_failures += 1
                self._needs_grow.add(rid)

    # -------------------------------------------------------------- prefill

    def _phys_positions(self, r: ServeRequest, lo: int, hi: int,
                        pad_to: int) -> np.ndarray:
        """Flat pool token indices for logical positions [lo, hi), padded
        to ``pad_to`` entries pointing at the scratch page."""
        page = self.block_size
        table = self._block_tables[r.slot]
        pos = np.arange(lo, lo + pad_to)
        phys = table[np.minimum(pos // page, self._max_pages - 1)] * page \
            + pos % page
        phys[pos >= hi] = SCRATCH_BLOCK * page
        return phys.astype(np.int32)

    def _finalize_prefill(self, r: ServeRequest, ctx: list[int]) -> None:
        # the prefill may have run over a padded buffer, so its
        # last-position logits are not trustworthy; rewind one position
        # and let the shared decode path re-emit from the true last
        # context token (the cache holds positions < len(ctx)).
        # Identical for fresh prompts and recompute-mode readmissions —
        # ctx already includes any previously generated tokens.
        self._cache_len[r.slot] = len(ctx) - 1
        self._last_token[r.slot] = ctx[-1]
        self.metrics.prefills += 1
        # publish this prompt's full blocks for later prompts to adopt
        # (first writer wins; positions at/after the rewind point above
        # are never published — the manager excludes the last prompt
        # position's block)
        if self._sharing:
            self.kv.register_prefix(r.request_id, r.prompt_tokens)

    def _prefill_chunk_step(self, r: ServeRequest, take: int) -> None:
        """Advance one Sarathi chunk: run [prefill_pos, prefill_pos+take)
        against the pool-resident prefix, scatter the chunk's KV."""
        ctx = r.prompt_tokens + r.output_tokens
        s0, s1 = r.prefill_pos, r.prefill_pos + take
        cpad = _pad_len(take)
        toks = np.zeros((1, cpad), np.int32)
        toks[0, :take] = ctx[s0:s1]
        if s0 == 0:
            shp = self._cache["k"].shape
            past_k = jnp.zeros((shp[0], 1, 0) + shp[3:], jnp.bfloat16)
            past_v = past_k
        else:
            past_pad = _pad_len(s0)
            idx = jnp.asarray(self._phys_positions(r, 0, s0, past_pad))
            past_k, past_v = self._gather_fn(self._cache["k"],
                                             self._cache["v"], idx)
        k_c, v_c = self._chunk_fn(self.params, jnp.asarray(toks),
                                  past_k, past_v, jnp.int32(s0))
        out_idx = jnp.asarray(self._phys_positions(r, s0, s1, cpad))
        self._cache["k"], self._cache["v"] = self._scatter_fn(
            self._cache["k"], self._cache["v"], k_c, v_c, out_idx)
        r.prefill_pos = s1
        self.metrics.prefill_chunks += 1
        self.metrics.prefill_tokens += take  # tokens actually computed
        if s1 >= len(ctx):
            self._finalize_prefill(r, ctx)

    def _prefill_atomic(self, r: ServeRequest) -> None:
        """Whole-context prefill for families without chunk support
        (SSM / hybrid recurrent state cannot replay a chunk), padded to a
        pow2 bucket.  The true length rides along as a mask threaded
        through the recurrent scan (``mamba2_block`` forces dt = 0 at pad
        positions, so decay is exactly 1 and the state is bit-identical
        to an unpadded run) — one XLA compile per *bucket*, not per
        distinct context length.  KV (hybrid) is scattered into the pool
        for valid positions only; pad positions land in scratch."""
        ctx = r.prompt_tokens + r.output_tokens
        n = len(ctx)
        spad = _pad_len(n, quantum=32)
        toks = np.zeros((1, spad), np.int32)
        toks[0, :n] = ctx
        _, cache = self._prefill_fn(
            self.params, {"tokens": jnp.asarray(toks),
                          "lengths": jnp.asarray([n], jnp.int32)})
        if self._has_kv:
            phys = jnp.asarray(self._phys_positions(r, 0, n, spad))
            self._cache["k"], self._cache["v"] = self._scatter_fn(
                self._cache["k"], self._cache["v"], cache["k"], cache["v"],
                phys)
        if "ssm" in self._cache:
            slot = r.slot
            self._cache["ssm"] = jax.tree.map(
                lambda big, small: big.at[:, slot].set(
                    small[:, 0].astype(big.dtype)),
                self._cache["ssm"], cache["ssm"])
            self._commit_cache()
        r.prefill_pos = len(ctx)
        self.metrics.prefill_chunks += 1
        self.metrics.prefill_tokens += len(ctx)
        self._finalize_prefill(r, ctx)

    def _run_prefills(self) -> None:
        """Advance every prefilling slot under the step's token budget:
        chunked prefill mixes with the decode batch — decode-ready slots
        each consume one budget token, the remainder goes to chunks."""
        prefilling = [rid for rid in self._running
                      if self._cache_len[self._requests[rid].slot] < 0]
        if not prefilling:
            return
        budget = None
        if self.max_tokens_per_step is not None:
            n_decoding = len(self._running) - len(prefilling)
            budget = max(0, self.max_tokens_per_step - n_decoding)
        for rid in prefilling:
            r = self._requests[rid]
            if not self.model.supports_chunked_prefill:
                self._prefill_atomic(r)
                continue
            remaining = r.context_len - r.prefill_pos
            cap = self.prefill_chunk or remaining
            if budget is not None:
                cap = min(cap, budget)
            take = min(cap, remaining)
            if take <= 0:
                continue            # budget exhausted: resume next step
            self._prefill_chunk_step(r, take)
            if budget is not None:
                budget -= take

    # ------------------------------------------------------------- pressure

    def _finish(self, r: ServeRequest, reason: str = "eos") -> None:
        r.state = RequestState.FINISHED
        r.finish_reason = reason
        r.ttlt = self.clock() - r.arrival
        self._release(r)
        self.scheduler.on_complete(r.request_id, r.generated)
        self.metrics.completed += 1
        if hasattr(self.scheduler, "calibration_summary"):
            # per-tenant coverage / CRPS over the rolling window — kept
            # current on every completion so metrics snapshots mid-run
            # see live calibration, not just the final state
            self.metrics.calibration = self.scheduler.calibration_summary()

    def _relieve_pressure(self) -> None:
        """Decode growth that returned ``grow() == False`` is surfaced
        here: force eviction until the growth fits, victims chosen by the
        scheduler's memory-aware eviction order (priority + held-KV /
        swap-cost term — the paper's hybrid true-service-cost).  Until a
        request's growth fits, its slot sits out the decode batch (the
        sampling loop skips ``_needs_grow`` members)."""
        while self._needs_grow:
            rid = next(iter(self._needs_grow))
            r = self._requests.get(rid)
            if r is None or r.done or not self.kv.holds(rid):
                self._needs_grow.discard(rid)
                continue
            if self.kv.grow(rid, 1):
                self._sync_block_table(r)
                self._needs_grow.discard(rid)
                continue
            candidates = [x for x in self._running if self.kv.holds(x)]
            if candidates == [rid]:
                # sole resident request and still no room: its context has
                # filled the physical pool — terminate by truncation, the
                # same way the max_seq_len guard ends an endless request
                self._finish(r, reason="truncated")
                continue
            if not candidates:
                break
            victims = self.scheduler.eviction_order(
                candidates,
                # owned (refcount-weighted) tokens: a heavy sharer frees
                # little real memory when evicted, so it ranks cheap to
                # keep; equals block-aligned held tokens when private
                held_tokens={x: self.kv.owned_tokens_of(x)
                             for x in candidates},
                swap_cost=lambda t: self.service_model.swap_time(
                    t, self.kv.block_size),
                memory_weight=self.memory_weight)
            self._preempt(self._requests[victims[0]])
            self.metrics.forced_evictions += 1

    # ------------------------------------------------------------- sampling

    def _sample_batch(self, logits: np.ndarray, slots: list[int],
                      temps: np.ndarray) -> np.ndarray:
        """ONE vectorized sampling pass over all decode-ready slots:
        argmax for greedy rows, inverse-CDF categorical for the rest."""
        rows = logits[slots].astype(np.float64)
        out = np.empty(len(slots), np.int64)
        greedy = temps <= 0
        if greedy.any():
            out[greedy] = rows[greedy].argmax(axis=1)
        stoch = ~greedy
        if stoch.any():
            x = rows[stoch] / temps[stoch, None]
            x -= x.max(axis=1, keepdims=True)
            p = np.exp(x)
            p /= p.sum(axis=1, keepdims=True)
            u = self._rng.random(p.shape[0])
            cdf = np.cumsum(p, axis=1)
            out[stoch] = np.minimum((cdf < u[:, None]).sum(axis=1),
                                    p.shape[1] - 1)
        return out

    # ----------------------------------------------------------------- step

    def step(self) -> int:
        """One engine iteration. Returns number of running requests."""
        now = self.clock()
        self.scheduler.set_now(now)
        selected = self._select_running()
        sel = set(selected)

        # preempt displaced requests (swap mode keeps their KV on host)
        for rid in list(self._running):
            if rid not in sel:
                self._preempt(self._requests[rid])

        # admit newcomers: swap-ins restore KV, others (re-)prefill
        for rid in selected:
            r = self._requests[rid]
            if r.state != RequestState.RUNNING:
                try:
                    self._admit(r)
                except RuntimeError:
                    if self.kv.blocks_for(r.context_len + 1) \
                            > self.kv.n_blocks:
                        # the context can NEVER fit the physical pool:
                        # reject instead of livelocking in WAITING
                        self.abort(rid, reason="infeasible_prompt")
                        continue
                    # transient shortfall (e.g. forced-top guard racing
                    # an external hog): leave the request queued
                    continue

        # capacity pressure from the previous decode's growth
        self._relieve_pressure()

        # chunked prefill, mixed with the decode batch under one budget
        self._run_prefills()

        if not self._running:
            return 0

        # decode-ready slots.  _relieve_pressure drains _needs_grow every
        # step before this point (a pressured resident request is always
        # grown, evicted, or truncation-finished), so the filter below is
        # a defensive invariant guard: if a future path ever leaves a
        # pressured slot resident, sampling it would append a token whose
        # KV write lands in scratch and is lost.
        ready = [(slot, rid) for slot, rid in sorted(self._slot_rid.items())
                 if self._cache_len[slot] >= 0
                 and rid not in self._needs_grow]
        if not ready:
            return len(self._running)

        if self.step_mode == "fused":
            self._decode_fused(ready)
        else:
            self._decode_orchestrated(ready)
        return len(self._running)

    def _decode_orchestrated(self, ready: list[tuple[int, str]]) -> None:
        """Python-orchestrated decode iteration (the pre-fused path, kept
        as the fused step's parity oracle and benchmark baseline): one
        full-width device forward, logits shipped to the host, sampling
        and per-slot bookkeeping in numpy."""
        # one decode iteration over all slots.  Slots that are mid-prefill
        # (or free) are masked by pointing their table rows at the scratch
        # page for this call: their lane's write lands in scratch instead
        # of clobbering KV the chunked prefill already scattered.
        tokens = jnp.asarray(self._last_token[:, None], jnp.int32)
        cache_len = jnp.asarray(np.maximum(self._cache_len, 0), jnp.int32)
        tables_np = self._block_tables
        not_ready = self._cache_len < 0
        if not_ready.any():
            tables_np = tables_np.copy()
            tables_np[not_ready] = SCRATCH_BLOCK
        tables = jnp.asarray(tables_np)
        logits, self._cache = self._decode_fn(self.params, tokens,
                                              self._cache, cache_len,
                                              tables)
        logits_np = np.asarray(logits, np.float32)
        self.metrics.decode_iterations += 1

        slots = [s for s, _ in ready]
        rids = [rid for _, rid in ready]
        temps = np.array([self._requests[rid].temperature for rid in rids])
        toks = self._sample_batch(logits_np, slots, temps)

        progressing, progressed = [], []
        for slot, rid, tok in zip(slots, rids, toks):
            r = self._requests[rid]
            tok = int(tok)
            self._cache_len[slot] += 1
            self._last_token[slot] = tok
            r.output_tokens.append(tok)
            self.metrics.decode_tokens += 1
            if np.isnan(r.ttft):
                r.ttft = self.clock() - r.arrival
            if tok == r.eos_token:
                self._finish(r, reason="eos")
                continue
            if r.generated >= r.max_new_tokens \
                    or r.context_len >= self.max_seq_len - 1:
                self._finish(r, reason="length")
                continue
            progressing.append(rid)
            progressed.append(r.generated)
            # reserve the next token's block now; a False return is
            # surfaced as capacity pressure and forces eviction at the
            # next select (previously this return value was dropped and
            # over-capacity growth went unaccounted)
            if self.kv.grow(rid, 1):
                self._sync_block_table(r)
            else:
                self.metrics.grow_failures += 1
                self._needs_grow.add(rid)
        self.scheduler.on_progress_many(progressing, progressed)

    def _decode_fused(self, ready: list[tuple[int, str]]) -> None:
        """Fused decode: ONE jitted, donated device call advances every
        ready lane by up to ``decode_steps`` tokens (attention, sampling,
        KV/state writes, EOS/length bookkeeping all on-device in a
        fori_loop); the host gets back one (tokens, emitted, finished)
        transfer and only does block accounting + scheduler feedback.

        Lane layout: recurrent families are slot-positional (their state
        lives per-slot inside the cache); attention families gather the
        ready slots into a pow2 batch bucket.  Table width rides its own
        pow2 ladder, so batch/page churn never changes the traced shapes
        beyond the bounded bucket set."""
        n_steps = self.decode_steps
        # per-lane step budgets: cap = tokens until forced finish
        # (max_new_tokens / max_seq_len), grant = KV reserved ahead of the
        # call (a short grant pauses the lane rather than overrunning)
        plan = []                              # (slot, rid, budget, cap)
        for slot, rid in ready:
            r = self._requests[rid]
            cap = min(r.max_new_tokens - r.generated,
                      (self.max_seq_len - 1) - r.context_len)
            cap = max(1, cap)
            want = min(n_steps, cap)
            grant = self.kv.grow_upto(rid, want - 1) if want > 1 else 0
            if grant:
                self._sync_block_table(r)
            plan.append((slot, rid, grant + 1, cap))

        # ladder floors (8 lanes / 4 pages): padding a tiny batch up to
        # the floor costs almost nothing to execute, but every ladder
        # rung below it is a whole XLA compile of the fused loop — the
        # floors keep short-lived small engines from spending their
        # entire run compiling rungs they graduate out of
        if self._slot_state:
            nb = self.n_slots
            lane_of = {slot: slot for slot, _ in ready}
        else:
            nb = _pow2_bucket(len(ready), floor=8, cap=self.n_slots)
            lane_of = {slot: j for j, (slot, _) in enumerate(ready)}
        p_used = max(len(self.kv.block_table(rid)) for _, rid in ready)
        pb = _pow2_bucket(p_used, floor=4, cap=self._max_pages)

        last = np.zeros(nb, np.int32)
        cl = np.zeros(nb, np.int32)
        tables = np.full((nb, pb), SCRATCH_BLOCK, np.int32)
        budgets = np.zeros(nb, np.int32)
        caps = np.ones(nb, np.int32)
        eos = np.full(nb, -1, np.int32)
        temps = np.zeros(nb, np.float32)
        seeds = np.zeros(nb, np.uint32)
        counters = np.zeros(nb, np.int32)
        for slot, rid, budget, cap in plan:
            r = self._requests[rid]
            lane = lane_of[slot]
            last[lane] = self._last_token[slot]
            cl[lane] = self._cache_len[slot]
            tables[lane] = self._block_tables[slot, :pb]
            budgets[lane] = budget
            caps[lane] = cap
            eos[lane] = r.eos_token
            temps[lane] = r.temperature
            seeds[lane] = _rid_seed(rid)
            counters[lane] = r.generated

        dev_args = (self.params, self._cache, jnp.asarray(last),
                    jnp.asarray(cl), jnp.asarray(tables),
                    jnp.asarray(budgets), jnp.asarray(caps),
                    jnp.asarray(eos), jnp.asarray(temps),
                    jnp.asarray(seeds), jnp.asarray(counters))
        static = dict(n_steps=n_steps,
                      all_greedy=bool((temps <= 0.0).all()))
        def _abs(a):
            # host-built args (tokens, tables, budgets) carry a default
            # single-device placement; on a mesh the stash must record
            # them as replicated or a later re-lower sees a device-set
            # mismatch against the mesh-sharded params/pool
            sh = a.sharding
            if self.plan is not None and len(sh.device_set) != \
                    self.plan.mesh.size:
                sh = self.plan.replicated
            return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)
        self._last_fused_call = (jax.tree.map(_abs, dev_args), static)
        buf, emitted, fin, self._cache = self._fused_fn(*dev_args, **static)
        # the ONE batched device->host transfer for this (multi-)step
        buf, emitted, fin = jax.device_get((buf, emitted, fin))
        self.metrics.decode_iterations += n_steps
        self.metrics.fused_steps += 1

        progressing, progressed = [], []
        for slot, rid, _, _ in plan:
            lane = lane_of[slot]
            e = int(emitted[lane])
            if e == 0:
                continue
            r = self._requests[rid]
            toks = [int(t) for t in buf[lane, :e]]
            r.output_tokens.extend(toks)
            self._cache_len[slot] += e
            self._last_token[slot] = toks[-1]
            self.metrics.decode_tokens += e
            if np.isnan(r.ttft):
                r.ttft = self.clock() - r.arrival
            if fin[lane]:
                self._finish(r, reason="eos" if toks[-1] == r.eos_token
                             else "length")
                continue
            progressing.append(rid)
            progressed.append(r.generated)
            # restore the reserve-one-ahead invariant for the next write;
            # a False return is capacity pressure, relieved by forced
            # eviction at the next select — same contract as the
            # orchestrated path's per-token grow
            if self.kv.grow(rid, 1):
                self._sync_block_table(r)
            else:
                self.metrics.grow_failures += 1
                self._needs_grow.add(rid)
        self.scheduler.on_progress_many(progressing, progressed)

    # ------------------------------------------------------ compile budget

    @property
    def fused_compile_count(self) -> int:
        """Actual XLA compile count of the fused step (jit cache size).

        Reads jax's (private, but the only per-function counter there
        is) ``PjitFunction._cache_size``; returns -1 if a jax upgrade
        removes it, so bound checks degrade to vacuous-pass instead of
        crashing CI (the compile-counter tests skip on -1)."""
        counter = getattr(self._fused_fn, "_cache_size", None)
        return counter() if counter is not None else -1

    def max_fused_compiles(self, n_steps_variants: int = 1) -> int:
        """Upper bound on fused-step compiles: the bucket-ladder product.
        Batch churn (admit/evict/finish) can only move shapes along the
        pow2 ladders, so the jit cache can never exceed this.  The
        final factor 2 is the all-greedy / mixed-sampling static
        specialization."""
        b_ladder = 1 if self._slot_state \
            else _ladder_size(self.n_slots, floor=8)
        return b_ladder * _ladder_size(self._max_pages, floor=4) \
            * n_steps_variants * 2

    def lower_fused_hlo(self) -> str | None:
        """Compiled HLO text of the most recent fused-step call (None
        before any decode).  Re-lowers from the stashed abstract args —
        shape/dtype/sharding only, so this is safe after donation — for
        the roofline bench's ``collective_bytes`` accounting."""
        if self._last_fused_call is None:
            return None
        abstract, static = self._last_fused_call
        return self._fused_fn.lower(*abstract, **static).compile().as_text()

    def sharding_report(self) -> dict | None:
        """Per-component sharding outcome on this engine's mesh (None on
        the single-device path) — see ShardingPlan.describe()."""
        return None if self.plan is None else self.plan.describe()

    def stall_report(self) -> dict:
        """Live-state diagnosis: per-state request counts, queue depth,
        pool occupancy, pressure set — the payload of EngineStallError."""
        states = Counter(r.state.name for r in self._requests.values())
        waiting = [rid for rid, r in self._requests.items()
                   if not r.done and rid not in self._running]
        return {
            "request_states": dict(states),
            "queue_depth": len(waiting),
            "running": list(self._running),
            "needs_grow": sorted(self._needs_grow),
            "kv": self.kv.conservation(),
        }

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            self.step()
        if not self.has_work:
            return
        raise EngineStallError(
            f"run_until_done: step budget ({max_steps}) exhausted with "
            f"work still live — {self.stall_report()}")
