"""Continuous-batching serving engine driving a real JAX model.

vLLM-style iteration loop, scheduled by repro.core.Scheduler (SageSched or
any baseline policy):

    submit() -> scheduler.admit (predict + cost + Gittins)
    each step():
        1. select the running set: scheduler priority order under the
           KVCacheManager token budget (+ slot limit), with hysteresis
           against priority thrashing (Sec. 3.3);
        2. prefill newly admitted requests (slot-written caches);
        3. one decode iteration over all running slots;
        4. sample, detect <EOS>/max_tokens, feed completions back to the
           scheduler's history window.

Preemption uses recompute mode (vLLM default): an evicted request frees
its slot and re-prefills its full context when readmitted.

The engine is single-host (the real CpuDevice here; a TPU slice in
production — the jitted step functions are the same ones the dry-run
lowers for the production mesh).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..core.scheduler import Scheduler
from ..models import Model
from .kv_cache import KVCacheManager
from .metrics import EngineMetrics
from .request import RequestState, ServeRequest

__all__ = ["ServingEngine"]


def _pad_len(n: int, quantum: int = 64) -> int:
    return max(quantum, ((n + quantum - 1) // quantum) * quantum)


@dataclass
class ServingEngine:
    model: Model
    scheduler: Scheduler
    n_slots: int = 8
    max_seq_len: int = 512
    capacity_tokens: int | None = None
    preemption_hysteresis: float = 0.5
    seed: int = 0
    params: dict | None = None

    _requests: dict[str, ServeRequest] = field(default_factory=dict)
    _running: list[str] = field(default_factory=list)

    def __post_init__(self):
        if self.params is None:
            self.params = self.model.init(jax.random.PRNGKey(self.seed))
        self.kv = KVCacheManager(self.n_slots, self.max_seq_len,
                                 self.capacity_tokens)
        self.metrics = EngineMetrics()
        self._rng = np.random.default_rng(self.seed)
        self._cache = self.model.init_cache(self.n_slots, self.max_seq_len)
        self._cache_len = np.zeros(self.n_slots, np.int64)
        self._last_token = np.zeros(self.n_slots, np.int64)
        self._slot_rid: dict[int, str] = {}
        self._decode_fn = jax.jit(
            lambda p, t, c, cl: self.model.decode_step(p, t, c, cl),
            donate_argnums=(2,))
        self._prefill_fn = jax.jit(
            lambda p, b: self.model.prefill(p, b),
            static_argnames=())

    # ------------------------------------------------------------ frontend

    def submit(self, request: ServeRequest) -> None:
        """Enqueue one request — the B = 1 case of ``submit_batch``."""
        self.submit_batch([request])

    def submit_batch(self, requests: list[ServeRequest]) -> None:
        """Enqueue a burst of requests through one batched admission:
        a single ``Scheduler.admit_batch`` call (one predict_batch over
        the burst's prompts, one BatchState append).  Unstamped arrivals
        (``arrival == 0.0``) share one clock reading — the burst arrived
        together."""
        if not requests:
            return
        now = time.monotonic()
        arrivals = [now if r.arrival == 0.0 else r.arrival
                    for r in requests]
        # admit first: admit_batch rejects duplicates before mutating any
        # state, so a failed burst leaves no ghost entries in _requests
        self.scheduler.admit_batch(
            [r.request_id for r in requests],
            [r.prompt for r in requests],
            [r.input_len for r in requests],
            arrivals=arrivals)
        for r, arrival in zip(requests, arrivals):
            r.arrival = arrival
            self._requests[r.request_id] = r

    def abort(self, request_id: str) -> None:
        r = self._requests.get(request_id)
        if r and not r.done:
            if r.state == RequestState.RUNNING:
                self._release(r)
            r.state = RequestState.ABORTED
            self.scheduler.on_abort(request_id)

    @property
    def has_work(self) -> bool:
        return any(not r.done for r in self._requests.values())

    # ------------------------------------------------------------- internal

    def _release(self, r: ServeRequest) -> None:
        if self.kv.holds(r.request_id):
            self.kv.release(r.request_id)
        if r.slot >= 0:
            self._slot_rid.pop(r.slot, None)
            self._cache_len[r.slot] = 0
            r.slot = -1
        if r.request_id in self._running:
            self._running.remove(r.request_id)

    def _select_running(self) -> list[str]:
        """Scheduler-priority admission under slot + token budget, with
        hysteresis protecting the current running set.  Ranking happens
        inside the scheduler (one lexsort over BatchState under a batched
        backend): preemptive policies scale running priorities by the
        hysteresis factor, non-preemptive ones pin the running set ahead
        of all waiters."""
        live = [rid for rid, r in self._requests.items() if not r.done]
        if not live:
            return []
        running = set(self._running)
        if self.scheduler.preemptive:
            order = self.scheduler.order(
                live, running=running,
                hysteresis=self.preemption_hysteresis)
        else:
            order = self.scheduler.order(live, running=running,
                                         pin_running=True)
        selected, used = [], 0
        budget = self.kv.capacity_tokens * (1 - self.kv.watermark)
        for rid in order:
            if len(selected) >= self.n_slots:
                break
            r = self._requests[rid]
            need = r.context_len + 1
            if used + need <= budget:
                selected.append(rid)
                used += need
        return selected

    def _write_slot(self, small_cache, slot: int) -> None:
        """Write a prefill (B=1) cache into `slot` of the engine cache."""
        def write(big, small):
            if small.ndim >= 3 and big.shape[2] != small.shape[2]:
                pad = [(0, 0)] * small.ndim
                pad[2] = (0, big.shape[2] - small.shape[2])
                small = jnp.pad(small, pad)
            idx = [slice(None)] * big.ndim
            idx[1] = slice(slot, slot + 1)
            return big.at[tuple(idx)].set(small.astype(big.dtype))
        self._cache = jax.tree.map(write, self._cache, small_cache)

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        if temperature <= 0:
            return int(np.argmax(logits))
        x = logits.astype(np.float64) / temperature
        x -= x.max()
        p = np.exp(x)
        p /= p.sum()
        return int(self._rng.choice(len(p), p=p))

    # ----------------------------------------------------------------- step

    def step(self) -> int:
        """One engine iteration. Returns number of running requests."""
        now = time.monotonic()
        self.scheduler.set_now(now)
        selected = self._select_running()

        # preempt displaced requests (recompute mode: drop KV)
        for rid in list(self._running):
            if rid not in selected:
                r = self._requests[rid]
                self._release(r)
                r.state = RequestState.SWAPPED
                r.n_preemptions += 1
                self.metrics.preemptions += 1

        # admit + prefill newcomers
        for rid in selected:
            r = self._requests[rid]
            if r.state == RequestState.RUNNING:
                continue
            ctx = r.prompt_tokens + r.output_tokens  # replay on readmission
            slot = self.kv.allocate(rid, len(ctx))
            r.slot = slot
            self._slot_rid[slot] = rid
            padded = _pad_len(len(ctx))
            toks = np.zeros((1, padded), np.int32)
            toks[0, :len(ctx)] = ctx
            logits, cache = self._prefill_fn(self.params,
                                             {"tokens": jnp.asarray(toks)})
            self._write_slot(cache, slot)
            # the prefill ran over a padded buffer, so its last-position
            # logits are not trustworthy; rewind one position and let the
            # shared decode path re-emit from the true last context token
            # (the cache holds positions < len(ctx)).  Identical for fresh
            # prompts and recompute-mode readmissions — ctx already
            # includes any previously generated tokens.
            self._cache_len[slot] = len(ctx) - 1
            self._last_token[slot] = ctx[-1]
            r.state = RequestState.RUNNING
            if rid not in self._running:
                self._running.append(rid)
            self.metrics.prefills += 1

        if not self._running:
            return 0

        # one decode iteration over all slots (inactive slots masked)
        tokens = jnp.asarray(self._last_token[:, None], jnp.int32)
        cache_len = jnp.asarray(np.maximum(self._cache_len, 0), jnp.int32)
        logits, self._cache = self._decode_fn(self.params, tokens,
                                              self._cache, cache_len)
        logits_np = np.asarray(logits, np.float32)
        self.metrics.decode_iterations += 1

        for slot, rid in list(self._slot_rid.items()):
            r = self._requests[rid]
            tok = self._sample(logits_np[slot], r.temperature)
            self._cache_len[slot] += 1
            self._last_token[slot] = tok
            r.output_tokens.append(tok)
            if np.isnan(r.ttft):
                r.ttft = time.monotonic() - r.arrival
            self.scheduler.on_progress(rid, r.generated)
            self.kv.grow(rid, 1)
            if tok == r.eos_token or r.generated >= r.max_new_tokens \
                    or r.context_len >= self.max_seq_len - 1:
                r.state = RequestState.FINISHED
                r.ttlt = time.monotonic() - r.arrival
                self._release(r)
                self.scheduler.on_complete(rid, r.generated)
                self.metrics.completed += 1
        return len(self._running)

    def run_until_done(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if not self.has_work:
                return
            self.step()
        raise RuntimeError("run_until_done: step budget exhausted")
