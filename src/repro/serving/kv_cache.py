"""Block-table KV cache manager: paged device pool + host swap pool.

TPU adaptation of vLLM's paged block manager (DESIGN.md): KV memory is a
pool of fixed-size *token blocks* (``block_size`` tokens each).  A running
request owns

  * a *slot* — its row in the engine's decode batch (tokens / cache_len /
    block-table arrays), and
  * a *block table* — the ordered list of physical blocks holding its KV;
    logical token position ``p`` lives at block ``table[p // block_size]``,
    offset ``p % block_size``.

Physical block 0 is a reserved *scratch* block: inactive decode rows point
their tables at it, so masked lanes write harmlessly instead of corrupting
a neighbour.  Allocation is block-granular, which makes the accounting
*fragmentation-aware*: a request holding ``t`` tokens pins
``ceil(t / block_size)`` blocks, and admission is budgeted in blocks
(``budget_blocks`` — one authoritative accessor shared by ``can_admit``
and the engine's running-set selection), not in raw tokens.

Preemption is swap-based: ``swap_out`` moves a request's blocks to a host
pool (the engine attaches the gathered KV arrays as an opaque *payload*),
``swap_in`` re-allocates device blocks and returns the payload so the
engine can restore the cache without re-prefilling.  Recompute-mode
preemption is plain ``release`` (drop the KV, replay the context later).

This manager is deliberately *mesh-agnostic* (docs/sharded_serving.md):
under a sharded engine the physical pages stripe over the kv-head dim,
but tables, refcounts, the prefix index, and swap accounting all stay
host-side and authoritative — a payload is the gathered full-head array
(gather/scatter of per-shard slices is a pure relayout), so payloads,
and with them cluster migration, are mesh-width-agnostic.

Prefix sharing (copy-on-write)
------------------------------
Full blocks of *prompt* KV are content-addressed by a chain hash
(``h_i = hash((h_{i-1}, block_tokens))``, so a hash names the whole
prefix up to and including block ``i``).  The prefix index maps chain
hash -> physical block; ``match_prefix`` walks it to find the longest
resident block chain for an incoming prompt, and ``allocate_shared``
adopts those blocks by bumping their *refcount* instead of copying.
Every physical block is therefore in exactly one of three states:

  * **free**       — on the free list, unreferenced, no content tag;
  * **cached**     — refcount 0 but still holding indexed prefix KV;
                     reclaimable (counted in ``free_blocks``) and evicted
                     LRU when the free list runs dry;
  * **referenced** — refcount >= 1, held by that many live allocations.

``release``/``swap_out`` decrement refcounts; a block is only recycled
(to *cached* if it carries a prefix tag, else to *free*) when its count
hits zero.  ``fork_block`` is the copy-on-write primitive: it gives one
reader a private replacement for a shared block (the engine avoids ever
needing a data copy by capping matches below the last prompt position,
so the divergence point is block-aligned — see docs/serving_engine.md).

Accounting under sharing distinguishes *held* from *owned*: a request
holding a block with refcount ``r`` owns ``1/r`` of it, so
``owned_blocks`` is the true pool pressure and is what admission and
eviction charge.  For private-only workloads (sharing disabled) owned ==
held and every number below is identical to the pre-sharing manager.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

__all__ = ["KVCacheManager", "BlockAllocation"]

SCRATCH_BLOCK = 0


@dataclass
class BlockAllocation:
    """Device-side state of one resident request.

    ``hashes[i]`` is the chain hash of ``blocks[i]`` for the leading
    *full prompt* blocks that participate in prefix sharing (shorter
    than ``blocks``: decode-grown and partial tail blocks are never
    hashed).  ``adopted`` counts the leading blocks that were adopted
    from the prefix index at allocation/swap-in time (their KV is
    already resident — the engine skips prefill / payload scatter for
    them).
    """

    slot: int
    tokens: int
    blocks: list[int] = field(default_factory=list)
    hashes: list[int] = field(default_factory=list)
    adopted: int = 0


@dataclass
class _HostAllocation:
    """Host-side state of one swapped-out request."""

    tokens: int
    n_blocks: int
    payload: Any = None
    # chain hashes of the leading prompt blocks at swap-out time, so
    # swap_in can re-match still-resident shared prefixes and restore
    # the share structure instead of scattering private copies.
    prefix_hashes: list[int] = field(default_factory=list)


class KVCacheManager:
    def __init__(self, n_slots: int, max_seq_len: int,
                 capacity_tokens: int | None = None,
                 watermark: float = 0.05,
                 block_size: int = 16,
                 swap_capacity_tokens: int | None = None):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        self.capacity_tokens = capacity_tokens or n_slots * max_seq_len
        self.watermark = watermark
        # device pool: blocks 1..n_blocks are allocatable, 0 is scratch
        self.n_blocks = -(-self.capacity_tokens // block_size)
        # host pool (swap destination), in blocks; default: 2x device
        swap_cap = (2 * self.capacity_tokens if swap_capacity_tokens is None
                    else swap_capacity_tokens)
        self.swap_blocks = -(-swap_cap // block_size)
        self._free_slots = list(range(n_slots))[::-1]
        self._free_blocks = list(range(1, self.n_blocks + 1))[::-1]
        self._held: dict[str, BlockAllocation] = {}
        self._swapped: dict[str, _HostAllocation] = {}
        # --- prefix-sharing state -----------------------------------
        # refcount per *referenced* block (absent == not referenced)
        self._ref: dict[int, int] = {}
        # refcount-0 blocks still holding indexed prefix KV, in LRU
        # order (dict preserves insertion order; oldest evicted first)
        self._cached: dict[int, int] = {}
        # chain hash -> canonical physical block, and its inverse
        self._index: dict[int, int] = {}
        self._block_hash: dict[int, int] = {}

    # ---------------------------------------------------------------- sizing

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` (fragmentation-aware: the last
        block is pinned whole even when partially filled)."""
        return max(1, -(-int(tokens) // self.block_size))

    @property
    def pool_blocks(self) -> int:
        """Physical pool size in blocks, *including* the scratch block —
        the first dimension of the engine's paged KV tensors."""
        return self.n_blocks + 1

    @property
    def budget_blocks(self) -> int:
        """The authoritative admission budget, in blocks: total blocks
        minus the watermark reserve kept free for decode growth.  Both
        ``can_admit`` and the engine's running-set selection budget
        against this single number (previously each hand-rolled its own
        ``capacity * (1 - watermark)`` and they could drift).  Under
        prefix sharing the budget is consumed by *owned* (refcount-
        weighted) blocks, so N requests sharing a prefix charge it
        once, not N times."""
        return int(self.n_blocks * (1.0 - self.watermark))

    @property
    def admission_budget_tokens(self) -> int:
        """``budget_blocks`` in token units (block-quantized)."""
        return self.budget_blocks * self.block_size

    # ---------------------------------------------------------------- state

    @property
    def used_tokens(self) -> int:
        """Logical tokens held on device (excludes fragmentation)."""
        return sum(a.tokens for a in self._held.values())

    @property
    def used_blocks(self) -> int:
        """Distinct physical blocks referenced by live allocations (a
        shared block counts once)."""
        return len(self._ref)

    @property
    def owned_blocks(self) -> float:
        """Refcount-weighted blocks charged to live allocations: a block
        with refcount r charges 1/r to each of its r holders, so the
        total equals ``used_blocks`` while splitting the cost fairly.
        Equals ``used_blocks`` exactly for private-only allocations."""
        return sum(self.owned_blocks_of(rid) for rid in self._held)

    @property
    def frag_tokens(self) -> int:
        """Tokens pinned but unused inside partially-filled last blocks
        (private allocations; sharing makes this a lower bound)."""
        return self.used_blocks * self.block_size - self.used_tokens

    @property
    def free_blocks(self) -> int:
        """Reclaimable blocks: the free list plus refcount-0 cached
        prefix blocks (evicted LRU on demand)."""
        return len(self._free_blocks) + len(self._cached)

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks retained for prefix reuse."""
        return len(self._cached)

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def swapped_tokens(self) -> int:
        return sum(a.tokens for a in self._swapped.values())

    @property
    def swapped_blocks_used(self) -> int:
        return sum(a.n_blocks for a in self._swapped.values())

    def tokens_of(self, request_id: str) -> int:
        return self._held[request_id].tokens

    def slot_of(self, request_id: str) -> int:
        return self._held[request_id].slot

    def block_table(self, request_id: str) -> list[int]:
        return list(self._held[request_id].blocks)

    def holds(self, request_id: str) -> bool:
        return request_id in self._held

    def is_swapped(self, request_id: str) -> bool:
        return request_id in self._swapped

    def swapped_tokens_of(self, request_id: str) -> int:
        return self._swapped[request_id].tokens

    def owned_blocks_of(self, request_id: str) -> float:
        """Refcount-weighted block charge of one request (1/r per block
        with refcount r; == len(block_table) when fully private)."""
        return sum(1.0 / self._ref[b]
                   for b in self._held[request_id].blocks)

    def owned_tokens_of(self, request_id: str) -> float:
        """``owned_blocks_of`` in token units — the eviction cost proxy
        (a heavy sharer frees little real memory when evicted)."""
        return self.owned_blocks_of(request_id) * self.block_size

    def shared_excess_blocks(self, request_id: str) -> float:
        """Blocks held but not owned (0.0 when fully private)."""
        a = self._held[request_id]
        return len(a.blocks) - self.owned_blocks_of(request_id)

    def adopted_blocks_of(self, request_id: str) -> int:
        """Leading blocks adopted from the prefix index at allocate /
        swap-in time (their KV is already resident on device)."""
        return self._held[request_id].adopted

    def refcount_of(self, block: int) -> int:
        return self._ref.get(block, 0)

    def live_refcounts(self) -> dict[int, int]:
        """Snapshot of per-block refcounts for referenced blocks."""
        return dict(self._ref)

    # ------------------------------------------------------- prefix sharing

    def chain_hashes(self, token_ids) -> list[int]:
        """Chain hashes of the *full* blocks of ``token_ids``: entry i
        names the whole prefix ``token_ids[:(i+1)*block_size]``."""
        bs = self.block_size
        out: list[int] = []
        h = 0
        for i in range(len(token_ids) // bs):
            h = hash((h, tuple(int(t) for t in token_ids[i * bs:(i + 1) * bs])))
            out.append(h)
        return out

    def match_prefix(self, token_ids) -> tuple[int, list[int], list[int]]:
        """Longest indexed block-chain prefix of ``token_ids``.  Returns
        ``(matched_tokens, blocks, hashes)`` where ``blocks`` are the
        resident physical blocks holding that prefix's KV (matched_tokens
        == len(blocks) * block_size; all full blocks)."""
        blocks: list[int] = []
        hashes: list[int] = []
        for h in self.chain_hashes(token_ids):
            b = self._index.get(h)
            if b is None:
                break
            blocks.append(b)
            hashes.append(h)
        return len(blocks) * self.block_size, blocks, hashes

    def register_prefix(self, request_id: str, token_ids) -> int:
        """Publish ``request_id``'s full prompt blocks into the prefix
        index so later prompts can adopt them.  Only positions strictly
        below ``len(token_ids) - 1`` are published (the engine re-writes
        KV at the last context position when decode starts, so the block
        holding it must stay private — see ``ServingEngine``).  First
        writer wins: a hash already indexed keeps its canonical block.
        Returns the number of newly indexed blocks."""
        a = self._held[request_id]
        bs = self.block_size
        k = max(0, (len(token_ids) - 1) // bs)  # publishable full blocks
        k = min(k, len(a.blocks))
        hashes = self.chain_hashes(token_ids)[:k]
        if a.hashes and hashes[:len(a.hashes)] != a.hashes[:k]:
            raise RuntimeError(
                f"{request_id}: prompt hash chain diverged from the "
                "chain recorded at allocation")
        added = 0
        for i in range(len(a.hashes), k):
            h, b = hashes[i], a.blocks[i]
            a.hashes.append(h)
            if h not in self._index:
                self._index[h] = b
                self._block_hash[b] = h
                added += 1
        return added

    def fork_block(self, request_id: str, logical_idx: int
                   ) -> tuple[int, int] | None:
        """Copy-on-write: give ``request_id`` a private replacement for
        the shared block at ``blocks[logical_idx]`` ahead of a divergent
        write.  Returns ``(old_block, new_block)`` so the caller can copy
        the KV page device-side, or ``None`` if the block is already
        private (refcount 1).  Raises ``RuntimeError`` when no block can
        be reclaimed for the copy."""
        a = self._held[request_id]
        old = a.blocks[logical_idx]
        if self._ref[old] == 1:
            return None
        new = self._take_block()
        self._ref[new] = 1
        self._ref[old] -= 1
        a.blocks[logical_idx] = new
        # the fork diverges this request's content from the indexed
        # chain at logical_idx; truncate its published-chain record
        del a.hashes[logical_idx:]
        a.adopted = min(a.adopted, logical_idx)
        return old, new

    def check_prefix_index(self) -> None:
        """Rebuild the prefix index from per-block content tags over all
        live (referenced + cached) blocks and assert it equals the
        incrementally maintained one — the fuzz suite's index invariant.
        Raises ``RuntimeError`` on mismatch."""
        rebuilt = {}
        live = set(self._ref) | set(self._cached)
        for b in live:
            h = self._block_hash.get(b)
            if h is not None:
                rebuilt[h] = b
        if rebuilt != self._index:
            stale = {h: b for h, b in self._index.items()
                     if rebuilt.get(h) != b}
            missing = {h: b for h, b in rebuilt.items()
                       if self._index.get(h) != b}
            raise RuntimeError(
                f"prefix index drifted: stale={stale} missing={missing}")
        if set(self._block_hash) != set(self._index.values()):
            raise RuntimeError("block hash tags are not the inverse of "
                               "the prefix index")

    # ------------------------------------------------------ block recycling

    def _take_block(self) -> int:
        """Pop a physical block for writing: free list first, then evict
        the LRU cached prefix block (dropping its index entry)."""
        if self._free_blocks:
            return self._free_blocks.pop()
        if self._cached:
            b = next(iter(self._cached))
            del self._cached[b]
            h = self._block_hash.pop(b)
            if self._index.get(h) == b:
                del self._index[h]
            return b
        raise RuntimeError("no free blocks")

    def _incref(self, block: int) -> None:
        """Adopt a shared block: bump its refcount, un-caching it if it
        was sitting at refcount 0."""
        if block in self._ref:
            self._ref[block] += 1
        else:
            self._cached.pop(block, None)
            self._ref[block] = 1

    def _decref(self, block: int) -> None:
        """Drop one reference; at zero the block goes to the cached tier
        (if it still carries an index tag) or back to the free list."""
        self._ref[block] -= 1
        if self._ref[block] > 0:
            return
        del self._ref[block]
        h = self._block_hash.get(block)
        if h is not None:
            self._cached[block] = h  # LRU tail == most recently released
        else:
            self._free_blocks.append(block)

    # ---------------------------------------------------------- invariants

    def conservation(self) -> dict:
        """Snapshot of the pool accounting the conservation invariant is
        stated over (free + cached + referenced == device pool; swap
        usage <= host pool)."""
        return {
            "n_blocks": self.n_blocks,
            "free_blocks": len(self._free_blocks),
            "cached_blocks": len(self._cached),
            "held_blocks": self.used_blocks,
            "owned_blocks": self.owned_blocks,
            "free_slots": len(self._free_slots),
            "held_slots": len(self._held),
            "n_slots": self.n_slots,
            "swapped_blocks": self.swapped_blocks_used,
            "swap_blocks": self.swap_blocks,
        }

    def assert_conserved(self) -> None:
        """Block/slot conservation: every device block is in exactly one
        of {free, cached, referenced} (scratch excluded from all three),
        per-block refcounts equal the number of live allocations holding
        the block, every slot is free or bound once, and the host pool
        is within capacity.  Raises ``RuntimeError`` with the full
        ledger on any violation — the fault-injection harness and the
        allocator fuzz suite call this after every operation."""
        errs = []
        multiplicity: dict[int, int] = {}
        for a in self._held.values():
            if len(set(a.blocks)) != len(a.blocks):
                errs.append("block appears twice in one allocation")
            for b in a.blocks:
                multiplicity[b] = multiplicity.get(b, 0) + 1
        referenced = set(multiplicity)
        free = set(self._free_blocks)
        cached = set(self._cached)
        if len(free) != len(self._free_blocks):
            errs.append("duplicate free blocks")
        if multiplicity != self._ref:
            errs.append("refcounts != live readers")
        if free & referenced:
            errs.append("block both free and referenced")
        if free & cached:
            errs.append("block both free and cached")
        if cached & referenced:
            errs.append("block both cached and referenced")
        if (len(self._free_blocks) + len(cached) + len(referenced)
                != self.n_blocks):
            errs.append("free+cached+referenced blocks != pool")
        if SCRATCH_BLOCK in free | cached | referenced:
            errs.append("scratch block entered the pool")
        for b, h in self._block_hash.items():
            if self._index.get(h) != b:
                errs.append("block hash tag without matching index entry")
                break
        if not set(self._index.values()) <= referenced | cached:
            errs.append("prefix index points at a dead block")
        if not cached <= set(self._block_hash):
            errs.append("cached block without a content tag")
        held_slots = [a.slot for a in self._held.values()]
        if sorted(self._free_slots + held_slots) != list(range(self.n_slots)):
            errs.append("slot ledger broken")
        if self.swapped_blocks_used > self.swap_blocks:
            errs.append("host swap pool over capacity")
        if set(self._held) & set(self._swapped):
            errs.append("request both resident and swapped")
        if errs:
            raise RuntimeError(
                f"KV conservation violated: {errs}; {self.conservation()}")

    # ------------------------------------------------------------ admission

    def can_admit(self, context_len: int, growth_reserve: int = 0,
                  shared_blocks: int = 0) -> bool:
        if not self._free_slots:
            return False
        need = self.blocks_for(context_len + growth_reserve) \
            - int(shared_blocks)
        if need > self.free_blocks:
            return False
        return self.owned_blocks + max(0, need) <= self.budget_blocks

    def allocate(self, request_id: str, context_len: int) -> int:
        """Claim a slot + the blocks for ``context_len`` tokens; returns
        the slot index."""
        if request_id in self._held:
            raise KeyError(f"{request_id} already holds a slot")
        if not self._free_slots:
            raise RuntimeError("no free slots")
        need = self.blocks_for(context_len)
        if need > self.free_blocks:
            raise RuntimeError(
                f"no free blocks: need {need}, have {self.free_blocks}")
        slot = self._free_slots.pop()
        blocks = [self._take_block() for _ in range(need)]
        for b in blocks:
            self._ref[b] = 1
        self._held[request_id] = BlockAllocation(slot, int(context_len),
                                                 blocks)
        return slot

    def allocate_shared(self, request_id: str, context_len: int,
                        shared_blocks: list[int],
                        shared_hashes: list[int]) -> int:
        """Claim a slot + blocks for ``context_len`` tokens, adopting
        ``shared_blocks`` (a ``match_prefix`` result: resident blocks
        holding this prompt's leading full blocks) by reference instead
        of allocating and re-filling them.  Returns the slot index."""
        if request_id in self._held:
            raise KeyError(f"{request_id} already holds a slot")
        if len(shared_blocks) != len(shared_hashes):
            raise ValueError("shared_blocks/shared_hashes length mismatch")
        if len(shared_blocks) * self.block_size > int(context_len):
            raise ValueError("shared prefix longer than the context")
        if not self._free_slots:
            raise RuntimeError("no free slots")
        need = self.blocks_for(context_len) - len(shared_blocks)
        # adopting a cached block consumes a reclaimable block too
        reclaimable = self.free_blocks \
            - sum(1 for b in shared_blocks if b in self._cached)
        if need > reclaimable:
            raise RuntimeError(
                f"no free blocks: need {need}, have {reclaimable}")
        slot = self._free_slots.pop()
        for b in shared_blocks:
            self._incref(b)
        blocks = list(shared_blocks)
        for _ in range(max(0, need)):
            b = self._take_block()
            self._ref[b] = 1
            blocks.append(b)
        self._held[request_id] = BlockAllocation(
            slot, int(context_len), blocks,
            hashes=list(shared_hashes), adopted=len(shared_blocks))
        return slot

    def grow(self, request_id: str, new_tokens: int = 1) -> bool:
        """Account for decode growth, appending blocks when the request
        crosses a block boundary.  Returns False — with NO partial
        mutation — when the growth does not fit (``max_seq_len`` hit, or
        the free pool is exhausted: capacity-forced eviction time)."""
        a = self._held[request_id]
        t_new = a.tokens + int(new_tokens)
        if t_new > self.max_seq_len:
            return False
        need = self.blocks_for(t_new) - len(a.blocks)
        if need > self.free_blocks:
            return False
        for _ in range(need):
            b = self._take_block()
            self._ref[b] = 1
            a.blocks.append(b)
        a.tokens = t_new
        return True

    def grow_upto(self, request_id: str, new_tokens: int) -> int:
        """Grow by as many of ``new_tokens`` as currently fit (bounded by
        ``max_seq_len`` and the free pool); returns the granted token
        count.  The fused multi-step decode uses this to reserve N
        tokens of KV ahead of one device call — a partial grant bounds
        that call's per-lane step budget instead of failing it."""
        granted = 0
        while granted < new_tokens and self.grow(request_id, 1):
            granted += 1
        return granted

    def release(self, request_id: str) -> int:
        """Drop the slot + this request's references (completion,
        recompute-eviction, abort).  Blocks are recycled only at
        refcount zero; indexed prefix blocks park in the cached tier."""
        a = self._held.pop(request_id)
        self._free_slots.append(a.slot)
        for b in reversed(a.blocks):
            self._decref(b)
        return a.slot

    # ----------------------------------------------------------------- swap

    def can_swap_out(self, request_id: str) -> bool:
        """Host pool headroom for this request's blocks."""
        a = self._held[request_id]
        return (self.swapped_blocks_used + len(a.blocks)
                <= self.swap_blocks)

    def swap_out(self, request_id: str, payload: Any = None) -> int:
        """Move a resident request to the host pool.  ``payload`` is the
        engine-gathered KV (opaque here); device references + slot are
        dropped, but the prefix hash chain rides along so swap_in can
        re-adopt any still-resident shared blocks.  Returns the number
        of tokens swapped."""
        if not self.can_swap_out(request_id):
            raise RuntimeError(f"host swap pool full for {request_id}")
        a = self._held.pop(request_id)
        self._free_slots.append(a.slot)
        for b in reversed(a.blocks):
            self._decref(b)
        self._swapped[request_id] = _HostAllocation(
            tokens=a.tokens, n_blocks=len(a.blocks), payload=payload,
            prefix_hashes=list(a.hashes))
        return a.tokens

    def can_swap_in(self, request_id: str, growth_reserve: int = 0) -> bool:
        return self.can_admit(self._swapped[request_id].tokens
                              + growth_reserve)

    def swap_in(self, request_id: str) -> tuple[int, Any]:
        """Restore a swapped request onto the device: re-matches its
        recorded prefix chain against the index (adopting any blocks
        that are still resident), allocates private blocks for the rest
        and returns ``(slot, payload)`` so the engine can scatter the
        saved KV back — ``adopted_blocks_of`` tells it how many leading
        blocks to skip."""
        host = self._swapped[request_id]
        if not self._free_slots:
            raise RuntimeError("no free slots")
        shared: list[int] = []
        hashes: list[int] = []
        for h in host.prefix_hashes:
            b = self._index.get(h)
            if b is None:
                break
            shared.append(b)
            hashes.append(h)
        need = self.blocks_for(host.tokens) - len(shared)
        reclaimable = self.free_blocks \
            - sum(1 for b in shared if b in self._cached)
        if need > reclaimable:
            raise RuntimeError(
                f"no free blocks: need {need}, have {reclaimable}")
        del self._swapped[request_id]
        slot = self._free_slots.pop()
        for b in shared:
            self._incref(b)
        blocks = list(shared)
        for _ in range(max(0, need)):
            b = self._take_block()
            self._ref[b] = 1
            blocks.append(b)
        self._held[request_id] = BlockAllocation(
            slot, host.tokens, blocks,
            hashes=hashes, adopted=len(shared))
        return slot, host.payload

    def drop_swapped(self, request_id: str) -> None:
        """Discard a host-side allocation (abort, or fall back to
        recompute when restoring is no longer worth it)."""
        self._swapped.pop(request_id, None)
