"""Block-table KV cache manager: paged device pool + host swap pool.

TPU adaptation of vLLM's paged block manager (DESIGN.md): KV memory is a
pool of fixed-size *token blocks* (``block_size`` tokens each).  A running
request owns

  * a *slot* — its row in the engine's decode batch (tokens / cache_len /
    block-table arrays), and
  * a *block table* — the ordered list of physical blocks holding its KV;
    logical token position ``p`` lives at block ``table[p // block_size]``,
    offset ``p % block_size``.

Physical block 0 is a reserved *scratch* block: inactive decode rows point
their tables at it, so masked lanes write harmlessly instead of corrupting
a neighbour.  Allocation is block-granular, which makes the accounting
*fragmentation-aware*: a request holding ``t`` tokens pins
``ceil(t / block_size)`` blocks, and admission is budgeted in blocks
(``budget_blocks`` — one authoritative accessor shared by ``can_admit``
and the engine's running-set selection), not in raw tokens.

Preemption is swap-based: ``swap_out`` moves a request's blocks to a host
pool (the engine attaches the gathered KV arrays as an opaque *payload*),
``swap_in`` re-allocates device blocks and returns the payload so the
engine can restore the cache without re-prefilling.  Recompute-mode
preemption is plain ``release`` (drop the KV, replay the context later).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

__all__ = ["KVCacheManager", "BlockAllocation"]

SCRATCH_BLOCK = 0


@dataclass
class BlockAllocation:
    """Device-side state of one resident request."""

    slot: int
    tokens: int
    blocks: list[int] = field(default_factory=list)


@dataclass
class _HostAllocation:
    """Host-side state of one swapped-out request."""

    tokens: int
    n_blocks: int
    payload: Any = None


class KVCacheManager:
    def __init__(self, n_slots: int, max_seq_len: int,
                 capacity_tokens: int | None = None,
                 watermark: float = 0.05,
                 block_size: int = 16,
                 swap_capacity_tokens: int | None = None):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        self.capacity_tokens = capacity_tokens or n_slots * max_seq_len
        self.watermark = watermark
        # device pool: blocks 1..n_blocks are allocatable, 0 is scratch
        self.n_blocks = -(-self.capacity_tokens // block_size)
        # host pool (swap destination), in blocks; default: 2x device
        swap_cap = (2 * self.capacity_tokens if swap_capacity_tokens is None
                    else swap_capacity_tokens)
        self.swap_blocks = -(-swap_cap // block_size)
        self._free_slots = list(range(n_slots))[::-1]
        self._free_blocks = list(range(1, self.n_blocks + 1))[::-1]
        self._held: dict[str, BlockAllocation] = {}
        self._swapped: dict[str, _HostAllocation] = {}

    # ---------------------------------------------------------------- sizing

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` (fragmentation-aware: the last
        block is pinned whole even when partially filled)."""
        return max(1, -(-int(tokens) // self.block_size))

    @property
    def pool_blocks(self) -> int:
        """Physical pool size in blocks, *including* the scratch block —
        the first dimension of the engine's paged KV tensors."""
        return self.n_blocks + 1

    @property
    def budget_blocks(self) -> int:
        """The authoritative admission budget, in blocks: total blocks
        minus the watermark reserve kept free for decode growth.  Both
        ``can_admit`` and the engine's running-set selection budget
        against this single number (previously each hand-rolled its own
        ``capacity * (1 - watermark)`` and they could drift)."""
        return int(self.n_blocks * (1.0 - self.watermark))

    @property
    def admission_budget_tokens(self) -> int:
        """``budget_blocks`` in token units (block-quantized)."""
        return self.budget_blocks * self.block_size

    # ---------------------------------------------------------------- state

    @property
    def used_tokens(self) -> int:
        """Logical tokens held on device (excludes fragmentation)."""
        return sum(a.tokens for a in self._held.values())

    @property
    def used_blocks(self) -> int:
        return sum(len(a.blocks) for a in self._held.values())

    @property
    def frag_tokens(self) -> int:
        """Tokens pinned but unused inside partially-filled last blocks."""
        return self.used_blocks * self.block_size - self.used_tokens

    @property
    def free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def swapped_tokens(self) -> int:
        return sum(a.tokens for a in self._swapped.values())

    @property
    def swapped_blocks_used(self) -> int:
        return sum(a.n_blocks for a in self._swapped.values())

    def tokens_of(self, request_id: str) -> int:
        return self._held[request_id].tokens

    def slot_of(self, request_id: str) -> int:
        return self._held[request_id].slot

    def block_table(self, request_id: str) -> list[int]:
        return list(self._held[request_id].blocks)

    def holds(self, request_id: str) -> bool:
        return request_id in self._held

    def is_swapped(self, request_id: str) -> bool:
        return request_id in self._swapped

    def swapped_tokens_of(self, request_id: str) -> int:
        return self._swapped[request_id].tokens

    # ---------------------------------------------------------- invariants

    def conservation(self) -> dict:
        """Snapshot of the pool accounting the conservation invariant is
        stated over (free + held == device pool; swap usage <= host pool)."""
        return {
            "n_blocks": self.n_blocks,
            "free_blocks": len(self._free_blocks),
            "held_blocks": self.used_blocks,
            "free_slots": len(self._free_slots),
            "held_slots": len(self._held),
            "n_slots": self.n_slots,
            "swapped_blocks": self.swapped_blocks_used,
            "swap_blocks": self.swap_blocks,
        }

    def assert_conserved(self) -> None:
        """Block/slot conservation: every device block is either free or
        held by exactly one request (scratch excluded from both), every
        slot is free or bound once, and the host pool is within capacity.
        Raises ``RuntimeError`` with the full ledger on any violation —
        the fault-injection harness calls this after every injected fault.
        """
        errs = []
        held_blocks = [b for a in self._held.values() for b in a.blocks]
        if len(self._free_blocks) + len(held_blocks) != self.n_blocks:
            errs.append("free+held blocks != pool")
        if len(set(self._free_blocks)) != len(self._free_blocks):
            errs.append("duplicate free blocks")
        if len(set(held_blocks)) != len(held_blocks):
            errs.append("block held by two requests")
        if set(self._free_blocks) & set(held_blocks):
            errs.append("block both free and held")
        if SCRATCH_BLOCK in self._free_blocks or SCRATCH_BLOCK in held_blocks:
            errs.append("scratch block entered the pool")
        held_slots = [a.slot for a in self._held.values()]
        if sorted(self._free_slots + held_slots) != list(range(self.n_slots)):
            errs.append("slot ledger broken")
        if self.swapped_blocks_used > self.swap_blocks:
            errs.append("host swap pool over capacity")
        if set(self._held) & set(self._swapped):
            errs.append("request both resident and swapped")
        if errs:
            raise RuntimeError(
                f"KV conservation violated: {errs}; {self.conservation()}")

    # ------------------------------------------------------------ admission

    def can_admit(self, context_len: int, growth_reserve: int = 0) -> bool:
        if not self._free_slots:
            return False
        need = self.blocks_for(context_len + growth_reserve)
        if need > len(self._free_blocks):
            return False
        return self.used_blocks + need <= self.budget_blocks

    def allocate(self, request_id: str, context_len: int) -> int:
        """Claim a slot + the blocks for ``context_len`` tokens; returns
        the slot index."""
        if request_id in self._held:
            raise KeyError(f"{request_id} already holds a slot")
        if not self._free_slots:
            raise RuntimeError("no free slots")
        need = self.blocks_for(context_len)
        if need > len(self._free_blocks):
            raise RuntimeError(
                f"no free blocks: need {need}, have {len(self._free_blocks)}")
        slot = self._free_slots.pop()
        blocks = [self._free_blocks.pop() for _ in range(need)]
        self._held[request_id] = BlockAllocation(slot, int(context_len),
                                                 blocks)
        return slot

    def grow(self, request_id: str, new_tokens: int = 1) -> bool:
        """Account for decode growth, appending blocks when the request
        crosses a block boundary.  Returns False — with NO partial
        mutation — when the growth does not fit (``max_seq_len`` hit, or
        the free pool is exhausted: capacity-forced eviction time)."""
        a = self._held[request_id]
        t_new = a.tokens + int(new_tokens)
        if t_new > self.max_seq_len:
            return False
        need = self.blocks_for(t_new) - len(a.blocks)
        if need > len(self._free_blocks):
            return False
        for _ in range(need):
            a.blocks.append(self._free_blocks.pop())
        a.tokens = t_new
        return True

    def grow_upto(self, request_id: str, new_tokens: int) -> int:
        """Grow by as many of ``new_tokens`` as currently fit (bounded by
        ``max_seq_len`` and the free pool); returns the granted token
        count.  The fused multi-step decode uses this to reserve N
        tokens of KV ahead of one device call — a partial grant bounds
        that call's per-lane step budget instead of failing it."""
        granted = 0
        while granted < new_tokens and self.grow(request_id, 1):
            granted += 1
        return granted

    def release(self, request_id: str) -> int:
        """Free the slot + blocks (completion, recompute-eviction, abort)."""
        a = self._held.pop(request_id)
        self._free_slots.append(a.slot)
        self._free_blocks.extend(reversed(a.blocks))
        return a.slot

    # ----------------------------------------------------------------- swap

    def can_swap_out(self, request_id: str) -> bool:
        """Host pool headroom for this request's blocks."""
        a = self._held[request_id]
        return (self.swapped_blocks_used + len(a.blocks)
                <= self.swap_blocks)

    def swap_out(self, request_id: str, payload: Any = None) -> int:
        """Move a resident request to the host pool.  ``payload`` is the
        engine-gathered KV (opaque here); device blocks + slot are freed.
        Returns the number of tokens swapped."""
        if not self.can_swap_out(request_id):
            raise RuntimeError(f"host swap pool full for {request_id}")
        a = self._held.pop(request_id)
        self._free_slots.append(a.slot)
        self._free_blocks.extend(reversed(a.blocks))
        self._swapped[request_id] = _HostAllocation(
            tokens=a.tokens, n_blocks=len(a.blocks), payload=payload)
        return a.tokens

    def can_swap_in(self, request_id: str, growth_reserve: int = 0) -> bool:
        return self.can_admit(self._swapped[request_id].tokens
                              + growth_reserve)

    def swap_in(self, request_id: str) -> tuple[int, Any]:
        """Restore a swapped request onto the device: allocates a (new)
        slot + blocks and returns ``(slot, payload)`` so the engine can
        scatter the saved KV back — no re-prefill."""
        host = self._swapped[request_id]
        if not self._free_slots:
            raise RuntimeError("no free slots")
        need = self.blocks_for(host.tokens)
        if need > len(self._free_blocks):
            raise RuntimeError(
                f"no free blocks: need {need}, have {len(self._free_blocks)}")
        del self._swapped[request_id]
        slot = self._free_slots.pop()
        blocks = [self._free_blocks.pop() for _ in range(need)]
        self._held[request_id] = BlockAllocation(slot, host.tokens, blocks)
        return slot, host.payload

    def drop_swapped(self, request_id: str) -> None:
        """Discard a host-side allocation (abort, or fall back to
        recompute when restoring is no longer worth it)."""
        self._swapped.pop(request_id, None)
