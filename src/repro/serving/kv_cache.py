"""Slot-based KV cache manager with token-capacity accounting.

TPU adaptation of vLLM's paged block manager (DESIGN.md): rather than
16-token CUDA pages with in-kernel block tables, each running request owns
a *slot* in dense (L, slots, S_max, KV, dh) cache tensors — the layout the
Pallas flash-decode kernel consumes — while admission is governed by a
global *token* budget exactly like vLLM's block accounting (a request
holds context_len tokens of budget; eviction frees them).  Swapped
requests keep their tokens on the host conceptually; the engine replays
their KV by re-prefilling (recompute preemption mode, vLLM's default).
"""

from __future__ import annotations

__all__ = ["KVCacheManager"]


class KVCacheManager:
    def __init__(self, n_slots: int, max_seq_len: int,
                 capacity_tokens: int | None = None,
                 watermark: float = 0.05):
        self.n_slots = n_slots
        self.max_seq_len = max_seq_len
        self.capacity_tokens = capacity_tokens or n_slots * max_seq_len
        self.watermark = watermark
        self._free = list(range(n_slots))[::-1]
        self._held: dict[str, tuple[int, int]] = {}  # rid -> (slot, tokens)

    # ---------------------------------------------------------------- state

    @property
    def used_tokens(self) -> int:
        return sum(t for _, t in self._held.values())

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def tokens_of(self, request_id: str) -> int:
        return self._held[request_id][1]

    def slot_of(self, request_id: str) -> int:
        return self._held[request_id][0]

    def holds(self, request_id: str) -> bool:
        return request_id in self._held

    # ------------------------------------------------------------ admission

    def can_admit(self, context_len: int, growth_reserve: int = 0) -> bool:
        if not self._free:
            return False
        budget = self.capacity_tokens * (1.0 - self.watermark)
        return self.used_tokens + context_len + growth_reserve <= budget

    def allocate(self, request_id: str, context_len: int) -> int:
        """Claim a slot + token budget; returns the slot index."""
        if request_id in self._held:
            raise KeyError(f"{request_id} already holds a slot")
        if not self._free:
            raise RuntimeError("no free slots")
        slot = self._free.pop()
        self._held[request_id] = (slot, context_len)
        return slot

    def grow(self, request_id: str, new_tokens: int = 1) -> bool:
        """Account for decode growth; False if capacity exceeded."""
        slot, t = self._held[request_id]
        if self.used_tokens + new_tokens > self.capacity_tokens:
            return False
        if t + new_tokens > self.max_seq_len:
            return False
        self._held[request_id] = (slot, t + new_tokens)
        return True

    def release(self, request_id: str) -> int:
        """Free the slot + budget (completion, eviction, abort)."""
        slot, _ = self._held.pop(request_id)
        self._free.append(slot)
        return slot
