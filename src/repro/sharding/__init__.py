"""Sharding rules: logical axes -> mesh PartitionSpecs + activation hook."""

from .context import (activation_sharding, constrain_activations,
                      gather_model, serving_sharding)
from .partitioning import (batch_axes, decode_rule_table, decode_rules,
                           kv_cache_spec, logits_spec, megatron_axes,
                           named_shardings, paged_kv_pool_spec,
                           resolve_specs, rules_for, shard_bytes_table,
                           ssm_state_spec)

__all__ = ["activation_sharding", "constrain_activations", "batch_axes",
           "decode_rule_table", "decode_rules", "gather_model",
           "kv_cache_spec", "logits_spec", "megatron_axes",
           "named_shardings", "paged_kv_pool_spec", "resolve_specs",
           "rules_for", "serving_sharding", "shard_bytes_table",
           "ssm_state_spec"]
