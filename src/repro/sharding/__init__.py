"""Sharding rules: logical axes -> mesh PartitionSpecs + activation hook."""

from .context import activation_sharding, constrain_activations
from .partitioning import (batch_axes, kv_cache_spec, logits_spec,
                           named_shardings, resolve_specs, rules_for,
                           ssm_state_spec)

__all__ = ["activation_sharding", "constrain_activations", "batch_axes",
           "kv_cache_spec", "logits_spec", "named_shardings",
           "resolve_specs", "rules_for", "ssm_state_spec"]
