"""Logical-axis -> mesh-axis resolution (per architecture family & mode).

Parameters are declared with logical axes (repro.models.layers.ParamSpec);
this module maps them to PartitionSpecs for a given mesh and execution
mode.  Three rule sets:

  * train:       tensor parallel over 'model' (+ optional FSDP: the stacked
                 'layers' dim over 'data', i.e. ZeRO-3 — GSPMD all-gathers
                 each layer's params at its scan step);
  * serve:       tensor parallel over 'model', weights replicated over
                 'data' (batch-parallel serving, small models);
  * serve_big:   like serve but with 2-D weight *storage* ('embed' over
                 'data' too) for models whose weights exceed HBM when only
                 16-way sharded (nemotron-340b, internvl2-76b); GSPMD
                 gathers each layer transiently at its scan step.

KV caches: batch over ('pod','data') when divisible (dropped for B=1
long-context); heads over 'model' when the config has >= model_parallel
KV heads, otherwise the *sequence* dim over 'model' (flash-decode LSE
combine — DESIGN.md Sec. 5).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["rules_for", "resolve_specs", "batch_axes", "kv_cache_spec",
           "ssm_state_spec", "logits_spec", "named_shardings"]


def _mesh_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh, global_batch: int | None = None):
    """Axes the global batch shards over (None when not divisible,
    e.g. batch-1 long-context decode)."""
    ax = [a for a in ("pod", "data") if a in _mesh_axes(mesh)]
    if not ax:
        return None
    if global_batch is not None:
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        if global_batch % size != 0:
            # try 'data' alone before giving up
            if global_batch % mesh.shape["data"] == 0:
                return ("data",)
            return None
    return tuple(ax)


def rules_for(cfg, mode: str, mesh: Mesh) -> dict:
    """Logical-axis -> mesh-axis (or None) mapping."""
    has_pod = "pod" in _mesh_axes(mesh)
    model_ax = "model"
    kv_shardable = cfg.n_kv_heads >= cfg.model_parallel
    rules = {
        "vocab": model_ax,
        "heads": model_ax,
        "kv": model_ax if kv_shardable else None,
        "mlp": model_ax,
        "expert": model_ax,
        "expert_mlp": None,
        "router": None,
        "ssm_inner": model_ax,
        "embed": None,
        "layers": None,
        None: None,
    }
    if mode == "train" and cfg.fsdp:
        # ZeRO-3/FSDP as 2-D weight *storage*: the non-'model' weight dim
        # shards over 'data'; GSPMD all-gathers one layer slice per scan
        # step (sharding the scanned 'layers' axis instead makes XLA hoist
        # a full-stack gather out of the loop — measured 200 GiB of temp
        # on nemotron-340b, see EXPERIMENTS.md §Dry-run).
        rules["embed"] = ("pod", "data") if has_pod else "data"
    if mode == "serve_big":
        rules["embed"] = "data"
    return rules


def resolve_specs(spec_tree, rules: dict):
    """Logical-axis tree -> PartitionSpec tree."""
    def to_pspec(axes):
        if axes is None:
            return P()
        return P(*[rules.get(a) for a in axes])
    return jax.tree.map(to_pspec, spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def kv_cache_spec(cfg, mode: str, mesh: Mesh, global_batch: int | None = None):
    """PartitionSpec for (layers, batch, seq, kv_heads, head_dim) caches."""
    b_ax = batch_axes(mesh, global_batch)
    kv_shardable = cfg.n_kv_heads >= cfg.model_parallel
    if kv_shardable:
        return P(None, b_ax, None, "model", None)
    return P(None, b_ax, "model", None, None)


def ssm_state_spec(cfg, mode: str, mesh: Mesh, global_batch: int | None = None):
    """Specs for the mamba2 state dict {ssd: (L,B,H,P,N), conv: (L,B,K,DI)}."""
    b_ax = batch_axes(mesh, global_batch)
    return {
        "ssd": P(None, b_ax, "model", None, None),   # heads over model
        "conv": P(None, b_ax, None, "model"),        # d_inner over model
    }


def logits_spec(mesh: Mesh, mode: str, global_batch: int | None = None):
    return P(batch_axes(mesh, global_batch), "model")


def named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
