"""Logical-axis -> mesh-axis resolution (per architecture family & mode).

Parameters are declared with logical axes (repro.models.layers.ParamSpec);
this module maps them to PartitionSpecs for a given mesh and execution
mode.  Three rule sets:

  * train:       tensor parallel over 'model' (+ optional FSDP: the stacked
                 'layers' dim over 'data', i.e. ZeRO-3 — GSPMD all-gathers
                 each layer's params at its scan step);
  * serve:       tensor parallel over 'model', weights replicated over
                 'data' (batch-parallel serving, small models);
  * serve_big:   like serve but with 2-D weight *storage* ('embed' over
                 'data' too) for models whose weights exceed HBM when only
                 16-way sharded (nemotron-340b, internvl2-76b); GSPMD
                 gathers each layer transiently at its scan step.

KV caches: batch over ('pod','data') when divisible (dropped for B=1
long-context); heads over 'model' when the config has >= model_parallel
KV heads, otherwise the *sequence* dim over 'model' (flash-decode LSE
combine — DESIGN.md Sec. 5).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["rules_for", "resolve_specs", "batch_axes", "kv_cache_spec",
           "ssm_state_spec", "logits_spec", "named_shardings",
           "decode_rules", "paged_kv_pool_spec"]


def _mesh_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh, global_batch: int | None = None):
    """Axes the global batch shards over (None when not divisible,
    e.g. batch-1 long-context decode)."""
    ax = [a for a in ("pod", "data") if a in _mesh_axes(mesh)]
    if not ax:
        return None
    if global_batch is not None:
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        if global_batch % size != 0:
            # try 'data' alone before giving up
            if global_batch % mesh.shape["data"] == 0:
                return ("data",)
            return None
    return tuple(ax)


def rules_for(cfg, mode: str, mesh: Mesh) -> dict:
    """Logical-axis -> mesh-axis (or None) mapping."""
    has_pod = "pod" in _mesh_axes(mesh)
    model_ax = "model"
    kv_shardable = cfg.n_kv_heads >= cfg.model_parallel
    rules = {
        "vocab": model_ax,
        "heads": model_ax,
        "heads_out": model_ax,       # Megatron row-parallel wo (psum after)
        "kv": model_ax if kv_shardable else None,
        "mlp": model_ax,
        "expert": model_ax,
        "expert_mlp": None,
        "router": None,
        "ssm_inner": model_ax,
        "embed": None,
        "layers": None,
        None: None,
    }
    if mode == "train" and cfg.fsdp:
        # ZeRO-3/FSDP as 2-D weight *storage*: the non-'model' weight dim
        # shards over 'data'; GSPMD all-gathers one layer slice per scan
        # step (sharding the scanned 'layers' axis instead makes XLA hoist
        # a full-stack gather out of the loop — measured 200 GiB of temp
        # on nemotron-340b, see EXPERIMENTS.md §Dry-run).
        rules["embed"] = ("pod", "data") if has_pod else "data"
    if mode == "serve_big":
        rules["embed"] = "data"
    return rules


def decode_rules(cfg, mesh: Mesh, axis: str = "model"):
    """Exact (bit-identical) serving-decode rule set.

    Returns ``(rules, report)``.  Unlike ``rules_for``'s train/serve
    modes, this set shards ONLY batch-like einsum dimensions — axes that
    no floating-point contraction ever crosses AND whose split leaves
    every per-slice GEMM the same shape as in the unsharded program:

      * the paged KV pool (and with it the attention einsums) over the
        kv-head dim — scores/values contract over head_dim and sequence,
        both shard-local, and each (batch, kv-head) slice is an
        identically-shaped GEMM;
      * expert weights and the (E, C, D) capacity buffer over E — the
        expert FFN einsums batch over E, one identically-shaped GEMM per
        expert;
      * the wo projection via its per-kv-group decomposition
        (models.transformer._wo_proj) — partial dots batch over groups,
        the cross-group sum runs post-gather in a fixed order.

    Everything else — wq/wk/wv, lm_head/embed, mlp, router, ssm —
    stays REPLICATED, deliberately: splitting a GEMM's output (column
    parallel) or contraction (row parallel / psum) dimension changes the
    backend's accumulation path, and the resulting last-ulp float drift
    is amplified into token divergence by discrete MoE routing and
    sampling thresholds.  Replicated projections recompute identical
    full-shape GEMMs on every shard; their outputs are sliced locally
    (exact, no collective) where a sharded consumer needs them.  This is
    the exactness/efficiency dial: flip these axes to ``axis`` (as the
    train/serve rules do) to parallelize the projection FLOPs at the
    cost of bit-identity.

    Any component whose dimension does not divide the mesh axis falls
    back to replicated (still correct, just not sharded) and is flagged
    in ``report`` so callers can surface the degradation.  The pool's
    mesh axis travels in the extra ``"pool_kv"`` rule key (not a
    parameter axis name — see ``paged_kv_pool_spec``).
    """
    tp = mesh.shape[axis]
    for a in mesh.axis_names:
        if a != axis and mesh.shape[a] != 1:
            raise ValueError(
                f"decode_rules: non-'{axis}' mesh axis {a!r} has size "
                f"{mesh.shape[a]} — the serving engine manages the batch "
                "host-side and only shards over the model axis")
    heads_ok = cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0
    expert_ok = cfg.n_experts % tp == 0 if cfg.family == "moe" else False
    rules = {
        "vocab": None,
        "heads": None,
        "heads_out": None,
        "kv": None,
        "mlp": None,
        "expert": axis if expert_ok else None,
        "expert_mlp": None,
        "router": None,
        "ssm_inner": None,
        "embed": None,
        "layers": None,
        None: None,
        "pool_kv": axis if heads_ok else None,
    }
    report = {
        "tp": tp,
        "attention": "sharded" if heads_ok else "replicated",
        "experts": ("sharded" if expert_ok else "replicated")
        if cfg.family == "moe" else "n/a",
        "vocab": "replicated",
        "mlp": "replicated",
        "ssm": "replicated" if cfg.family in ("ssm", "hybrid") else "n/a",
    }
    return rules, report


def paged_kv_pool_spec(rules: dict):
    """PartitionSpec for the (L, n_pages, page, KV, dh) paged KV pool:
    physical pages shard over the kv-head dim; the page grid itself (and
    the host-side block tables indexing it) stays shard-invariant.  Keyed
    by ``"pool_kv"`` rather than the ``"kv"`` parameter axis: the wk/wv
    *weights* stay replicated under ``decode_rules`` while the pool they
    feed is sharded (the write is a local slice of the full-head k/v)."""
    return P(None, None, None, rules.get("pool_kv"), None)


def resolve_specs(spec_tree, rules: dict):
    """Logical-axis tree -> PartitionSpec tree."""
    def to_pspec(axes):
        if axes is None:
            return P()
        return P(*[rules.get(a) for a in axes])
    return jax.tree.map(to_pspec, spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def kv_cache_spec(cfg, mode: str, mesh: Mesh, global_batch: int | None = None):
    """PartitionSpec for (layers, batch, seq, kv_heads, head_dim) caches."""
    b_ax = batch_axes(mesh, global_batch)
    kv_shardable = cfg.n_kv_heads >= cfg.model_parallel
    if kv_shardable:
        return P(None, b_ax, None, "model", None)
    return P(None, b_ax, "model", None, None)


def ssm_state_spec(cfg, mode: str, mesh: Mesh, global_batch: int | None = None):
    """Specs for the mamba2 state dict {ssd: (L,B,H,P,N), conv: (L,B,K,DI)}."""
    b_ax = batch_axes(mesh, global_batch)
    return {
        "ssd": P(None, b_ax, "model", None, None),   # heads over model
        "conv": P(None, b_ax, None, "model"),        # d_inner over model
    }


def logits_spec(mesh: Mesh, mode: str, global_batch: int | None = None):
    return P(batch_axes(mesh, global_batch), "model")


def named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
