"""Logical-axis -> mesh-axis resolution (per architecture family & mode).

Parameters are declared with logical axes (repro.models.layers.ParamSpec);
this module maps them to PartitionSpecs for a given mesh and execution
mode.  Three rule sets:

  * train:       tensor parallel over 'model' (+ optional FSDP: the stacked
                 'layers' dim over 'data', i.e. ZeRO-3 — GSPMD all-gathers
                 each layer's params at its scan step);
  * serve:       tensor parallel over 'model', weights replicated over
                 'data' (batch-parallel serving, small models);
  * serve_big:   like serve but with 2-D weight *storage* ('embed' over
                 'data' too) for models whose weights exceed HBM when only
                 16-way sharded (nemotron-340b, internvl2-76b); GSPMD
                 gathers each layer transiently at its scan step.

KV caches: batch over ('pod','data') when divisible (dropped for B=1
long-context); heads over 'model' when the config has >= model_parallel
KV heads, otherwise the *sequence* dim over 'model' (flash-decode LSE
combine — DESIGN.md Sec. 5).
"""

from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["rules_for", "resolve_specs", "batch_axes", "kv_cache_spec",
           "ssm_state_spec", "logits_spec", "named_shardings",
           "decode_rules", "decode_rule_table", "paged_kv_pool_spec",
           "megatron_axes", "shard_bytes_table"]

# The ONE Megatron axis table: every logical parameter axis that tensor
# parallelism splits, shared by the train/serve rules (``rules_for``) and
# the serving engine's ``parallel="efficient"`` decode rules
# (``decode_rule_table``).  vocab/heads/kv/mlp are column-parallel output
# dims; heads_out and the mlp w_out contraction are row-parallel (psum
# after); expert is expert-parallel; ssm_inner splits the Mamba2 inner
# projection.  Callers apply their own gating (train: static
# ``model_parallel`` config; decode: actual-tp divisibility) on top.
MEGATRON_AXES = ("vocab", "heads", "heads_out", "kv", "mlp", "expert",
                 "ssm_inner")


def megatron_axes(axis: str = "model") -> dict:
    """Base logical-axis -> mesh-axis map with every Megatron axis
    assigned to ``axis`` and everything else replicated."""
    rules = {a: None for a in ("vocab", "heads", "heads_out", "kv", "mlp",
                               "expert", "expert_mlp", "router",
                               "ssm_inner", "embed", "layers", None)}
    for a in MEGATRON_AXES:
        rules[a] = axis
    return rules


def _mesh_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def batch_axes(mesh: Mesh, global_batch: int | None = None):
    """Axes the global batch shards over (None when not divisible,
    e.g. batch-1 long-context decode)."""
    ax = [a for a in ("pod", "data") if a in _mesh_axes(mesh)]
    if not ax:
        return None
    if global_batch is not None:
        size = 1
        for a in ax:
            size *= mesh.shape[a]
        if global_batch % size != 0:
            # try 'data' alone before giving up
            if global_batch % mesh.shape["data"] == 0:
                return ("data",)
            return None
    return tuple(ax)


def rules_for(cfg, mode: str, mesh: Mesh) -> dict:
    """Logical-axis -> mesh-axis (or None) mapping."""
    has_pod = "pod" in _mesh_axes(mesh)
    rules = megatron_axes("model")
    if cfg.n_kv_heads < cfg.model_parallel:
        rules["kv"] = None
    if mode == "train" and cfg.fsdp:
        # ZeRO-3/FSDP as 2-D weight *storage*: the non-'model' weight dim
        # shards over 'data'; GSPMD all-gathers one layer slice per scan
        # step (sharding the scanned 'layers' axis instead makes XLA hoist
        # a full-stack gather out of the loop — measured 200 GiB of temp
        # on nemotron-340b, see EXPERIMENTS.md §Dry-run).
        rules["embed"] = ("pod", "data") if has_pod else "data"
    if mode == "serve_big":
        rules["embed"] = "data"
    return rules


def decode_rule_table(cfg, tp: int, axis: str = "model",
                      parallel: str = "exact"):
    """Mesh-free serving-decode rule core: ``(rules, report)`` from the
    config and an integer tensor-parallel width.  ``decode_rules`` wraps
    this with mesh validation; the memory preflight and the dry-run
    min-tp report call it directly (pure arithmetic, no devices).

    ``parallel="exact"`` — the bit-identical rule set.  It shards ONLY
    batch-like einsum dimensions — axes that no floating-point
    contraction ever crosses AND whose split leaves every per-slice GEMM
    the same shape as in the unsharded program:

      * the paged KV pool (and with it the attention einsums) over the
        kv-head dim — scores/values contract over head_dim and sequence,
        both shard-local, and each (batch, kv-head) slice is an
        identically-shaped GEMM;
      * expert weights and the (E, C, D) capacity buffer over E — the
        expert FFN einsums batch over E, one identically-shaped GEMM per
        expert;
      * the wo projection via its per-kv-group decomposition
        (models.transformer._wo_proj) — partial dots batch over groups,
        the cross-group sum runs post-gather in a fixed order.

    Everything else — wq/wk/wv, lm_head/embed, mlp, router, ssm —
    stays REPLICATED, deliberately: splitting a GEMM's output (column
    parallel) or contraction (row parallel / psum) dimension changes the
    backend's accumulation path, and the resulting last-ulp float drift
    is amplified into token divergence by discrete MoE routing and
    sampling thresholds.

    ``parallel="efficient"`` — the Megatron rule set (the SAME axis
    table ``rules_for`` uses, gated by actual-tp divisibility instead of
    the static ``model_parallel`` config): column-parallel wq/wk/wv and
    MLP up/gate, row-parallel wo/down (GSPMD emits one psum per
    attention block and one per MLP through the existing model code —
    see ``serving.sharded``), vocab-sharded lm_head/embed with
    partitioned argmax/categorical, expert-parallel MoE.  Per-token
    FLOPs genuinely shrink by ~tp at the price of bit-identity: the
    tolerance contract (``testing.assert_tokens_close``,
    docs/sharded_serving.md) replaces exactness.  When the kv heads do
    not divide, attention falls back to an explicit log-sum-exp split
    over the logical page axis (``report["attn_splits"] > 1``) so the
    pool bandwidth still scales.

    Any component whose dimension does not divide ``tp`` falls back to
    replicated (still correct, just not sharded); the parameter axes
    that fell back are listed in ``report["fallbacks"]`` so callers can
    surface the degradation (``ShardingPlan`` warns on big weights).
    The pool's mesh axis travels in the extra ``"pool_kv"`` rule key
    (not a parameter axis name — see ``paged_kv_pool_spec``).
    """
    if parallel not in ("exact", "efficient"):
        raise ValueError(f"bad parallel mode {parallel!r} "
                         "(expected 'exact' or 'efficient')")
    heads_ok = cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0
    expert_ok = cfg.n_experts % tp == 0 if cfg.family == "moe" else False
    rules = {a: None for a in megatron_axes(axis)}
    rules["expert"] = axis if expert_ok else None
    rules["pool_kv"] = axis if heads_ok else None
    fallbacks = []
    if cfg.family == "moe" and not expert_ok:
        fallbacks.append("expert")
    report = {
        "tp": tp,
        "parallel": parallel,
        "attention": "sharded" if heads_ok else "replicated",
        "experts": ("sharded" if expert_ok else "replicated")
        if cfg.family == "moe" else "n/a",
        "vocab": "replicated",
        "mlp": "replicated",
        "ssm": "replicated" if cfg.family in ("ssm", "hybrid") else "n/a",
        "attn_splits": 1,
    }
    if parallel == "efficient":
        vocab_ok = cfg.padded_vocab % tp == 0
        ff_dims = [cfg.d_ff]
        if cfg.family == "moe" and cfg.first_k_dense:
            ff_dims.append(cfg.dense_d_ff or cfg.d_ff)
        mlp_ok = all(d % tp == 0 for d in ff_dims)
        if heads_ok:
            rules["heads"] = rules["heads_out"] = rules["kv"] = axis
        else:
            fallbacks += ["heads", "heads_out", "kv"]
            # pool stays replicated; attention parallelism comes from an
            # explicit LSE split over the logical page axis instead
            report["attention"] = "lse-split" if tp > 1 else "replicated"
            report["attn_splits"] = tp
        rules["vocab"] = axis if vocab_ok else None
        rules["mlp"] = axis if mlp_ok else None
        if not vocab_ok:
            fallbacks.append("vocab")
        if not mlp_ok:
            fallbacks.append("mlp")
        report["vocab"] = "sharded" if vocab_ok else "replicated"
        report["mlp"] = "sharded" if mlp_ok else "replicated"
        if cfg.family in ("ssm", "hybrid"):
            d_inner = getattr(cfg, "d_inner", 0) or 0
            if d_inner and d_inner % tp == 0:
                rules["ssm_inner"] = axis
                report["ssm"] = "sharded"
            else:
                fallbacks.append("ssm_inner")
    report["fallbacks"] = tuple(fallbacks)
    return rules, report


def decode_rules(cfg, mesh: Mesh, axis: str = "model",
                 parallel: str = "exact"):
    """Serving-decode rule set for an actual mesh (``decode_rule_table``
    plus validation): raises if any non-``axis`` mesh axis is bigger
    than 1 — the serving engine manages the batch host-side and only
    shards over the model axis."""
    tp = mesh.shape[axis]
    for a in mesh.axis_names:
        if a != axis and mesh.shape[a] != 1:
            raise ValueError(
                f"decode_rules: non-'{axis}' mesh axis {a!r} has size "
                f"{mesh.shape[a]} — the serving engine manages the batch "
                "host-side and only shards over the model axis")
    return decode_rule_table(cfg, int(tp), axis, parallel)


def paged_kv_pool_spec(rules: dict):
    """PartitionSpec for the (L, n_pages, page, KV, dh) paged KV pool:
    physical pages shard over the kv-head dim; the page grid itself (and
    the host-side block tables indexing it) stays shard-invariant.  Keyed
    by ``"pool_kv"`` rather than the ``"kv"`` parameter axis: the wk/wv
    *weights* stay replicated under ``decode_rules`` while the pool they
    feed is sharded (the write is a local slice of the full-head k/v)."""
    return P(None, None, None, rules.get("pool_kv"), None)


def resolve_specs(spec_tree, rules: dict):
    """Logical-axis tree -> PartitionSpec tree."""
    def to_pspec(axes):
        if axes is None:
            return P()
        return P(*[rules.get(a) for a in axes])
    return jax.tree.map(to_pspec, spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def kv_cache_spec(cfg, mode: str, mesh: Mesh, global_batch: int | None = None):
    """PartitionSpec for (layers, batch, seq, kv_heads, head_dim) caches."""
    b_ax = batch_axes(mesh, global_batch)
    kv_shardable = cfg.n_kv_heads >= cfg.model_parallel
    if kv_shardable:
        return P(None, b_ax, None, "model", None)
    return P(None, b_ax, "model", None, None)


def ssm_state_spec(cfg, mode: str, mesh: Mesh, global_batch: int | None = None):
    """Specs for the mamba2 state dict {ssd: (L,B,H,P,N), conv: (L,B,K,DI)}."""
    b_ax = batch_axes(mesh, global_batch)
    return {
        "ssd": P(None, b_ax, "model", None, None),   # heads over model
        "conv": P(None, b_ax, None, "model"),        # d_inner over model
    }


def logits_spec(mesh: Mesh, mode: str, global_batch: int | None = None):
    return P(batch_axes(mesh, global_batch), "model")


def named_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _is_param_spec(x) -> bool:
    return hasattr(x, "axes") and hasattr(x, "shape") and hasattr(x, "dtype")


def shard_bytes_table(template, rules: dict, tp: int,
                      fallbacks=()) -> list[dict]:
    """Per-tensor byte accounting for a parameter template under a rule
    set: one row per ``ParamSpec`` leaf with its global byte size, the
    per-device shard size (``bytes // tp`` when any logical axis maps to
    a mesh axis, else replicated at full size), and whether replication
    was a divisibility *fallback* (``fallbacks`` is the rule report's
    list of logical axes that wanted sharding but fell back).  Pure
    arithmetic — no mesh, no devices — so the dry-run min-tp report
    prices multi-hundred-GiB configs instantly."""
    leaves = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=_is_param_spec)[0]
    rows = []
    for path, spec in leaves:
        axes = spec.axes if spec.axes is not None else ()
        sharded = any(rules.get(a) is not None for a in axes)
        nbytes = int(math.prod(spec.shape)) * np.dtype(spec.dtype).itemsize
        per_dev = nbytes // tp if sharded else nbytes
        rows.append({
            "name": jax.tree_util.keystr(path),
            "shape": tuple(int(d) for d in spec.shape),
            "axes": tuple(axes),
            "spec": str(P(*[rules.get(a) for a in axes])),
            "bytes": nbytes,
            "bytes_per_device": per_dev,
            "sharded": sharded,
            "fallback": not sharded and any(a in fallbacks for a in axes),
        })
    return rows
