"""Activation-sharding context: Megatron-style sequence parallelism hook.

Model code calls ``constrain_activations(h)`` at block boundaries; by
default it is the identity.  The launcher installs a PartitionSpec (e.g.
P(('pod','data'), 'model', None) — sequence over 'model') before lowering
big-model training steps, which caps the per-device rematerialized
residual-stream memory (DESIGN.md Sec. 5).
"""

from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()

__all__ = ["constrain_activations", "activation_sharding",
           "gather_model", "serving_sharding", "constrain_q_heads",
           "constrain_kv_heads", "attn_split_count",
           "constrain_attn_split"]


def constrain_activations(h):
    spec = getattr(_state, "spec", None)
    if spec is None:
        return h
    return jax.lax.with_sharding_constraint(h, spec)


def constrain_heads(x):
    """Constrain (batch, seq, heads, head_dim) projections to head-sharded.

    Without this, the backward of the QKV/output projections under 2-D
    (FSDP x TP) weight sharding resolves the seq-vs-heads contraction
    conflict by full replication ('Involuntary full rematerialization' —
    60 x 1.27 GiB f32 on nemotron-340b; EXPERIMENTS.md §Perf)."""
    spec = getattr(_state, "heads_spec", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_inner(x):
    """Constrain (batch, seq, d_inner) SSM projections: seq gathered,
    inner dim sharded over 'model' — resolves the seq-vs-inner GSPMD
    conflict in Mamba2 blocks under sequence parallelism (§Perf B)."""
    spec = getattr(_state, "inner_spec", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_ssm_state(x):
    """Constrain the (B, H, P, N) SSD scan carry head-sharded over 'model'
    — an unannotated zeros-init carry is otherwise replicated, forcing
    full-head re-gathers of every chunk's inputs in the scan (§Perf B.3)."""
    spec = getattr(_state, "state_spec", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_expert_buf(x):
    """Constrain the (E, C, D) MoE capacity buffer expert-sharded over
    'model' — without it GSPMD replicates the expert einsums so every
    chip computes all experts (measured 35x FLOP inflation on olmoe
    prefill, §Perf addendum)."""
    spec = getattr(_state, "expert_spec", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def gather_model(x):
    """Force a model-sharded activation back to replicated.

    Identity by default.  The serving sharded-decode plan installs a
    with_sharding_constraint(P()) here at the points where the exact
    (bit-identical) tensor-parallel decomposition must leave the sharded
    regime: before the attention output projection, after the MoE
    capacity-buffer pick, and on the final logits.  Every collective this
    inserts is a pure all-gather (relayout, no arithmetic), which is what
    keeps the sharded engine bit-identical to the single-device one —
    see docs/sharded_serving.md."""
    fn = getattr(_state, "gather_fn", None)
    if fn is None:
        return x
    return fn(x)


def constrain_q_heads(x):
    """Pin a freshly projected (B, S, H, dh) query to the serving plan's
    head sharding (identity outside an efficient-mode serving context).
    Separate from ``constrain_heads`` (the *training* hook) so the
    serving engine never perturbs train/dry-run lowering."""
    spec = getattr(_state, "q_heads_spec", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_kv_heads(x):
    """Pin a freshly projected (B, S, KV, dh) key/value to the serving
    plan's kv-head sharding — matching the paged pool's layout, so the
    pool scatter is shard-local (identity outside an efficient-mode
    serving context)."""
    spec = getattr(_state, "kv_heads_spec", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def attn_split_count() -> int:
    """Number of log-sum-exp splits of the logical page axis in paged
    decode attention (models.attention.decode_attention_paged).  1 (no
    split) outside a serving context; the efficient-mode plan installs
    tp when the kv heads don't divide the mesh, so attention still
    parallelizes via flash-style (m, l, acc) partials merged across
    splits."""
    return int(getattr(_state, "attn_splits", 1) or 1)


def constrain_attn_split(x):
    """Constrain a tensor whose axis 1 is the LSE split axis (the token
    index map, then transitively the gathered KV stripes and partial
    softmax stats) to split-sharded over 'model'."""
    spec = getattr(_state, "split_spec", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@contextlib.contextmanager
def serving_sharding(gather_fn, expert_spec=None, q_heads_spec=None,
                     kv_heads_spec=None, attn_splits=1, split_spec=None):
    """Install the serving-decode hooks around a jit trace: ``gather_fn``
    backs ``gather_model``; ``expert_spec`` (optional) backs
    ``constrain_expert_buf`` so the MoE capacity buffer stays
    expert-sharded.  The efficient-mode plan additionally installs
    ``q_heads_spec``/``kv_heads_spec`` (column-parallel projection
    outputs pinned head-sharded), and ``attn_splits``/``split_spec``
    (the LSE page-split fallback when heads don't divide).  Scoped: the
    engine enters this only around its jit call sites, so plain
    single-device engines in the same process never see the
    constraints."""
    prev_g = getattr(_state, "gather_fn", None)
    prev_e = getattr(_state, "expert_spec", None)
    prev_q = getattr(_state, "q_heads_spec", None)
    prev_kv = getattr(_state, "kv_heads_spec", None)
    prev_n = getattr(_state, "attn_splits", 1)
    prev_sp = getattr(_state, "split_spec", None)
    _state.gather_fn = gather_fn
    _state.expert_spec = expert_spec
    _state.q_heads_spec = q_heads_spec
    _state.kv_heads_spec = kv_heads_spec
    _state.attn_splits = attn_splits
    _state.split_spec = split_spec
    try:
        yield
    finally:
        _state.gather_fn = prev_g
        _state.expert_spec = prev_e
        _state.q_heads_spec = prev_q
        _state.kv_heads_spec = prev_kv
        _state.attn_splits = prev_n
        _state.split_spec = prev_sp


@contextlib.contextmanager
def activation_sharding(spec, heads_spec=None, inner_spec=None,
                        state_spec=None, expert_spec=None):
    """spec: a PartitionSpec/NamedSharding for (batch, seq, d_model)
    activations; heads_spec: for (batch, seq, heads, head_dim);
    inner_spec: for (batch, seq, d_inner) SSM projections."""
    prev = getattr(_state, "spec", None)
    prev_h = getattr(_state, "heads_spec", None)
    prev_i = getattr(_state, "inner_spec", None)
    prev_s = getattr(_state, "state_spec", None)
    prev_e = getattr(_state, "expert_spec", None)
    _state.spec = spec
    _state.heads_spec = heads_spec
    _state.inner_spec = inner_spec
    _state.state_spec = state_spec
    _state.expert_spec = expert_spec
    try:
        yield
    finally:
        _state.spec = prev
        _state.heads_spec = prev_h
        _state.inner_spec = prev_i
        _state.state_spec = prev_s
        _state.expert_spec = prev_e
