"""repro: SageSched (Gan et al., 2026) reproduction — an LLM serving
framework with uncertainty- and hybridity-aware request scheduling,
built in JAX with Pallas TPU kernels."""

__version__ = "1.0.0"
