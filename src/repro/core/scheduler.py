"""SageSched scheduler facade (paper Fig. 3 workflow).

Wires the three techniques together for use by both the real serving
engine (repro.serving.engine) and the discrete-event simulator
(repro.simulator):

    arrival  -> predictor.predict()  -> length distribution
             -> cost_model.distribution() -> cost distribution
             -> policy.priority()    -> queue index

    progress -> attained cost grows; *refreshing* policies recompute the
                priority only when the request crosses a token-bucket
                boundary (default bucket_size=200 tokens, Fig. 13b) —
                balancing rescheduling timeliness against thrashing.

    completion -> predictor.observe() feeds the history window.

The scheduler is backend-agnostic: callers ask for ``order()`` over any
subset of live request ids and apply their own admission constraints
(KV capacity, max batch) — exactly how vLLM separates policy from the
block manager.

Array-native hot path
---------------------
At cluster scale (Fig. 12) the decision loop dominates: thousands of
Gittins refreshes per second.  The scheduler therefore keeps all live
requests in a ``BatchState`` — a structure-of-arrays mirror of the
per-request objects: bucketized (n, k) cost/length distributions plus
parallel ``generated`` / ``attained`` / ``arrival`` / ``next_refresh`` /
``priority`` vectors.  ``on_progress`` only *marks rows dirty*;
``refresh()`` recomputes every dirty priority in one fused pass through a
pluggable backend (vectorized numpy, or the Pallas TPU kernel), and
``order()`` is a single ``np.lexsort`` over the priority/arrival arrays.
``priority_backend="object"`` preserves the original object-at-a-time
path as the oracle; the numpy backend is engineered to be bit-identical
to it (see docs/scheduler_internals.md).

Batch-first ingress
-------------------
Admission is batched the same way (PR 3): ``admit_batch`` registers a
whole burst of arrivals through one ``Predictor.predict_batch`` call,
one cost-model sweep, one ``BatchState.add_batch`` append and one
vectorized initial-priority evaluation; scalar ``admit`` is its B = 1
case.  The two are bit-identical — see the "Batched ingress" section of
docs/scheduler_internals.md.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .backends import (BatchView, NumpyPriorityBackend,
                       make_priority_backend)
from .cost_model import (CostDistribution, CostModel, ResourceBoundCost,
                         bucketize_support, eviction_scores)
from .policies import Policy, SageSchedPolicy, make_policy
from .predictor import LengthDistribution, Predictor, SemanticHistoryPredictor
from .robust import CalibrationMonitor, truncate_rows

__all__ = ["ScheduledRequest", "BatchState", "Scheduler"]

# Admission-time priorities are always evaluated in float64 numpy (the
# backend that is bit-identical to the scalar oracle), no matter which
# refresh backend the scheduler was configured with — see
# Scheduler._admission_priorities.
_ADMIT_BACKEND = NumpyPriorityBackend()


@dataclass
class ScheduledRequest:
    """Scheduler-side state for one live request.

    Under a batched backend the authoritative copies of ``generated`` /
    ``attained_cost`` / ``next_refresh`` / ``priority`` live in
    ``BatchState``; ``Scheduler.get`` syncs them back on access.
    """

    request_id: str
    prompt: str
    input_len: int
    arrival: float
    length_dist: LengthDistribution
    cost_dist: CostDistribution
    generated: int = 0            # output tokens produced so far
    attained_cost: float = 0.0    # cost consumed so far (cost-model units)
    next_refresh: float = float("inf")  # generated count of next refresh
    priority: float = 0.0         # cached policy priority (smaller = sooner)
    node_id: int = -1             # serving node (cluster mode; -1 = unassigned)
    tenant: str = "default"       # calibration-monitoring key
    # generated count triggering the next mid-flight posterior update
    # (inf = posterior updates disabled)
    posterior_cut: float = float("inf")
    # the admission-time prediction, kept pristine for completion-time
    # scoring (hedge weights / calibration must grade the predictor, not
    # the trivially-covering posterior); None when it was a degraded-mode
    # prior — there is nothing to grade
    pred_dist: LengthDistribution | None = field(default=None, repr=False)
    noise_rng: np.random.Generator | None = field(default=None, repr=False)


class BatchState:
    """Structure-of-arrays store for all live requests.

    Rows are dense in [0, n); removal swaps the last row into the hole.
    Columns (k) hold bucketized distributions: support is non-decreasing,
    padded entries repeat the last real support value and carry prob 0 —
    a padding every batched consumer treats as exactly inert.  Row
    capacity doubles amortized; column width auto-grows (power-of-two,
    capped at ``max_k``) so compression only kicks in past ``max_k``.
    """

    def __init__(self, k: int = 8, cap: int = 64, max_k: int = 256):
        self.k = int(k)
        self.cap = int(cap)
        self.max_k = int(max_k)
        self.n = 0
        self.cost_sup = np.zeros((self.cap, self.k))
        self.cost_probs = np.zeros((self.cap, self.k))
        self.len_sup = np.zeros((self.cap, self.k))
        self.len_probs = np.zeros((self.cap, self.k))
        self.generated = np.zeros(self.cap, np.int64)
        self.attained = np.zeros(self.cap)
        self.arrival = np.zeros(self.cap)
        self.input_len = np.zeros(self.cap, np.int64)
        self.next_refresh = np.full(self.cap, np.inf)
        self.priority = np.zeros(self.cap)
        self.base_priority = np.zeros(self.cap)
        self.node_id = np.full(self.cap, -1, np.int64)
        self.cost_mean = np.zeros(self.cap)
        self.posterior_cut = np.full(self.cap, np.inf)
        self.dirty = np.zeros(self.cap, bool)
        self.ids: list[str] = []
        self.index: dict[str, int] = {}

    # ------------------------------------------------------------- growth

    def _grow_rows(self) -> None:
        new_cap = self.cap * 2
        for name in ("cost_sup", "cost_probs", "len_sup", "len_probs"):
            old = getattr(self, name)
            arr = np.zeros((new_cap, self.k), old.dtype)
            arr[:self.cap] = old
            setattr(self, name, arr)
        for name, fill in (("generated", 0), ("attained", 0.0),
                           ("arrival", 0.0), ("input_len", 0),
                           ("next_refresh", np.inf), ("priority", 0.0),
                           ("base_priority", 0.0), ("node_id", -1),
                           ("cost_mean", 0.0), ("posterior_cut", np.inf),
                           ("dirty", False)):
            old = getattr(self, name)
            arr = np.full(new_cap, fill, old.dtype)
            arr[:self.cap] = old
            setattr(self, name, arr)
        self.cap = new_cap

    def _grow_cols(self, k_needed: int) -> None:
        k_new = self.k
        while k_new < k_needed:
            k_new *= 2
        k_new = min(k_new, self.max_k)
        if k_new <= self.k:
            return
        pad = k_new - self.k
        for name in ("cost_sup", "len_sup"):
            # edge-repeat keeps the pad-with-last-support invariant
            setattr(self, name,
                    np.pad(getattr(self, name), ((0, 0), (0, pad)),
                           mode="edge"))
        for name in ("cost_probs", "len_probs"):
            setattr(self, name,
                    np.pad(getattr(self, name), ((0, 0), (0, pad))))
        self.k = k_new

    # ------------------------------------------------------------ rows

    def add(self, rid: str, cost_dist: CostDistribution,
            length_dist: LengthDistribution, *, arrival: float,
            input_len: int, next_refresh: float, priority: float,
            base_priority: float, node_id: int = -1) -> int:
        """Append one row — semantically the B = 1 case of ``add_batch``,
        kept as direct scalar writes (no index arrays) because single
        admissions remain a hot path for non-bursty callers."""
        k_needed = max(cost_dist.support.shape[0],
                       length_dist.lengths.shape[0])
        if k_needed > self.k:
            self._grow_cols(k_needed)
        if self.n == self.cap:
            self._grow_rows()
        i = self.n
        self._write_row(self.cost_sup, self.cost_probs, i,
                        cost_dist.support, cost_dist.probs)
        self._write_row(self.len_sup, self.len_probs, i,
                        length_dist.lengths, length_dist.probs)
        self.generated[i] = 0
        self.attained[i] = 0.0
        self.arrival[i] = arrival
        self.input_len[i] = input_len
        self.next_refresh[i] = next_refresh
        self.priority[i] = priority
        self.base_priority[i] = base_priority
        self.node_id[i] = node_id
        self.cost_mean[i] = cost_dist.mean
        self.posterior_cut[i] = np.inf
        self.dirty[i] = False
        self.ids.append(rid)
        self.index[rid] = i
        self.n += 1
        return i

    def _write_row(self, sup_arr: np.ndarray, prob_arr: np.ndarray, i: int,
                   support: np.ndarray, probs: np.ndarray) -> None:
        """Write one bucketized distribution row in place (no concatenate
        allocations on the admit hot path)."""
        k0 = support.shape[0]
        if k0 <= self.k:
            sup_arr[i, :k0] = support
            sup_arr[i, k0:] = support[-1]   # repeat-last pad
            prob_arr[i, :k0] = probs
            prob_arr[i, k0:] = 0.0
        else:  # > max_k: lossy equal-mass compression
            s, p = bucketize_support(np.asarray(support, np.float64),
                                     probs, self.k)
            sup_arr[i] = s
            prob_arr[i] = p

    def add_batch(self, rids: list[str], cost_dists, length_dists, *,
                  arrivals, input_lens, next_refreshes, priorities,
                  base_priorities, node_ids) -> np.ndarray:
        """Append B rows in one pass: ONE column grow (to the widest
        distribution in the batch), ONE amortized row grow, ragged
        per-row distribution writes, then vectorized scalar-column
        writes.  State afterwards is identical to B sequential ``add``
        calls (power-of-two growth commutes with batching).  Returns the
        new row indices."""
        b = len(rids)
        if b == 0:
            return np.zeros(0, np.int64)
        k_needed = max(max(cd.support.shape[0] for cd in cost_dists),
                       max(ld.lengths.shape[0] for ld in length_dists))
        if k_needed > self.k:
            self._grow_cols(k_needed)
        while self.cap < self.n + b:
            self._grow_rows()
        i0 = self.n
        idx = np.arange(i0, i0 + b)
        for j in range(b):
            i = i0 + j
            self._write_row(self.cost_sup, self.cost_probs, i,
                            cost_dists[j].support, cost_dists[j].probs)
            self._write_row(self.len_sup, self.len_probs, i,
                            length_dists[j].lengths, length_dists[j].probs)
            self.cost_mean[i] = cost_dists[j].mean
            self.index[rids[j]] = i
        self.ids.extend(rids)
        self.generated[idx] = 0
        self.attained[idx] = 0.0
        self.arrival[idx] = arrivals
        self.input_len[idx] = input_lens
        self.next_refresh[idx] = next_refreshes
        self.priority[idx] = priorities
        self.base_priority[idx] = base_priorities
        self.node_id[idx] = node_ids
        self.posterior_cut[idx] = np.inf
        self.dirty[idx] = False
        self.n += b
        return idx

    def remove(self, rid: str) -> None:
        i = self.index.pop(rid)
        last = self.n - 1
        if i != last:
            for name in ("cost_sup", "cost_probs", "len_sup", "len_probs",
                         "generated", "attained", "arrival", "input_len",
                         "next_refresh", "priority", "base_priority",
                         "node_id", "cost_mean", "posterior_cut", "dirty"):
                arr = getattr(self, name)
                arr[i] = arr[last]
            moved = self.ids[last]
            self.ids[i] = moved
            self.index[moved] = i
        self.ids.pop()
        self.dirty[last] = False
        self.n -= 1

    def view(self, idx: np.ndarray) -> BatchView:
        if idx.shape[0] == self.n:
            idx = slice(0, self.n)  # all rows dirty: zero-copy slices
        return BatchView(
            cost_sup=self.cost_sup[idx], cost_probs=self.cost_probs[idx],
            len_sup=self.len_sup[idx], len_probs=self.len_probs[idx],
            generated=self.generated[idx], attained=self.attained[idx],
            arrival=self.arrival[idx], input_len=self.input_len[idx])


class Scheduler:
    """Predictor + cost model + policy, with bucketized priority refresh.

    priority_backend: "numpy" (default, vectorized float64 hot path),
        "pallas" (TPU kernel, interpret-mode on CPU), "object" (the
        original per-request scalar path, kept as the oracle), or a
        ``PriorityBackend`` instance.
    """

    def __init__(self,
                 predictor: Predictor | None = None,
                 cost_model: CostModel | None = None,
                 policy: "Policy | str | None" = None,
                 bucket_size: int = 200,
                 noise_weight: float = 0.0,
                 noise_max_len: int = 4096,
                 priority_backend="numpy",
                 batch_k: int = 8,
                 max_batch_k: int = 256,
                 posterior_quantile: float | None = None,
                 calibration: CalibrationMonitor | None = None,
                 conformal_widening: bool = True,
                 degraded_exit_successes: int = 4,
                 clock=time.monotonic):
        self.predictor = predictor or SemanticHistoryPredictor()
        self.cost_model = cost_model or ResourceBoundCost()
        if isinstance(policy, str):
            policy = make_policy(policy)
        self.policy = policy or SageSchedPolicy()
        self.bucket_size = max(1, bucket_size)
        self.noise_weight = noise_weight  # Fig. 11 robustness experiment
        self.noise_max_len = noise_max_len
        self.clock = clock
        self.backend = make_priority_backend(priority_backend)
        self._state = BatchState(k=batch_k, max_k=max_batch_k) \
            if self.backend is not None else None
        if getattr(self.policy, "rank_based", False) and self._state is None:
            raise ValueError(
                f"policy {self.policy.name!r} blends ranks over the whole "
                "live set and needs an array backend; "
                "priority_backend='object' has no batch view to rank over")
        # mid-flight posterior updates: truncate a request's stored
        # length/cost beliefs once it decodes past this quantile of its
        # own predicted length distribution (None = frozen-at-admission
        # beliefs, the pre-PR-10 behavior)
        if posterior_quantile is not None \
                and not 0.0 < posterior_quantile < 1.0:
            raise ValueError(
                f"posterior_quantile must be in (0, 1), got "
                f"{posterior_quantile!r}")
        self._posterior_q = posterior_quantile
        self.calibration = calibration if calibration is not None \
            else CalibrationMonitor()
        self.conformal_widening = bool(conformal_widening)
        # degraded-mode exit hysteresis: this many consecutive successful
        # predictions before trusting the predictor again (a single good
        # call after an outage must not flap the gateway's static limits)
        self.degraded_exit_successes = max(1, int(degraded_exit_successes))
        self._pred_ok_streak = 0
        self._live: dict[str, ScheduledRequest] = {}
        self._arrival_seq = 0  # tie-break for identical clock readings
        self._now = 0.0
        self.stats = {"predictions": 0, "refreshes": 0, "completions": 0,
                      "prediction_failures": 0, "posterior_updates": 0,
                      "conformal_widenings": 0}
        self.degraded = False  # last predictor call failed (see admit_batch)
        self._fallback_dist: LengthDistribution | None = None

    # ------------------------------------------------------------- lifecycle

    def _prediction_free_prior(self) -> LengthDistribution:
        """Static fallback when the predictor is unavailable: a flat
        prior over a coarse length grid up to ``noise_max_len``.  Every
        request gets the SAME distribution, so no request is ranked on
        (stale or corrupt) per-request information."""
        if self._fallback_dist is None:
            grid = np.unique(np.linspace(
                1, max(2, self.noise_max_len), 16).astype(np.int64))
            self._fallback_dist = LengthDistribution(
                grid, np.full(grid.size, 1.0 / grid.size))
        return self._fallback_dist

    def admit(self, request_id: str, prompt: str, input_len: int,
              arrival: float | None = None,
              node_id: int = -1, length_dist=None,
              tenant: str = "default") -> ScheduledRequest:
        """Register one arriving request — the B = 1 case of
        ``admit_batch`` (batch is the primitive; scalar is sugar).

        ``node_id`` tags the request with its serving node (cluster mode,
        see repro.simulator.cluster); ``order(node_id=...)`` then ranks
        one node's queue as a masked lexsort over the shared state.
        ``length_dist`` short-circuits the predictor with an already-
        computed prediction (e.g. the cost-aware router's route-time
        lookup) so the semantic-history search is not paid twice.
        ``tenant`` keys the calibration monitor's rolling statistics."""
        return self.admit_batch(
            [request_id], [prompt], [input_len],
            arrivals=None if arrival is None else [arrival],
            node_ids=node_id,
            length_dists=None if length_dist is None else [length_dist],
            tenants=[tenant])[0]

    def admit_batch(self, request_ids, prompts, input_lens, *,
                    arrivals=None, node_ids=-1,
                    length_dists=None, tenants=None) -> list[ScheduledRequest]:
        """Admit a burst of arrivals in one batched pass: one
        ``predict_batch`` over the (unique) prompts, one cost-model
        pushforward sweep, one ``BatchState.add_batch`` append (single
        capacity grow), and one vectorized initial-priority evaluation.
        Bit-identical to the equivalent sequence of scalar ``admit``
        calls — asserted column-for-column in tests/test_batch_ingress.py.

        ``arrivals=None`` stamps the whole burst with ONE clock reading
        (a scalar-admit loop would read the clock per request — pass
        explicit arrivals when that distinction matters).  ``node_ids``
        is a scalar or per-request sequence.  ``length_dists`` may carry
        route-time predictions; ``None`` entries are predicted here, in
        one batched call.  Duplicate request ids (against live state or
        within the burst) raise before any state is mutated.
        """
        rids = list(request_ids)
        b = len(rids)
        if b == 0:
            return []
        seen: set[str] = set()
        for rid in rids:
            if rid in self._live or rid in seen:
                raise KeyError(f"request {rid!r} already admitted")
            seen.add(rid)
        prompts = list(prompts)
        input_lens = [int(il) for il in input_lens]
        if arrivals is None:
            now = self.clock()
            arrivals = [now] * b
        else:
            arrivals = [float(a) for a in arrivals]
        if np.ndim(node_ids) == 0:
            node_ids = [int(node_ids)] * b
        else:
            node_ids = [int(nd) for nd in node_ids]
        length_dists = [None] * b if length_dists is None \
            else list(length_dists)
        tenants = ["default"] * b if tenants is None \
            else [str(t) for t in tenants]
        missing = [j for j in range(b) if length_dists[j] is None]
        degraded_fill: set[int] = set()
        if missing:
            # predict_many: the batched path when it is authoritative for
            # this predictor class, else a scalar-predict loop (honors
            # subclasses that override only the scalar method)
            try:
                preds = self.predictor.predict_many(
                    [prompts[j] for j in missing],
                    [input_lens[j] for j in missing])
                # exit hysteresis: one healthy call after an outage must
                # not flap the degraded flag (and with it the gateway's
                # static limits); require a streak of clean predictions
                self._pred_ok_streak += len(missing)
                if self.degraded \
                        and self._pred_ok_streak >= self.degraded_exit_successes:
                    self.degraded = False
            except Exception:
                # predictor / history store down: degrade to a static
                # prediction-free prior instead of failing admission —
                # Gittins over a flat prior carries no per-request
                # information, so ordering falls back to arrival-driven
                # behavior; the gateway reads ``degraded`` and switches
                # its shed policy to FCFS tail-drop + static limits
                self.stats["prediction_failures"] += len(missing)
                self.degraded = True
                self._pred_ok_streak = 0
                preds = [self._prediction_free_prior() for _ in missing]
                degraded_fill = set(missing)
            for j, d in zip(missing, preds):
                length_dists[j] = d
            self.stats["predictions"] += len(missing)
        # the pristine admission-time prediction, captured BEFORE any
        # widening / noise mixing: completion-time scoring (calibration,
        # hedge weights) must grade the predictor's own output, not the
        # scheduler's defensive transformations of it.  Degraded-mode
        # priors carry no per-request information — nothing to grade.
        pred_dists = [None if j in degraded_fill else length_dists[j]
                      for j in range(b)]
        if self.conformal_widening:
            # conformal widening: tenants whose realized lengths have
            # been escaping the predicted coverage band get their next
            # admissions mixed toward the flat prior (deterministic, so
            # batch/scalar admission parity is preserved)
            wcache: dict[str, float] = {}
            for j in range(b):
                if j in degraded_fill:
                    continue
                t = tenants[j]
                w = wcache.get(t)
                if w is None:
                    w = wcache[t] = self.calibration.widen_weight(t)
                if w > 0.0:
                    length_dists[j] = length_dists[j].mix_uniform(
                        w, self.noise_max_len)
                    self.stats["conformal_widenings"] += 1
        if self.noise_weight > 0.0:  # Fig. 11 robustness experiment
            length_dists = [ld.mix_uniform(self.noise_weight,
                                           self.noise_max_len)
                            for ld in length_dists]
        cost_dists = self.cost_model.distribution_batch(input_lens,
                                                        length_dists)
        q = self._posterior_q
        srs: list[ScheduledRequest] = []
        for j in range(b):
            # encode arrival order into the float so FCFS ties stay stable
            self._arrival_seq += 1
            sr = ScheduledRequest(
                request_id=rids[j], prompt=prompts[j],
                input_len=input_lens[j],
                arrival=arrivals[j] + self._arrival_seq * 1e-9,
                length_dist=length_dists[j], cost_dist=cost_dists[j],
                node_id=node_ids[j], tenant=tenants[j],
                pred_dist=pred_dists[j])
            if q is not None:
                # first mid-flight posterior trigger: the q-quantile of
                # the stored (post-widening) belief
                sr.posterior_cut = float(length_dists[j].quantile(q))
            srs.append(sr)
        pol = self.policy
        rank_based = getattr(pol, "rank_based", False)
        st = self._state
        for sr in srs:
            self._live[sr.request_id] = sr
        if st is None:
            for sr in srs:  # object backend: the eager scalar oracle
                sr.priority = pol.priority(sr)
                sr.next_refresh = pol.next_boundary(sr, self.bucket_size)
            return srs
        if b == 1 and not rank_based:
            # single admission: direct scalar writes, no index arrays —
            # this keeps the ``admit`` sugar as cheap as the pre-batch
            # scalar path for non-bursty callers
            sr = srs[0]
            aging = getattr(pol, "time_varying", False) \
                and hasattr(pol, "base_priority") \
                and hasattr(pol, "apply_age")
            if aging:
                # one index evaluation, not two: derive the discounted
                # priority from the cached base instead of recomputing
                base = pol.base_priority(sr)
                sr.priority = float(pol.apply_age(
                    base, sr.arrival, getattr(pol, "now", self._now)))
            else:
                sr.priority = pol.priority(sr)
                base = sr.priority
            sr.next_refresh = pol.next_boundary(sr, self.bucket_size)
            i = st.add(sr.request_id, sr.cost_dist, sr.length_dist,
                       arrival=sr.arrival, input_len=sr.input_len,
                       next_refresh=sr.next_refresh, priority=sr.priority,
                       base_priority=base, node_id=sr.node_id)
            st.posterior_cut[i] = sr.posterior_cut
            return srs
        if pol.has_boundary_batch:
            nrefresh = pol.next_boundary_batch(np.zeros(b, np.int64),
                                               self.bucket_size)
        else:
            nrefresh = np.array([pol.next_boundary(sr, self.bucket_size)
                                 for sr in srs], np.float64)
        for sr, nr in zip(srs, nrefresh):
            sr.next_refresh = float(nr)
        idx = st.add_batch(
            rids, cost_dists, length_dists,
            arrivals=[sr.arrival for sr in srs], input_lens=input_lens,
            next_refreshes=nrefresh, priorities=np.zeros(b),
            base_priorities=np.zeros(b), node_ids=node_ids)
        st.posterior_cut[idx] = [sr.posterior_cut for sr in srs]
        base, prio = self._admission_priorities(srs, idx)
        st.base_priority[idx] = base
        st.priority[idx] = prio
        for sr, p in zip(srs, prio):
            sr.priority = float(p)
        if rank_based:
            # rank-blending policies score against the WHOLE live set:
            # any membership change invalidates every cached priority
            st.dirty[:st.n] = True
        return srs

    def _admission_priorities(self, srs, idx: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Initial (base, priority) vectors for freshly admitted rows.

        Bursts go through the policy's batched path when it has one; the
        batched evaluators run on ``_ADMIT_BACKEND`` (float64 numpy)
        regardless of the configured refresh backend, because admission
        priorities are defined against the scalar oracle — the numpy
        batch path is engineered bit-identical to it, while e.g. the
        float32 Pallas kernel is not.  Scalar admits (B = 1) and
        policies without a batch path take the scalar oracle directly.
        """
        pol = self.policy
        st = self._state
        aging = getattr(pol, "time_varying", False) \
            and hasattr(pol, "base_priority") and hasattr(pol, "apply_age")
        now = getattr(pol, "now", self._now)
        if aging:
            if pol.has_batch and hasattr(pol, "base_priority_batch"):
                base = np.asarray(pol.base_priority_batch(
                    st.view(idx), _ADMIT_BACKEND), np.float64)
            else:
                # one index evaluation, not two: derive the discounted
                # priority from the cached base instead of recomputing
                base = np.array([pol.base_priority(sr) for sr in srs],
                                np.float64)
            return base, np.asarray(pol.apply_age(base, st.arrival[idx],
                                                  now), np.float64)
        if pol.has_batch:
            prio = np.asarray(pol.priority_batch(st.view(idx),
                                                 _ADMIT_BACKEND), np.float64)
        else:
            prio = np.array([pol.priority(sr) for sr in srs], np.float64)
        return prio, prio

    def assign_node(self, request_id: str, node_id: int) -> None:
        """(Re-)bind a live request to a serving node — the router's write
        path (initial placement, or migration between nodes)."""
        sr = self._live[request_id]
        sr.node_id = node_id
        if self._state is not None:
            self._state.node_id[self._state.index[request_id]] = node_id

    def outstanding_by_node(self, n_nodes: int) -> np.ndarray:
        """(n_nodes,) predicted *remaining* cost per node: one masked
        bincount over the shared state (admission-time cost mean minus
        attained cost, floored at 0).  Rows with ``node_id`` outside
        [0, n_nodes) — unassigned requests — are excluded.  This is the
        cluster-introspection surface (load dashboards, migration
        policies); ``CostAwareRouter`` keeps its own admit-time
        accounting instead, so routing decisions stay identical between
        shared-state and per-node-fanout modes and cover requests that
        are routed but not yet admitted."""
        st = self._state
        if st is None:
            out = np.zeros(n_nodes)
            for sr in self._live.values():
                if 0 <= sr.node_id < n_nodes:
                    out[sr.node_id] += max(
                        sr.cost_dist.mean - sr.attained_cost, 0.0)
            return out
        self.refresh()
        nid = st.node_id[:st.n]
        ok = (nid >= 0) & (nid < n_nodes)
        rem = np.maximum(st.cost_mean[:st.n] - st.attained[:st.n], 0.0)
        return np.bincount(nid[ok], weights=rem[ok], minlength=n_nodes)

    def on_progress(self, request_id: str, generated: int) -> None:
        """Report that ``generated`` output tokens now exist.  Under a
        batched backend this only *marks the row dirty* when it crosses
        its refresh boundary; the recomputation happens wholesale in
        ``refresh()``.  The object backend keeps the original eager
        per-request recompute (cost buckets for SageSched, quantum edges
        for FastServe)."""
        sr = self._live[request_id]
        if generated == sr.generated:
            return
        sr.generated = generated
        q = self._posterior_q
        st = self._state
        if st is not None:
            i = st.index[request_id]
            st.generated[i] = generated
            if (self.policy.refreshing and generated >= st.next_refresh[i]) \
                    or (q is not None and generated >= st.posterior_cut[i]):
                st.dirty[i] = True
            return
        refresh_due = self.policy.refreshing and generated >= sr.next_refresh
        posterior_due = q is not None and generated >= sr.posterior_cut
        if not (refresh_due or posterior_due):
            return
        sr.attained_cost = self.cost_model.attained(sr.input_len, generated)
        if posterior_due:
            # object backend truncates eagerly; the batched backend does
            # the same work wholesale in refresh().  Both paths see one
            # progress batch per refresh in the engine/simulator loops,
            # so chained truncations stay bit-identical.
            self._posterior_scalar(sr)
        sr.priority = self.policy.priority(sr)
        sr.next_refresh = self.policy.next_boundary(sr, self.bucket_size)
        self.stats["refreshes"] += 1

    def on_progress_many(self, request_ids, generated) -> None:
        """Vectorized ``on_progress`` over parallel id/count sequences:
        one fancy-indexed write + dirty-mark under a batched backend."""
        st = self._state
        if st is None:
            for rid, g in zip(request_ids, generated):
                self.on_progress(rid, int(g))
            return
        ids = list(request_ids)
        if not ids:
            return
        idx = np.fromiter((st.index[r] for r in ids), np.int64, len(ids))
        gens = np.asarray(generated, np.int64)
        st.generated[idx] = gens
        if self.policy.refreshing:
            st.dirty[idx] |= gens >= st.next_refresh[idx]
        if self._posterior_q is not None:
            st.dirty[idx] |= gens >= st.posterior_cut[idx]

    def refresh(self) -> int:
        """Recompute every dirty priority in one batched pass.  Returns
        the number of rows refreshed.  No-op on the object backend (it
        refreshes eagerly in ``on_progress``)."""
        st = self._state
        if st is None or st.n == 0:
            return 0
        d = st.dirty[:st.n]
        if not d.any():
            return 0
        pol = self.policy
        idx = np.flatnonzero(d)
        if getattr(pol, "rank_based", False):
            # rank blending is a function of the whole live set — one
            # dirty row means every rank can shift
            idx = np.arange(st.n)
        st.dirty[:st.n] = False
        st.attained[idx] = self.cost_model.attained_batch(
            st.input_len[idx], st.generated[idx])
        if self._posterior_q is not None:
            self._posterior_update(idx)
        if pol.has_batch:
            view = st.view(idx)
            if getattr(pol, "time_varying", False) \
                    and hasattr(pol, "base_priority_batch"):
                base = pol.base_priority_batch(view, self.backend)
                st.base_priority[idx] = base
                st.priority[idx] = pol.apply_age(base, st.arrival[idx],
                                                 self._now)
            else:
                st.priority[idx] = pol.priority_batch(view, self.backend)
        else:
            # scalar fallback: custom policies without a batch path
            for i in idx:
                sr = self._live[st.ids[i]]
                sr.generated = int(st.generated[i])
                sr.attained_cost = float(st.attained[i])
                st.priority[i] = pol.priority(sr)
        if not pol.has_boundary_batch:
            # custom scalar boundary without a batch override: honor it
            for i in idx:
                sr = self._live[st.ids[i]]
                sr.generated = int(st.generated[i])
                st.next_refresh[i] = pol.next_boundary(sr, self.bucket_size)
        else:
            st.next_refresh[idx] = pol.next_boundary_batch(
                st.generated[idx], self.bucket_size)
        self.stats["refreshes"] += int(idx.size)
        return int(idx.size)

    # ------------------------------------------------- mid-flight posteriors

    def _posterior_fallback(self, generated: int) -> LengthDistribution:
        """Tail belief for a request that has outrun its ENTIRE predicted
        support: a flat prior over a grid reaching past the current
        position (never NaN, never zero-mass — ``mix_uniform(1.0, ...)``
        lays a uniform grid up to at least 2x the attained length, and
        ``truncate`` keeps its strictly-larger points)."""
        point = LengthDistribution(np.array([generated + 1], np.int64),
                                   np.array([1.0]))
        flat = point.mix_uniform(
            1.0, max(self.noise_max_len, 2 * (generated + 1)))
        out = flat.truncate(generated)
        assert out is not None  # grid max > generated by construction
        return out

    def _posterior_scalar(self, sr: ScheduledRequest) -> None:
        """Object-backend posterior update: condition the stored beliefs
        on (length > generated, cost > attained) via the compact
        ``truncate`` oracles; the batched ``_posterior_update`` is
        engineered bit-identical to this."""
        g = int(sr.generated)
        new_len = sr.length_dist.truncate(g)
        new_cost = sr.cost_dist.truncate(sr.attained_cost)
        if new_len is None or new_cost is None:
            # prediction exhausted: rebuild from the flat tail prior
            new_len = self._posterior_fallback(g)
            new_cost = self.cost_model.distribution(
                sr.input_len, new_len.lengths, new_len.probs)
        sr.length_dist = new_len
        sr.cost_dist = new_cost
        sr.posterior_cut = float(new_len.quantile(self._posterior_q))
        self.stats["posterior_updates"] += 1

    def _posterior_update(self, idx: np.ndarray) -> None:
        """Batched posterior update over the rows in ``idx`` that crossed
        their posterior cut: ONE vectorized ``truncate_rows`` pass over
        the (n, k) length and cost blocks (supports stay absolute; dead
        columns carry exact-0 probs, inert to every batched consumer),
        then a vectorized requantile for the next cut.  Rows whose whole
        predicted mass is already behind them fall back to the same
        scalar flat-tail rebuild as the object backend.  Requires
        ``st.attained`` to be current for the rows (refresh() updates it
        first)."""
        st = self._state
        q = self._posterior_q
        hit = st.generated[idx] >= st.posterior_cut[idx]
        if not hit.any():
            return
        rows = idx[hit]
        gens = st.generated[rows].astype(np.float64)
        new_len, len_ex = truncate_rows(st.len_sup[rows],
                                        st.len_probs[rows], gens)
        new_cost, cost_ex = truncate_rows(st.cost_sup[rows],
                                          st.cost_probs[rows],
                                          st.attained[rows])
        ex = len_ex | cost_ex
        ok_rows = rows[~ex]
        if ok_rows.size:
            st.len_probs[ok_rows] = new_len[~ex]
            st.cost_probs[ok_rows] = new_cost[~ex]
            # sequential cumsum mean / quantile: bit-identical to the
            # scalar oracles (dead columns add exact 0.0)
            st.cost_mean[ok_rows] = np.cumsum(
                st.cost_sup[ok_rows] * st.cost_probs[ok_rows],
                axis=1)[:, -1]
            cdf = np.cumsum(st.len_probs[ok_rows], axis=1)
            qi = np.minimum((cdf < q).sum(axis=1), st.k - 1)
            st.posterior_cut[ok_rows] = st.len_sup[
                ok_rows, qi]
        for i in rows[ex]:
            g = int(st.generated[i])
            ld = self._posterior_fallback(g)
            cd = self.cost_model.distribution(int(st.input_len[i]),
                                              ld.lengths, ld.probs)
            k_needed = max(ld.lengths.shape[0], cd.support.shape[0])
            if k_needed > st.k:
                st._grow_cols(k_needed)
            st._write_row(st.len_sup, st.len_probs, i, ld.lengths, ld.probs)
            st._write_row(st.cost_sup, st.cost_probs, i,
                          cd.support, cd.probs)
            st.cost_mean[i] = cd.mean
            st.posterior_cut[i] = float(ld.quantile(q))
        self.stats["posterior_updates"] += int(rows.size)

    def tokens_to_refresh(self, request_id: str) -> float:
        """Output tokens until this request's next priority refresh OR
        posterior update, whichever comes first (simulator fast-forward
        bound — fast-forwarding past a posterior cut would skip the
        belief update that reorders the queue)."""
        st = self._state
        if st is not None:
            self.refresh()
            i = st.index[request_id]
            bound = st.next_refresh[i]
            if self._posterior_q is not None:
                bound = min(bound, st.posterior_cut[i])
            return float(bound - st.generated[i])
        sr = self._live[request_id]
        bound = sr.next_refresh
        if self._posterior_q is not None:
            bound = min(bound, sr.posterior_cut)
        return bound - sr.generated

    def min_tokens_to_refresh(self, request_ids) -> float:
        """Vectorized min over ``tokens_to_refresh`` (simulator hot path)."""
        st = self._state
        if st is None:
            return min(self.tokens_to_refresh(r) for r in request_ids)
        self.refresh()
        idx = np.fromiter((st.index[r] for r in request_ids), np.int64,
                          len(request_ids))
        bounds = st.next_refresh[idx]
        if self._posterior_q is not None:
            bounds = np.minimum(bounds, st.posterior_cut[idx])
        return float(np.min(bounds - st.generated[idx]))

    def on_complete(self, request_id: str, output_len: int) -> None:
        """Request finished: feed the predictor's history, grade the
        admission-time prediction (calibration window + hedge weights)
        and drop state."""
        sr = self._live.pop(request_id)
        self.predictor.observe(sr.prompt, sr.input_len, output_len)
        if sr.pred_dist is not None:
            self.calibration.observe(sr.tenant, sr.pred_dist, output_len)
        if hasattr(self.policy, "observe_outcome"):
            # hedging controllers race their experts on realized error;
            # pred_dist=None (degraded-mode prior) is a no-op for them
            self.policy.observe_outcome(sr.pred_dist, output_len)
            self.stats["hedge"] = self.policy.snapshot()
        if self._state is not None:
            self._state.remove(request_id)
            if getattr(self.policy, "rank_based", False) and self._state.n:
                self._state.dirty[:self._state.n] = True
        self.stats["completions"] += 1

    def on_abort(self, request_id: str) -> None:
        if self._live.pop(request_id, None) is not None \
                and self._state is not None:
            self._state.remove(request_id)
            if getattr(self.policy, "rank_based", False) and self._state.n:
                self._state.dirty[:self._state.n] = True

    # ------------------------------------------------------------- queries

    def get(self, request_id: str) -> ScheduledRequest:
        sr = self._live[request_id]
        st = self._state
        if st is not None:
            self.refresh()
            i = st.index[request_id]
            sr.generated = int(st.generated[i])
            sr.priority = float(st.priority[i])
            sr.attained_cost = float(st.attained[i])
            sr.next_refresh = float(st.next_refresh[i])
            sr.posterior_cut = float(st.posterior_cut[i])
        return sr

    def __contains__(self, request_id: str) -> bool:
        return request_id in self._live

    def __len__(self) -> int:
        return len(self._live)

    @property
    def preemptive(self) -> bool:
        return self.policy.preemptive

    @property
    def posterior_quantile(self) -> float | None:
        return self._posterior_q

    @property
    def runtime_refreshing(self) -> bool:
        """Whether per-iteration progress can change priorities: true for
        refreshing policies AND whenever mid-flight posteriors are on (a
        posterior cut reorders the queue even under a frozen policy) —
        the simulator keys its fast-forward decision on this, not on
        ``policy.refreshing`` alone."""
        return self.policy.refreshing or self._posterior_q is not None

    def calibration_summary(self) -> dict:
        """Per-tenant rolling calibration table (see
        ``robust.CalibrationMonitor.summary``) — the surface the engine
        metrics and the gateway summary re-export."""
        return self.calibration.summary()

    def set_now(self, now: float) -> None:
        """Inject the current (sim or wall) time; time-varying policies
        (aging) re-apply their discount — a single vectorized pass under
        a batched backend, no index recomputation."""
        self._now = now
        if not getattr(self.policy, "time_varying", False):
            return
        self.policy.now = now
        st = self._state
        if st is None:
            for sr in self._live.values():
                sr.priority = self.policy.priority(sr)
            return
        if not st.n:
            return
        self.refresh()
        pol = self.policy
        # the vectorized discount is only valid when refresh() maintains
        # st.base_priority — i.e. the policy has the full batched aging
        # surface; otherwise the cached base is stale admit-time data
        if hasattr(pol, "apply_age") and hasattr(pol, "base_priority_batch") \
                and pol.has_batch:
            st.priority[:st.n] = pol.apply_age(
                st.base_priority[:st.n], st.arrival[:st.n], now)
        else:  # scalar-only time-varying policy: loop the oracle
            for i in range(st.n):
                sr = self._live[st.ids[i]]
                sr.generated = int(st.generated[i])
                sr.attained_cost = float(st.attained[i])
                st.priority[i] = pol.priority(sr)

    def order(self, request_ids=None, *, running=None,
              hysteresis: float = 1.0, pin_running: bool = False,
              node_id: int | None = None) -> list[str]:
        """Request ids sorted by priority (smaller first, arrival ties).

        running/hysteresis/pin_running implement the callers' admission
        semantics in one place: ids in ``running`` either get their
        priority scaled by ``hysteresis`` (preemptive anti-thrashing,
        Sec. 3.3) or pinned ahead of everything (``pin_running``,
        non-preemptive engines).  Under a batched backend this is one
        ``np.lexsort`` over the state arrays.

        node_id restricts the ranking to one serving node's requests — a
        masked lexsort over the cluster-shared state (ignored when
        ``request_ids`` is given explicitly).
        """
        st = self._state
        if st is None:
            return self._order_object(request_ids, running, hysteresis,
                                      pin_running, node_id)
        self.refresh()
        if request_ids is None and node_id is not None:
            nidx = np.flatnonzero(st.node_id[:st.n] == node_id)
            ids = [st.ids[i] for i in nidx]
            prio = st.priority[nidx]
            arr = st.arrival[nidx]
        elif request_ids is None:
            ids = st.ids[:st.n]
            prio = st.priority[:st.n].copy()
            arr = st.arrival[:st.n]
        else:
            ids = list(request_ids)
            idx = np.fromiter((st.index[r] for r in ids), np.int64, len(ids))
            prio = st.priority[idx]
            arr = st.arrival[idx]
        if running:
            rmask = np.fromiter((r in running for r in ids), bool, len(ids))
            if pin_running:
                prio[rmask] = -np.inf
            else:
                prio[rmask] *= hysteresis
        # permute through an object array: ~10x faster than indexing a
        # python list with numpy int64 scalars at 10k-deep queues
        id_arr = np.empty(len(ids), object)
        id_arr[:] = ids
        return id_arr[np.lexsort((arr, prio))].tolist()

    def eviction_order(self, request_ids, *, held_tokens,
                       swap_cost=None, memory_weight: float = 0.0
                       ) -> list[str]:
        """Rank ``request_ids`` for *capacity-forced eviction*: the first
        id is the best victim.  With ``memory_weight = 0`` this is
        exactly ``order()`` reversed (evict the least urgent — the vLLM
        baseline).  A positive weight adds the paper's memory half of the
        hybrid service cost: among similarly-urgent candidates, prefer
        victims whose KV is cheap to restore (small held footprint /
        swap IO), because the preemption's true cost includes paying
        that IO on readmission.  Shared by the real engine and the
        simulator so both layers evict under ONE preemption cost model.

        held_tokens: mapping rid -> resident KV tokens the eviction
        would actually free.  Under copy-on-write prefix sharing the
        engine passes *owned* (refcount-weighted) tokens —
        ``KVCacheManager.owned_tokens_of`` — so a request holding a
        widely shared prefix ranks as a cheap-to-keep victim: evicting
        it frees almost nothing.  Fractional values are fine (the math
        below is float throughout); for private allocations owned ==
        block-aligned held tokens and the ranking is unchanged.
        swap_cost: callable tokens -> predicted restore cost (e.g.
        ``ServiceModel.swap_time``); None falls back to held tokens
        (∝ KV bytes) as the proxy — swap_time is affine in bytes, so
        the ranking is identical whenever every candidate shares one
        node spec.
        """
        ids = list(request_ids)
        if not ids:
            return []
        ordered = self.order(ids)            # most urgent first
        if memory_weight <= 0.0 or len(ids) == 1:
            return ordered[::-1]
        rank = {rid: j for j, rid in enumerate(ordered)}
        ranks = np.fromiter((rank[r] for r in ids), np.float64, len(ids))
        held = np.fromiter((float(held_tokens[r]) for r in ids),
                           np.float64, len(ids))
        costs = np.array([swap_cost(t) for t in held], np.float64) \
            if swap_cost is not None else held
        scores = eviction_scores(ranks, costs, memory_weight)
        # ties (same score) break toward the less urgent candidate
        sort = np.lexsort((-ranks, -scores))
        return [ids[i] for i in sort]

    def _order_object(self, request_ids, running, hysteresis,
                      pin_running, node_id=None) -> list[str]:
        if request_ids is None:
            srs = [sr for sr in self._live.values()
                   if node_id is None or sr.node_id == node_id]
        else:
            srs = [self._live[r] for r in request_ids]
        if running:
            if pin_running:
                srs.sort(key=lambda s: (
                    (-np.inf, s.arrival) if s.request_id in running
                    else (s.priority, s.arrival)))
            else:
                srs.sort(key=lambda s: (
                    s.priority * (hysteresis if s.request_id in running
                                  else 1.0), s.arrival))
        else:
            srs.sort(key=lambda s: (s.priority, s.arrival))
        return [s.request_id for s in srs]
