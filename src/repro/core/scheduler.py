"""SageSched scheduler facade (paper Fig. 3 workflow).

Wires the three techniques together for use by both the real serving
engine (repro.serving.engine) and the discrete-event simulator
(repro.simulator):

    arrival  -> predictor.predict()  -> length distribution
             -> cost_model.distribution() -> cost distribution
             -> policy.priority()    -> queue index

    progress -> attained cost grows; *refreshing* policies recompute the
                priority only when the request crosses a token-bucket
                boundary (default bucket_size=200 tokens, Fig. 13b) —
                balancing rescheduling timeliness against thrashing.

    completion -> predictor.observe() feeds the history window.

The scheduler is backend-agnostic: callers ask for ``order()`` over any
subset of live request ids and apply their own admission constraints
(KV capacity, max batch) — exactly how vLLM separates policy from the
block manager.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .cost_model import CostDistribution, CostModel, ResourceBoundCost
from .policies import Policy, SageSchedPolicy
from .predictor import LengthDistribution, Predictor, SemanticHistoryPredictor

__all__ = ["ScheduledRequest", "Scheduler"]


@dataclass
class ScheduledRequest:
    """Scheduler-side state for one live request."""

    request_id: str
    prompt: str
    input_len: int
    arrival: float
    length_dist: LengthDistribution
    cost_dist: CostDistribution
    generated: int = 0            # output tokens produced so far
    attained_cost: float = 0.0    # cost consumed so far (cost-model units)
    next_refresh: float = float("inf")  # generated count of next refresh
    priority: float = 0.0         # cached policy priority (smaller = sooner)
    noise_rng: np.random.Generator | None = field(default=None, repr=False)


class Scheduler:
    """Predictor + cost model + policy, with bucketized priority refresh."""

    def __init__(self,
                 predictor: Predictor | None = None,
                 cost_model: CostModel | None = None,
                 policy: Policy | None = None,
                 bucket_size: int = 200,
                 noise_weight: float = 0.0,
                 noise_max_len: int = 4096,
                 clock=time.monotonic):
        self.predictor = predictor or SemanticHistoryPredictor()
        self.cost_model = cost_model or ResourceBoundCost()
        self.policy = policy or SageSchedPolicy()
        self.bucket_size = max(1, bucket_size)
        self.noise_weight = noise_weight  # Fig. 11 robustness experiment
        self.noise_max_len = noise_max_len
        self.clock = clock
        self._live: dict[str, ScheduledRequest] = {}
        self._arrival_seq = 0  # tie-break for identical clock readings
        self.stats = {"predictions": 0, "refreshes": 0, "completions": 0}

    # ------------------------------------------------------------- lifecycle

    def admit(self, request_id: str, prompt: str, input_len: int,
              arrival: float | None = None) -> ScheduledRequest:
        """Register an arriving request: predict, cost, prioritize."""
        if request_id in self._live:
            raise KeyError(f"request {request_id!r} already admitted")
        arrival = self.clock() if arrival is None else arrival
        length_dist = self.predictor.predict(prompt, input_len)
        if self.noise_weight > 0.0:
            length_dist = length_dist.mix_uniform(self.noise_weight,
                                                  self.noise_max_len)
        self.stats["predictions"] += 1
        cost_dist = self.cost_model.distribution(
            input_len, length_dist.lengths, length_dist.probs)
        # encode arrival order into the float so FCFS ties stay stable
        self._arrival_seq += 1
        sr = ScheduledRequest(
            request_id=request_id, prompt=prompt, input_len=input_len,
            arrival=arrival + self._arrival_seq * 1e-9,
            length_dist=length_dist, cost_dist=cost_dist)
        sr.priority = self.policy.priority(sr)
        sr.next_refresh = self.policy.next_boundary(sr, self.bucket_size)
        self._live[request_id] = sr
        return sr

    def on_progress(self, request_id: str, generated: int) -> None:
        """Report that ``generated`` output tokens now exist.  Refreshing
        policies recompute the priority only at their refresh boundaries
        (cost buckets for SageSched, quantum edges for FastServe)."""
        sr = self._live[request_id]
        if generated == sr.generated:
            return
        sr.generated = generated
        if self.policy.refreshing and generated >= sr.next_refresh:
            sr.attained_cost = self.cost_model.attained(sr.input_len, generated)
            sr.priority = self.policy.priority(sr)
            sr.next_refresh = self.policy.next_boundary(sr, self.bucket_size)
            self.stats["refreshes"] += 1

    def tokens_to_refresh(self, request_id: str) -> float:
        """Output tokens until this request's next priority refresh
        (simulator fast-forward bound)."""
        sr = self._live[request_id]
        return sr.next_refresh - sr.generated

    def on_complete(self, request_id: str, output_len: int) -> None:
        """Request finished: feed the predictor's history and drop state."""
        sr = self._live.pop(request_id)
        self.predictor.observe(sr.prompt, sr.input_len, output_len)
        self.stats["completions"] += 1

    def on_abort(self, request_id: str) -> None:
        self._live.pop(request_id, None)

    # ------------------------------------------------------------- queries

    def get(self, request_id: str) -> ScheduledRequest:
        return self._live[request_id]

    def __contains__(self, request_id: str) -> bool:
        return request_id in self._live

    def __len__(self) -> int:
        return len(self._live)

    @property
    def preemptive(self) -> bool:
        return self.policy.preemptive

    def set_now(self, now: float) -> None:
        """Inject the current (sim or wall) time; time-varying policies
        (aging) recompute every live priority."""
        if not getattr(self.policy, "time_varying", False):
            return
        self.policy.now = now
        for sr in self._live.values():
            sr.priority = self.policy.priority(sr)

    def order(self, request_ids=None) -> list[str]:
        """Request ids sorted by priority (smaller first, arrival ties)."""
        if request_ids is None:
            srs = list(self._live.values())
        else:
            srs = [self._live[r] for r in request_ids]
        srs.sort(key=lambda s: (s.priority, s.arrival))
        return [s.request_id for s in srs]
