"""Adaptive robustness under prediction drift (beyond-paper subsystem).

SageSched's edge comes from trusting a predicted output-length
distribution, and every predictor in this repo freezes that prediction
at admission.  A drifting workload (new tenant, changed dataset, stale
history window) therefore rots the Gittins ranking silently: the
scheduler keeps acting on beliefs the requests themselves are busy
falsifying.  PR 6's degraded mode only fires when the predictor
*throws*; this module is the defense for when it *lies*.  Three
mechanisms, designed to compose (Adaptively Robust LLM Inference
Optimization, arXiv:2508.14544, is the hedging playbook):

  * **Mid-flight posteriors** — ``truncate_rows``: one vectorized
    truncate-and-renormalize over the (n, k) bucketized supports in
    ``BatchState``, applied when a request decodes past a predicted
    quantile.  It is the batched sibling of ``CostDistribution.shift``
    minus the re-origin: supports stay absolute (the scheduler's
    ``attained`` bookkeeping is absolute), dead mass is zeroed, and the
    renormalizer is a sequential cumsum so the scalar
    ``LengthDistribution.truncate`` / ``CostDistribution.truncate``
    oracles match bit for bit.

  * **Realized prediction error** — ``prediction_loss``: the log-loss
    margin of the predicted distribution against the prediction-free
    flat prior, evaluated at completion and squashed to [0, 1].  0.5 is
    the break-even point ("no better than no prediction"); the hedging
    controller (``policies.HedgedPolicy``) feeds this into
    multiplicative weights.

  * **Calibration monitoring** — ``CalibrationMonitor``: rolling
    per-tenant coverage@q, observed/predicted length ratio, and CRPS,
    fed by the scheduler's completion path.  Its ``widen_weight`` maps a
    coverage deficit to a conformal widening weight that the scheduler
    applies through ``LengthDistribution.mix_uniform`` at admission —
    quantile-level use of the distribution responds to miscalibration
    (arXiv:2604.00499) instead of cliffing.
"""

from __future__ import annotations

from collections import deque

import numpy as np

__all__ = ["truncate_rows", "prediction_loss", "crps",
           "CalibrationMonitor"]


def truncate_rows(support: np.ndarray, probs: np.ndarray,
                  cut: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Condition (n, k) bucketized distribution rows on X > cut[i].

    One vectorized pass: mass at support points <= the row's cut is
    zeroed and the survivors are renormalized IN PLACE of the original
    column positions — supports are untouched (they stay absolute), so
    leading dead columns simply carry prob 0, a shape every batched
    consumer already treats as inert (the Gittins kernels, TRAIL/LTR
    and the SSJF mean all mask on ``probs > 0`` / accumulate exact
    zeros).  The renormalizer is a sequential ``cumsum`` so the result
    is bit-identical to the compact scalar ``truncate`` oracles on
    ``LengthDistribution`` / ``CostDistribution``.

    Returns ``(new_probs, exhausted)``: rows whose whole predicted mass
    sits at or below the cut (the request outran its prediction) come
    back untouched with ``exhausted[i] = True`` — the caller must
    replace them with a proper tail belief (the scheduler rebuilds a
    flat ``mix_uniform`` fallback; never a NaN / zero-mass row).
    """
    support = np.asarray(support, np.float64)
    probs = np.asarray(probs, np.float64)
    cut = np.asarray(cut, np.float64)
    alive = (support > cut[:, None]) & (probs > 0.0)
    p = np.where(alive, probs, 0.0)
    norm = np.cumsum(p, axis=1)[:, -1]
    exhausted = norm <= 0.0
    out = p / np.where(exhausted, 1.0, norm)[:, None]
    out[exhausted] = probs[exhausted]
    return out, exhausted


def prediction_loss(dist, actual: int, max_len: int, *,
                    window: float = 0.25, scale: float = 8.0) -> float:
    """Realized error of a predicted length distribution, in [0, 1].

    Scores the log-loss of the predicted mass in a +/- ``window``
    relative band around the realized length against the same band's
    mass under a flat prior over [1, max_len] — the prediction-free
    belief the degraded mode schedules with.  The margin is squashed so

        0.0  = sharp and right (mass concentrated on the outcome),
        0.5  = exactly as informative as no prediction,
        1.0  = confidently wrong (negligible mass near the outcome).

    ``HedgedPolicy`` charges its prediction-free expert the constant
    0.5, so the hedge weights race on exactly this margin.
    """
    actual = int(actual)
    half = max(4.0, window * actual)
    lengths = np.asarray(dist.lengths, np.float64)
    in_win = (lengths >= actual - half) & (lengths <= actual + half)
    p_pred = float(np.cumsum(np.where(in_win, dist.probs, 0.0))[-1]) \
        if lengths.size else 0.0
    p_flat = min(1.0, (2.0 * half + 1.0) / max(2, max_len))
    margin = -np.log(max(p_pred, 1e-9)) + np.log(max(p_flat, 1e-9))
    return float(np.clip(0.5 + margin / (2.0 * scale), 0.0, 1.0))


def crps(lengths: np.ndarray, probs: np.ndarray, actual: float) -> float:
    """Continuous ranked probability score of a discrete distribution
    against one observation, in token units (0 = point mass on the
    truth; grows with both bias and spread).  Computed as the exact
    integral of (F(x) - H(x - actual))^2 between the outermost
    breakpoints of the step functions."""
    lengths = np.asarray(lengths, np.float64)
    y = float(actual)
    xs = np.unique(np.append(lengths, y))
    if xs.size < 2:
        return 0.0
    cdf = np.cumsum(np.asarray(probs, np.float64))
    pos = np.searchsorted(lengths, xs, side="right")
    f = np.where(pos > 0, cdf[np.minimum(pos, cdf.size) - 1], 0.0)
    h = (xs >= y).astype(np.float64)
    return float(np.cumsum((f[:-1] - h[:-1]) ** 2 * np.diff(xs))[-1])


class _TenantWindow:
    """Rolling window of completion-time calibration samples with O(1)
    running aggregates (observe is on the scheduler's completion path)."""

    def __init__(self, cap: int, n_q: int):
        self.cap = cap
        self.buf: deque = deque()
        self.cov_sum = np.zeros(n_q)
        self.actual_sum = 0.0
        self.pred_sum = 0.0
        self.crps_sum = 0.0

    def push(self, covered: np.ndarray, actual: float, pred_mean: float,
             score: float) -> None:
        self.buf.append((covered, actual, pred_mean, score))
        self.cov_sum += covered
        self.actual_sum += actual
        self.pred_sum += pred_mean
        self.crps_sum += score
        if len(self.buf) > self.cap:
            c, a, p, s = self.buf.popleft()
            self.cov_sum -= c
            self.actual_sum -= a
            self.pred_sum -= p
            self.crps_sum -= s

    @property
    def count(self) -> int:
        return len(self.buf)


class CalibrationMonitor:
    """Rolling per-tenant calibration statistics over completed requests.

    ``observe(tenant, dist, actual)`` records, per completion, whether
    the realized length was covered at each tracked quantile, the
    predicted mean, and the CRPS — all against the *admission-time*
    prediction (never the mid-flight posterior, which trivially covers).
    ``summary()`` exports the per-tenant table surfaced in
    ``Scheduler.stats`` / ``EngineMetrics`` / ``Gateway.summary``;
    ``widen_weight`` converts a coverage deficit at the highest tracked
    quantile into the conformal ``mix_uniform`` weight the scheduler
    applies to that tenant's next admissions.
    """

    def __init__(self, window: int = 256,
                 quantiles: tuple[float, ...] = (0.5, 0.9),
                 min_samples: int = 16,
                 widen_gain: float = 2.0,
                 max_widen: float = 0.5):
        self.window = int(window)
        self.quantiles = tuple(float(q) for q in quantiles)
        self.min_samples = int(min_samples)
        self.widen_gain = float(widen_gain)
        self.max_widen = float(max_widen)
        self._tenants: dict[str, _TenantWindow] = {}

    def observe(self, tenant: str, dist, actual: int) -> None:
        w = self._tenants.get(tenant)
        if w is None:
            w = self._tenants[tenant] = _TenantWindow(self.window,
                                                      len(self.quantiles))
        actual = int(actual)
        covered = np.array([actual <= dist.quantile(q)
                            for q in self.quantiles], np.float64)
        w.push(covered, float(actual), float(dist.mean),
               crps(dist.lengths, dist.probs, actual))

    def summary(self) -> dict:
        out = {}
        for tenant, w in sorted(self._tenants.items()):
            n = w.count
            if n == 0:
                continue
            stats = {"count": n,
                     "observed_over_predicted":
                         float(w.actual_sum / max(w.pred_sum, 1e-9)),
                     "crps_tokens": float(w.crps_sum / n)}
            for j, q in enumerate(self.quantiles):
                stats[f"coverage@{q:g}"] = float(w.cov_sum[j] / n)
            out[tenant] = stats
        return out

    def widen_weight(self, tenant: str) -> float:
        """Conformal widening weight for a tenant's next admissions:
        0 until ``min_samples`` completions exist, then proportional to
        the coverage deficit at the highest tracked quantile (a well-
        calibrated or over-covered tenant widens by exactly 0)."""
        w = self._tenants.get(tenant)
        if w is None or w.count < self.min_samples:
            return 0.0
        j = int(np.argmax(self.quantiles))
        q_hi = self.quantiles[j]
        deficit = q_hi - w.cov_sum[j] / w.count
        if deficit <= 0.0:
            return 0.0
        return float(min(self.max_widen, self.widen_gain * deficit))
