"""Scheduling policies: SageSched and every baseline the paper compares.

A policy maps a request's scheduler-side state to a scalar *priority*
(smaller = served first).  The Scheduler (scheduler.py) owns state updates
and bucketized refresh; policies are pure priority functions plus two
capability flags:

  * ``preemptive``   — may a running request be displaced by a smaller
                        priority arrival?
  * ``refreshing``   — does the priority depend on runtime progress (and
                        hence need recomputation at bucket boundaries)?

Implemented policies (paper Sec. 2.2 / 4.1 / 4.3.3):

  fcfs        FCFS, vLLM/SGLang default (Kwon et al. 2023).
  fastserve   MLFQ with exponentially growing quantums approximating SRPT
              without predictions (Wu et al. 2023).
  ssjf        Shortest-Job-First on a *point* output-length prediction
              (Qiu et al. 2024).
  ltr         Learning-to-rank: relative order of predicted lengths
              (Fu et al. 2024) — rank-preserving point estimate.
  trail       SRPT-approx with per-bucket re-predicted remaining length
              (Shahout et al. 2025).
  mean        Expected remaining *cost* (ablation, Fig. 6/11 'Mean').
  gittins     Gittins index at admission, never refreshed (ablation).
  sagesched   Gittins index + runtime bucket refresh — the paper's policy.
  hedged      Online hedge between a prediction-trusting ordering and a
              prediction-free one, multiplicative weights updated from
              realized prediction error (arXiv:2508.14544 playbook).
"""

from __future__ import annotations

import numpy as np

from .gittins import gittins_index, mean_index
from .robust import prediction_loss

__all__ = ["Policy", "HedgedPolicy", "make_policy", "POLICY_NAMES"]


class Policy:
    """Scalar interface (``priority``) plus the batched interface
    (``priority_batch``) used by the array-native scheduler hot path.

    ``priority_batch`` receives a *view* — a struct of parallel arrays
    (see ``repro.core.backends.BatchView``): ``cost_sup``/``cost_probs``
    and ``len_sup``/``len_probs`` as (n, k) bucketized distributions
    (padded entries carry prob 0), ``generated``/``attained``/``arrival``/
    ``input_len`` as (n,) vectors — and a backend exposing batched
    ``gittins``/``mean`` evaluators.  It returns the (n,) priorities in
    one fused pass; the scalar ``priority`` remains the oracle it is
    property-tested against.
    """

    name = "base"
    preemptive = False
    refreshing = False
    time_varying = False   # priority depends on wall/sim time (aging)

    def priority(self, sr) -> float:  # sr: scheduler.ScheduledRequest
        raise NotImplementedError

    def priority_batch(self, view, backend) -> np.ndarray:
        """Batched priorities; subclasses override with vectorized math.
        (The Scheduler falls back to the scalar path for policies that
        don't.)"""
        raise NotImplementedError

    @property
    def has_batch(self) -> bool:
        """True when the batch path is trustworthy: ``priority_batch``
        must be defined at (or below) the class that defines the scalar
        ``priority`` in the MRO.  A subclass that overrides only the
        scalar falls back to it — an inherited ``priority_batch`` would
        silently disagree with the override."""
        cls = type(self)
        if cls.priority_batch is Policy.priority_batch:
            return False
        pb = next(c for c in cls.__mro__ if "priority_batch" in c.__dict__)
        pr = next((c for c in cls.__mro__ if "priority" in c.__dict__),
                  Policy)
        return issubclass(pb, pr)

    @property
    def has_boundary_batch(self) -> bool:
        """Same MRO rule for the refresh-boundary pair: the vectorized
        ``next_boundary_batch`` is used only if it is defined at or
        below the scalar ``next_boundary`` override."""
        cls = type(self)
        nb = next(c for c in cls.__mro__
                  if "next_boundary_batch" in c.__dict__)
        ns = next(c for c in cls.__mro__ if "next_boundary" in c.__dict__)
        return issubclass(nb, ns)

    def next_boundary(self, sr, bucket_size: int) -> float:
        """Generated-token count at which the priority must next be
        recomputed.  Default: the paper's cost-bucket boundaries."""
        if not self.refreshing:
            return float("inf")
        return (sr.generated // bucket_size + 1) * bucket_size

    def next_boundary_batch(self, generated: np.ndarray, bucket_size: int
                            ) -> np.ndarray:
        if not self.refreshing:
            return np.full(np.asarray(generated).shape[0], np.inf)
        g = np.asarray(generated, np.int64)
        return ((g // bucket_size + 1) * bucket_size).astype(np.float64)


class FCFSPolicy(Policy):
    name = "fcfs"

    def priority(self, sr) -> float:
        return sr.arrival

    def priority_batch(self, view, backend) -> np.ndarray:
        return view.arrival.astype(np.float64, copy=True)


class FastServePolicy(Policy):
    """MLFQ: requests enter the top queue; after consuming the level's
    quantum of service they are demoted.  Priority = (level, arrival).
    Levels are encoded into one float: level * LEVEL_SPAN + arrival_rank."""

    name = "fastserve"
    preemptive = True
    refreshing = True
    LEVEL_SPAN = 1e12

    def __init__(self, base_quantum: int = 64, n_levels: int = 8):
        self.base_quantum = base_quantum
        self.n_levels = n_levels

    def _cum_budgets(self) -> np.ndarray:
        q = self.base_quantum * (2 ** np.arange(self.n_levels, dtype=np.int64))
        return np.cumsum(q)

    def level_of(self, service_tokens: int) -> int:
        """MLFQ level after ``service_tokens`` tokens of service: quantum of
        level k is base_quantum * 2^k; demote when cumulative budget spent."""
        budget, q = 0, self.base_quantum
        for level in range(self.n_levels):
            budget += q
            if service_tokens < budget:
                return level
            q *= 2
        return self.n_levels - 1

    def priority(self, sr) -> float:
        return self.level_of(sr.generated) * self.LEVEL_SPAN + sr.arrival

    def priority_batch(self, view, backend) -> np.ndarray:
        cum = self._cum_budgets()
        g = np.asarray(view.generated, np.int64)
        level = np.minimum(np.searchsorted(cum, g, side="right"),
                           self.n_levels - 1)
        return level.astype(np.float64) * self.LEVEL_SPAN + view.arrival

    def next_boundary(self, sr, bucket_size: int) -> float:
        """Demotion happens at cumulative quantum boundaries, not at the
        Gittins cost buckets."""
        budget, q = 0, self.base_quantum
        for _ in range(self.n_levels):
            budget += q
            if sr.generated < budget:
                return budget
            q *= 2
        return float("inf")

    def next_boundary_batch(self, generated, bucket_size: int) -> np.ndarray:
        cum = self._cum_budgets().astype(np.float64)
        g = np.asarray(generated, np.int64)
        idx = np.searchsorted(cum, g, side="right")
        return np.concatenate([cum, [np.inf]])[idx]


class SSJFPolicy(Policy):
    """Non-preemptive SJF on the predicted mean output length."""

    name = "ssjf"

    def priority(self, sr) -> float:
        return sr.length_dist.mean

    def priority_batch(self, view, backend) -> np.ndarray:
        lp = view.len_probs
        return np.cumsum(np.where(lp > 0, view.len_sup * lp, 0.0),
                         axis=1)[:, -1]


class LTRPolicy(Policy):
    """Learning-to-rank: only the relative order matters; we use the
    predicted median, which is what a rank model recovers (Fu et al. 2024
    optimize Kendall's tau against the true length order)."""

    name = "ltr"

    def priority(self, sr) -> float:
        return float(sr.length_dist.quantile(0.5))

    def priority_batch(self, view, backend) -> np.ndarray:
        cdf = np.cumsum(view.len_probs, axis=1)
        idx = np.minimum((cdf < 0.5).sum(axis=1), cdf.shape[1] - 1)
        return view.len_sup[np.arange(cdf.shape[0]), idx]


class TRAILPolicy(Policy):
    """SRPT-approx: expected REMAINING output length, re-evaluated at bucket
    boundaries (stand-in for TRAIL's per-iteration MLP repredictions).
    Cost proxy is the output length — TRAIL ignores demand hybridity."""

    name = "trail"
    preemptive = True
    refreshing = True

    def priority(self, sr) -> float:
        lens = sr.length_dist.lengths.astype(np.float64)
        probs = sr.length_dist.probs
        remaining = np.maximum(lens - sr.generated, 1.0)
        alive = lens > sr.generated
        if alive.any():
            # sequential sums so the batched path is bit-identical
            p = probs * alive
            num = np.cumsum(remaining * p)[-1]
            return float(num / np.cumsum(p)[-1])
        return 1.0  # predicted mass exhausted: completion imminent

    def priority_batch(self, view, backend) -> np.ndarray:
        g = np.asarray(view.generated, np.float64)[:, None]
        remaining = np.maximum(view.len_sup - g, 1.0)
        alive = (view.len_sup > g) & (view.len_probs > 0)
        p = np.where(alive, view.len_probs, 0.0)
        den = np.cumsum(p, axis=1)[:, -1]
        num = np.cumsum(remaining * p, axis=1)[:, -1]
        return np.where(den > 0.0, num / np.where(den > 0.0, den, 1.0), 1.0)


class MeanPolicy(Policy):
    """Expected remaining service cost (cost-model aware, no Gittins)."""

    name = "mean"
    preemptive = True
    refreshing = True

    def priority(self, sr) -> float:
        return mean_index(sr.cost_dist, sr.attained_cost)

    def priority_batch(self, view, backend) -> np.ndarray:
        return backend.mean(view.cost_sup, view.cost_probs, view.attained)


class GittinsPolicy(Policy):
    """Gittins index computed once at admission (no runtime refresh)."""

    name = "gittins"
    preemptive = True
    refreshing = False

    def priority(self, sr) -> float:
        return gittins_index(sr.cost_dist, 0.0)

    def priority_batch(self, view, backend) -> np.ndarray:
        return backend.gittins(view.cost_sup, view.cost_probs, None)


class SageSchedPolicy(Policy):
    """The paper's policy: Gittins index over the remaining-cost
    distribution, refreshed at bucket boundaries."""

    name = "sagesched"
    preemptive = True
    refreshing = True

    def priority(self, sr) -> float:
        return gittins_index(sr.cost_dist, sr.attained_cost)

    def priority_batch(self, view, backend) -> np.ndarray:
        return backend.gittins(view.cost_sup, view.cost_probs, view.attained)


class AgedSageSchedPolicy(Policy):
    """BEYOND-PAPER: Gittins with starvation bounding.

    Pure Gittins ordering can starve long requests indefinitely under
    sustained load (unbounded p99 TTLT).  We discount the index by the
    request's queueing age — an aging factor standard in OS schedulers
    but absent from the paper: priority = G / (1 + age/tau).  As tau ->
    inf this is exactly SageSched; small tau approaches FCFS.  Age is
    tracked in *scheduler decisions* via the arrival timestamp, so the
    policy stays stateless.  Evaluated in EXPERIMENTS.md §Beyond.
    """

    name = "sagesched_aged"
    preemptive = True
    refreshing = True
    time_varying = True

    def __init__(self, tau_age: float = 60.0):
        self.tau_age = tau_age
        self.now = 0.0      # injected by Scheduler.set_now()

    def priority(self, sr) -> float:
        g = gittins_index(sr.cost_dist, sr.attained_cost)
        age = max(0.0, self.now - sr.arrival)
        return g / (1.0 + age / self.tau_age)

    def base_priority(self, sr) -> float:
        """Undiscounted Gittins index — cached by BatchState so set_now()
        aging is a pure vectorized discount, no index recomputation."""
        return gittins_index(sr.cost_dist, sr.attained_cost)

    def base_priority_batch(self, view, backend) -> np.ndarray:
        return backend.gittins(view.cost_sup, view.cost_probs, view.attained)

    def apply_age(self, base: np.ndarray, arrival: np.ndarray,
                  now: float) -> np.ndarray:
        age = np.maximum(0.0, now - np.asarray(arrival, np.float64))
        return base / (1.0 + age / self.tau_age)

    def priority_batch(self, view, backend) -> np.ndarray:
        return self.apply_age(self.base_priority_batch(view, backend),
                              view.arrival, self.now)


class HedgedPolicy(Policy):
    """BEYOND-PAPER: online hedging between prediction-trusting and
    prediction-free orderings (robustness to prediction drift).

    Runs two sub-policies side by side — ``trusting`` (default
    SageSched: Gittins over the predicted cost distribution) and
    ``free`` (default FCFS: no per-request information) — and blends
    their *ranks* over the live set:

        priority_i = (w_t * rank_t(i) + w_f * rank_f(i)) / (n - 1)

    Ranks (not raw priorities) make the blend scale-free: Gittins
    indices and arrival timestamps live in incomparable units.  The
    weights follow multiplicative weights / Hedge: at each completion
    the trusting expert is charged ``prediction_loss`` (the realized
    log-loss margin of the admission-time prediction, in [0, 1]) and
    the free expert the constant break-even 0.5, then
    ``w *= exp(-eta * loss)``.  A sharp, correct predictor drives
    w_t -> 1 (pure SageSched); drift drives w_f up and the ordering
    degrades gracefully toward FCFS instead of cliffing on confidently
    wrong indices.  Log-weights are clamped to ``max_log_ratio`` so
    neither expert is ever abandoned — recovery after a regime shift
    takes O(max_log_ratio / eta) completions, not forever.

    Rank blending needs the FULL live set, so the policy sets
    ``rank_based = True``: the Scheduler promotes any dirty row to an
    all-rows refresh and requires an array backend (the object path has
    no batch view to rank over).
    """

    name = "hedged"
    preemptive = True
    rank_based = True   # priorities are ranks over the whole live set

    def __init__(self, trusting: "Policy | str | None" = None,
                 free: "Policy | str | None" = None,
                 eta: float = 0.8,
                 w_trust: float = 0.5,
                 max_log_ratio: float = 6.0,
                 free_loss: float = 0.5,
                 max_len: int = 4096):
        if isinstance(trusting, str):
            trusting = make_policy(trusting)
        if isinstance(free, str):
            free = make_policy(free)
        self.trusting = trusting or SageSchedPolicy()
        self.free = free or FCFSPolicy()
        self.refreshing = self.trusting.refreshing or self.free.refreshing
        self.eta = float(eta)
        self.max_log_ratio = float(max_log_ratio)
        self.free_loss = float(free_loss)
        self.max_len = int(max_len)
        w0 = float(np.clip(w_trust, 1e-6, 1.0 - 1e-6))
        self._lw = np.log(np.array([w0, 1.0 - w0]))
        self._lw -= self._lw.max()
        self.updates = 0

    @property
    def weights(self) -> tuple[float, float]:
        w = np.exp(self._lw - self._lw.max())
        w = w / np.cumsum(w)[-1]
        return float(w[0]), float(w[1])

    def snapshot(self) -> dict:
        w_t, w_f = self.weights
        return {"w_trust": w_t, "w_free": w_f, "updates": self.updates}

    def observe_outcome(self, dist, actual: int) -> None:
        """Hedge update at completion: ``dist`` is the admission-time
        prediction (None when it was a degraded-mode prior — nothing to
        score), ``actual`` the realized output length."""
        if dist is None:
            return
        loss_t = prediction_loss(dist, actual, self.max_len)
        self._lw[0] -= self.eta * loss_t
        self._lw[1] -= self.eta * self.free_loss
        self._lw -= self._lw.max()
        np.clip(self._lw, -self.max_log_ratio, 0.0, out=self._lw)
        self.updates += 1

    def priority(self, sr) -> float:
        raise RuntimeError(
            "hedged priorities are ranks over the whole live set; use an "
            "array priority_backend (numpy/pallas), not 'object'")

    @staticmethod
    def _ranks(prio: np.ndarray, arrival: np.ndarray) -> np.ndarray:
        r = np.empty(prio.shape[0], np.float64)
        r[np.lexsort((arrival, prio))] = np.arange(prio.shape[0],
                                                   dtype=np.float64)
        return r

    def priority_batch(self, view, backend) -> np.ndarray:
        n = view.arrival.shape[0]
        if n == 0:
            return np.zeros(0)
        p_t = np.asarray(self.trusting.priority_batch(view, backend),
                         np.float64)
        p_f = np.asarray(self.free.priority_batch(view, backend), np.float64)
        w_t, w_f = self.weights
        blended = w_t * self._ranks(p_t, view.arrival) \
            + w_f * self._ranks(p_f, view.arrival)
        return blended / max(1, n - 1)

    def next_boundary(self, sr, bucket_size: int) -> float:
        return min(self.trusting.next_boundary(sr, bucket_size),
                   self.free.next_boundary(sr, bucket_size))

    def next_boundary_batch(self, generated, bucket_size: int) -> np.ndarray:
        return np.minimum(
            self.trusting.next_boundary_batch(generated, bucket_size),
            self.free.next_boundary_batch(generated, bucket_size))


_REGISTRY = {
    "fcfs": FCFSPolicy,
    "fastserve": FastServePolicy,
    "ssjf": SSJFPolicy,
    "ltr": LTRPolicy,
    "trail": TRAILPolicy,
    "mean": MeanPolicy,
    "gittins": GittinsPolicy,
    "sagesched": SageSchedPolicy,
    "sagesched_aged": AgedSageSchedPolicy,
    "hedged": HedgedPolicy,
}

POLICY_NAMES = tuple(_REGISTRY)


def make_policy(name: str, **kwargs) -> Policy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
