"""Scheduling policies: SageSched and every baseline the paper compares.

A policy maps a request's scheduler-side state to a scalar *priority*
(smaller = served first).  The Scheduler (scheduler.py) owns state updates
and bucketized refresh; policies are pure priority functions plus two
capability flags:

  * ``preemptive``   — may a running request be displaced by a smaller
                        priority arrival?
  * ``refreshing``   — does the priority depend on runtime progress (and
                        hence need recomputation at bucket boundaries)?

Implemented policies (paper Sec. 2.2 / 4.1 / 4.3.3):

  fcfs        FCFS, vLLM/SGLang default (Kwon et al. 2023).
  fastserve   MLFQ with exponentially growing quantums approximating SRPT
              without predictions (Wu et al. 2023).
  ssjf        Shortest-Job-First on a *point* output-length prediction
              (Qiu et al. 2024).
  ltr         Learning-to-rank: relative order of predicted lengths
              (Fu et al. 2024) — rank-preserving point estimate.
  trail       SRPT-approx with per-bucket re-predicted remaining length
              (Shahout et al. 2025).
  mean        Expected remaining *cost* (ablation, Fig. 6/11 'Mean').
  gittins     Gittins index at admission, never refreshed (ablation).
  sagesched   Gittins index + runtime bucket refresh — the paper's policy.
"""

from __future__ import annotations

import numpy as np

from .gittins import gittins_index, mean_index

__all__ = ["Policy", "make_policy", "POLICY_NAMES"]


class Policy:
    name = "base"
    preemptive = False
    refreshing = False
    time_varying = False   # priority depends on wall/sim time (aging)

    def priority(self, sr) -> float:  # sr: scheduler.ScheduledRequest
        raise NotImplementedError

    def next_boundary(self, sr, bucket_size: int) -> float:
        """Generated-token count at which the priority must next be
        recomputed.  Default: the paper's cost-bucket boundaries."""
        if not self.refreshing:
            return float("inf")
        return (sr.generated // bucket_size + 1) * bucket_size


class FCFSPolicy(Policy):
    name = "fcfs"

    def priority(self, sr) -> float:
        return sr.arrival


class FastServePolicy(Policy):
    """MLFQ: requests enter the top queue; after consuming the level's
    quantum of service they are demoted.  Priority = (level, arrival).
    Levels are encoded into one float: level * LEVEL_SPAN + arrival_rank."""

    name = "fastserve"
    preemptive = True
    refreshing = True
    LEVEL_SPAN = 1e12

    def __init__(self, base_quantum: int = 64, n_levels: int = 8):
        self.base_quantum = base_quantum
        self.n_levels = n_levels

    def level_of(self, service_tokens: int) -> int:
        """MLFQ level after ``service_tokens`` tokens of service: quantum of
        level k is base_quantum * 2^k; demote when cumulative budget spent."""
        budget, q = 0, self.base_quantum
        for level in range(self.n_levels):
            budget += q
            if service_tokens < budget:
                return level
            q *= 2
        return self.n_levels - 1

    def priority(self, sr) -> float:
        return self.level_of(sr.generated) * self.LEVEL_SPAN + sr.arrival

    def next_boundary(self, sr, bucket_size: int) -> float:
        """Demotion happens at cumulative quantum boundaries, not at the
        Gittins cost buckets."""
        budget, q = 0, self.base_quantum
        for _ in range(self.n_levels):
            budget += q
            if sr.generated < budget:
                return budget
            q *= 2
        return float("inf")


class SSJFPolicy(Policy):
    """Non-preemptive SJF on the predicted mean output length."""

    name = "ssjf"

    def priority(self, sr) -> float:
        return sr.length_dist.mean


class LTRPolicy(Policy):
    """Learning-to-rank: only the relative order matters; we use the
    predicted median, which is what a rank model recovers (Fu et al. 2024
    optimize Kendall's tau against the true length order)."""

    name = "ltr"

    def priority(self, sr) -> float:
        return float(sr.length_dist.quantile(0.5))


class TRAILPolicy(Policy):
    """SRPT-approx: expected REMAINING output length, re-evaluated at bucket
    boundaries (stand-in for TRAIL's per-iteration MLP repredictions).
    Cost proxy is the output length — TRAIL ignores demand hybridity."""

    name = "trail"
    preemptive = True
    refreshing = True

    def priority(self, sr) -> float:
        lens = sr.length_dist.lengths.astype(np.float64)
        probs = sr.length_dist.probs
        remaining = np.maximum(lens - sr.generated, 1.0)
        alive = lens > sr.generated
        if alive.any():
            p = probs * alive
            return float(np.sum(remaining * p) / p.sum())
        return 1.0  # predicted mass exhausted: completion imminent


class MeanPolicy(Policy):
    """Expected remaining service cost (cost-model aware, no Gittins)."""

    name = "mean"
    preemptive = True
    refreshing = True

    def priority(self, sr) -> float:
        return mean_index(sr.cost_dist, sr.attained_cost)


class GittinsPolicy(Policy):
    """Gittins index computed once at admission (no runtime refresh)."""

    name = "gittins"
    preemptive = True
    refreshing = False

    def priority(self, sr) -> float:
        return gittins_index(sr.cost_dist, 0.0)


class SageSchedPolicy(Policy):
    """The paper's policy: Gittins index over the remaining-cost
    distribution, refreshed at bucket boundaries."""

    name = "sagesched"
    preemptive = True
    refreshing = True

    def priority(self, sr) -> float:
        return gittins_index(sr.cost_dist, sr.attained_cost)


class AgedSageSchedPolicy(Policy):
    """BEYOND-PAPER: Gittins with starvation bounding.

    Pure Gittins ordering can starve long requests indefinitely under
    sustained load (unbounded p99 TTLT).  We discount the index by the
    request's queueing age — an aging factor standard in OS schedulers
    but absent from the paper: priority = G / (1 + age/tau).  As tau ->
    inf this is exactly SageSched; small tau approaches FCFS.  Age is
    tracked in *scheduler decisions* via the arrival timestamp, so the
    policy stays stateless.  Evaluated in EXPERIMENTS.md §Beyond.
    """

    name = "sagesched_aged"
    preemptive = True
    refreshing = True
    time_varying = True

    def __init__(self, tau_age: float = 60.0):
        self.tau_age = tau_age
        self.now = 0.0      # injected by Scheduler.set_now()

    def priority(self, sr) -> float:
        g = gittins_index(sr.cost_dist, sr.attained_cost)
        age = max(0.0, self.now - sr.arrival)
        return g / (1.0 + age / self.tau_age)


_REGISTRY = {
    "fcfs": FCFSPolicy,
    "fastserve": FastServePolicy,
    "ssjf": SSJFPolicy,
    "ltr": LTRPolicy,
    "trail": TRAILPolicy,
    "mean": MeanPolicy,
    "gittins": GittinsPolicy,
    "sagesched": SageSchedPolicy,
    "sagesched_aged": AgedSageSchedPolicy,
}

POLICY_NAMES = tuple(_REGISTRY)


def make_policy(name: str, **kwargs) -> Policy:
    if name not in _REGISTRY:
        raise KeyError(f"unknown policy {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
