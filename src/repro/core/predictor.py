"""Output-length distribution predictors (paper Sec. 3.1 + ablations 4.3.1).

The paper's predictor is *semantic-aware* and *history-based*: embed the
incoming prompt, retrieve recently-served requests whose prompt embedding
has cosine similarity >= tau (default 0.8), and return the empirical
distribution of THEIR output lengths as the prediction.  Training-free,
model-agnostic, <0.5 ms per request.

Ablation baselines (Sec. 4.3.1):
  * ``LengthHistoryPredictor`` — semantic-UNAWARE history-based: retrieves
    by input-length proximity instead of prompt content.
  * ``ProxyModelPredictor`` — semantic-aware LLM-based: a fitted parametric
    head over the prompt embedding (stand-in for the DistillBERT model of
    SSJF with its argmax layer removed so it emits a distribution).  This
    carries training cost and emulation error, which is the paper's point.
  * ``OraclePredictor`` — knows the true per-request distribution; used to
    isolate scheduling-policy effects in tests/benchmarks.
  * ``PointPredictor`` — wraps any predictor, collapsing the distribution
    onto its mean (what SSJF/LTR effectively schedule with).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .cost_model import quantile_index
from .embedding import PromptEmbedder
from .history import HistoryStore

__all__ = [
    "LengthDistribution",
    "Predictor",
    "SemanticHistoryPredictor",
    "LengthHistoryPredictor",
    "ProxyModelPredictor",
    "OraclePredictor",
    "PointPredictor",
    "empirical_distribution",
]


@dataclass(frozen=True)
class LengthDistribution:
    """Discrete distribution over output token lengths."""

    lengths: np.ndarray  # (k,) int64, strictly ascending
    probs: np.ndarray    # (k,) float64, sums to 1

    def __post_init__(self):
        object.__setattr__(self, "lengths", np.asarray(self.lengths, np.int64))
        object.__setattr__(self, "probs", np.asarray(self.probs, np.float64))

    @property
    def mean(self) -> float:
        # sequential (cumsum) summation: keeps SSJF's batched priority
        # path bit-identical to this scalar oracle (numpy's pairwise
        # np.sum trees differ between compact and zero-padded arrays)
        return float(np.cumsum(self.lengths * self.probs)[-1])

    def quantile(self, q: float) -> int:
        return int(self.lengths[quantile_index(self.probs, q)])

    def sample(self, rng: np.random.Generator) -> int:
        return int(rng.choice(self.lengths, p=self.probs))

    def truncate(self, min_exclusive: int) -> "LengthDistribution | None":
        """Condition on L > ``min_exclusive`` keeping lengths absolute —
        the mid-flight posterior update (repro.core.robust): a request
        that decoded past a predicted quantile has falsified the mass at
        or below it.  Unlike ``CostDistribution.shift`` there is no
        re-origin (the scheduler's generated/attained bookkeeping is
        absolute).  Returns None when the whole predicted mass is
        falsified (caller must substitute a tail belief).  Sequential
        cumsum renormalizer: bit-identical to the batched
        ``robust.truncate_rows`` over zero-padded rows."""
        alive = self.lengths > int(min_exclusive)
        if not alive.any():
            return None
        p = self.probs[alive]
        return LengthDistribution(self.lengths[alive], p / np.cumsum(p)[-1])

    def mix_uniform(self, weight: float, max_len: int, k: int = 32
                    ) -> "LengthDistribution":
        """Blend with a uniform distribution (paper Fig. 11 noise test:
        'merging a uniform distribution ... following a weight ratio 1:4'
        → weight = 0.2)."""
        grid = np.unique(np.linspace(1, max_len, k).astype(np.int64))
        lengths = np.union1d(self.lengths, grid)
        probs = np.zeros(lengths.shape[0], np.float64)
        probs[np.searchsorted(lengths, self.lengths)] += (1 - weight) * self.probs
        probs[np.searchsorted(lengths, grid)] += weight / grid.size
        return LengthDistribution(lengths, probs / probs.sum())


def empirical_distribution(samples: np.ndarray, max_support: int = 64
                           ) -> LengthDistribution:
    """Empirical distribution of observed lengths, optionally compressed to
    <= max_support points by quantile binning (keeps Gittins cheap)."""
    samples = np.asarray(samples, np.int64)
    if samples.size == 0:
        raise ValueError("cannot build a distribution from zero samples")
    uniq, counts = np.unique(samples, return_counts=True)
    if uniq.size > max_support:
        # quantile-bin to max_support representative points
        qs = np.linspace(0, 1, max_support)
        edges = np.quantile(samples, qs, method="nearest").astype(np.int64)
        edges = np.unique(edges)
        idx = np.clip(np.searchsorted(edges, samples, side="right") - 1,
                      0, edges.size - 1)
        probs = np.bincount(idx, minlength=edges.size).astype(np.float64)
        keep = probs > 0
        return LengthDistribution(edges[keep], probs[keep] / probs.sum())
    return LengthDistribution(uniq, counts.astype(np.float64) / counts.sum())


class Predictor:
    """Interface: predict output-length distributions for prompts.

    The primitive is the *batched* call (``predict_batch``): arrivals at
    high rate come in bursts, and the built-in predictors amortize their
    expensive step (the semantic-history search, the proxy-model head)
    across the burst.  Scalar ``predict`` is sugar — the built-ins define
    it as the B = 1 case.  Custom predictors may do the opposite and only
    override ``predict``; the base ``predict_batch`` then loops it.
    Either way the two surfaces return identical distributions for
    identical history state (asserted bit-identically in
    tests/test_batch_ingress.py).
    """

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        raise NotImplementedError

    def predict_batch(self, prompts: list[str], input_lens
                      ) -> list[LengthDistribution]:
        """Batched prediction for a burst of arrivals; default loops the
        scalar ``predict`` so custom predictors keep working."""
        return [self.predict(p, int(il))
                for p, il in zip(prompts, input_lens)]

    @property
    def has_batch(self) -> bool:
        """True when ``predict_batch`` is trustworthy: it must be defined
        at (or below) the class that defines the scalar ``predict`` in
        the MRO (the same rule as ``Policy.has_batch``).  A subclass of a
        built-in predictor that overrides only ``predict`` would
        otherwise have its override silently bypassed by the inherited
        batch path; batched callers consult this flag and fall back to
        looping the scalar ``predict``."""
        cls = type(self)
        pb = next(c for c in cls.__mro__ if "predict_batch" in c.__dict__)
        pr = next((c for c in cls.__mro__ if "predict" in c.__dict__),
                  Predictor)
        return issubclass(pb, pr)

    def predict_many(self, prompts: list[str], input_lens
                     ) -> list[LengthDistribution]:
        """Burst dispatch for batched callers: the vectorized
        ``predict_batch`` when it is authoritative (``has_batch``), else
        a loop over the scalar ``predict`` so overrides are honored."""
        if self.has_batch:
            return self.predict_batch(prompts, input_lens)
        return [self.predict(p, int(il))
                for p, il in zip(prompts, input_lens)]

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        """Feed back a completed request (history-based predictors learn)."""


class SemanticHistoryPredictor(Predictor):
    """The paper's predictor (Sec. 3.1).

    similarity_threshold: cosine threshold tau (default 0.8, Fig. 13a).
    min_matches: below this, progressively relax tau, then fall back to the
        global recent-window marginal (footnote 3's public-dataset
        augmentation is served by ``seed``).
    """

    def __init__(self, embedder: PromptEmbedder | None = None,
                 history: HistoryStore | None = None,
                 similarity_threshold: float = 0.8,
                 min_matches: int = 8,
                 max_support: int = 64,
                 default_length: int = 256):
        self.embedder = embedder or PromptEmbedder()
        self.history = history or HistoryStore(self.embedder.dim)
        self.similarity_threshold = similarity_threshold
        self.min_matches = min_matches
        self.max_support = max_support
        self.default_length = default_length
        self._embed_cache: dict[str, np.ndarray] = {}

    # -- embedding with a tiny memo so observe() reuses predict()'s work
    def _embed(self, prompt: str) -> np.ndarray:
        e = self._embed_cache.get(prompt)
        if e is None:
            e = self.embedder.embed(prompt)
            if len(self._embed_cache) > 4096:
                self._embed_cache.clear()
            self._embed_cache[prompt] = e
        return e

    def seed(self, prompts: list[str], input_lens, output_lens) -> None:
        """Warm-up augmentation with public-dataset records (footnote 3)."""
        embs = self.embedder.embed_batch(prompts)
        self.history.add_batch(embs, input_lens, output_lens)

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        return self.predict_batch([prompt], [input_len])[0]

    def predict_batch(self, prompts: list[str], input_lens
                      ) -> list[LengthDistribution]:
        """The batch-first retrieval path: ONE (B, H) cosine matmul over
        the unique prompts of the burst, per-row threshold relaxation on
        the cached similarities, and a shared global-marginal fallback.

        A burst frequently repeats semantically identical prompts (that
        clustering is the predictor's whole premise, Fig. 4), so the
        search runs once per *unique* prompt — the history is fixed for
        the duration of the call, which also makes this bit-identical to
        B scalar ``predict`` calls.
        """
        n = len(prompts)
        if n == 0:
            return []
        uniq: dict[str, int] = {}
        rows = np.empty(n, np.int64)
        order: list[str] = []
        for j, p in enumerate(prompts):
            r = uniq.get(p)
            if r is None:
                r = uniq[p] = len(order)
                order.append(p)
            rows[j] = r
        embs = np.stack([self._embed(p) for p in order])
        hist = self.history
        sims = hist.similarity_batch(embs)
        glob_dist = None
        preds: list[LengthDistribution] = []
        for r in range(len(order)):
            tau = self.similarity_threshold
            idx = hist.threshold_matches(sims[r], embs[r], tau)
            while idx.size < self.min_matches and tau > 0.3:
                tau -= 0.1  # progressive relaxation on the cached sims
                idx = hist.threshold_matches(sims[r], embs[r], tau)
            if idx.size >= 1:
                preds.append(empirical_distribution(
                    hist.output_lengths(idx), self.max_support))
                continue
            if glob_dist is None:  # footnote-3 fallback, computed once
                glob = hist.global_output_lengths()
                glob_dist = empirical_distribution(glob, self.max_support) \
                    if glob.size > 0 else LengthDistribution(
                        np.array([self.default_length]), np.array([1.0]))
            preds.append(glob_dist)
        return [preds[r] for r in rows]

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self.history.add(self._embed(prompt), input_len, output_len)


class LengthHistoryPredictor(Predictor):
    """Semantic-UNAWARE ablation: retrieve history by input-length proximity
    (paper Sec. 4.3.1 baseline 1)."""

    def __init__(self, history: HistoryStore | None = None,
                 rel_tol: float = 0.2, max_support: int = 64,
                 default_length: int = 256):
        self.history = history or HistoryStore(dim=1)
        self.rel_tol = rel_tol
        self.max_support = max_support
        self.default_length = default_length
        self._zero = np.zeros(self.history.dim, np.float32)

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        return self.predict_batch([prompt], [input_len])[0]

    def predict_batch(self, prompts: list[str], input_lens
                      ) -> list[LengthDistribution]:
        if len(prompts) == 0:
            return []
        matches = self.history.search_by_input_len_batch(input_lens,
                                                         self.rel_tol)
        default = None
        out = []
        for idx in matches:
            if idx.size >= 1:
                out.append(empirical_distribution(
                    self.history.output_lengths(idx), self.max_support))
            else:
                if default is None:
                    default = LengthDistribution(
                        np.array([self.default_length]), np.array([1.0]))
                out.append(default)
        return out

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self.history.add(self._zero, input_len, output_len)


class ProxyModelPredictor(Predictor):
    """Semantic-aware LLM-based ablation (paper Sec. 4.3.1 baseline 2).

    Stand-in for a fine-tuned DistillBERT with the argmax layer removed:
    a ridge-regression bucket-logit head over the hashed prompt embedding,
    refit periodically from accumulated (embedding, output_len) pairs.
    This emulates the *class* of model-based distribution predictors: it
    carries fit cost and pays emulation error for rare prompts.
    """

    def __init__(self, embedder: PromptEmbedder | None = None,
                 n_buckets: int = 20, bucket_width: int = 100,
                 refit_every: int = 512, l2: float = 1.0,
                 default_length: int = 256):
        self.embedder = embedder or PromptEmbedder()
        self.n_buckets = n_buckets
        self.bucket_width = bucket_width
        self.refit_every = refit_every
        self.l2 = l2
        self.default_length = default_length
        self._X: list[np.ndarray] = []
        self._y: list[int] = []
        self._W: np.ndarray | None = None  # (dim, n_buckets)
        self._since_fit = 0

    def _bucket(self, output_len: int) -> int:
        return min(self.n_buckets - 1, output_len // self.bucket_width)

    def _fit(self) -> None:
        X = np.stack(self._X)                       # (n, dim)
        Y = np.zeros((X.shape[0], self.n_buckets))  # one-hot targets
        Y[np.arange(X.shape[0]), [self._bucket(y) for y in self._y]] = 1.0
        A = X.T @ X + self.l2 * np.eye(X.shape[1])
        self._W = np.linalg.solve(A, X.T @ Y)
        self._since_fit = 0

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        return self.predict_batch([prompt], [input_len])[0]

    def predict_batch(self, prompts: list[str], input_lens
                      ) -> list[LengthDistribution]:
        n = len(prompts)
        if n == 0:
            return []
        if self._W is None:
            d = LengthDistribution(np.array([self.default_length]),
                                   np.array([1.0]))
            return [d] * n
        embs = np.stack([self.embedder.embed(p) for p in prompts])
        # non-optimized einsum fixes the d-reduction order per output
        # element regardless of B — the batch/scalar parity requirement a
        # BLAS gemv/gemm pair cannot meet (their blocking differs by shape)
        logits = np.einsum("bd,dk->bk", embs, self._W)
        centers = (np.arange(self.n_buckets) + 0.5) * self.bucket_width
        out = []
        for b in range(n):
            lg = logits[b] - logits[b].max()
            probs = np.exp(lg * 4.0)  # sharpen: ridge scores are soft
            probs = probs / probs.sum()
            keep = probs > 1e-4
            out.append(LengthDistribution(centers[keep].astype(np.int64),
                                          probs[keep] / probs[keep].sum()))
        return out

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self._X.append(self.embedder.embed(prompt))
        self._y.append(output_len)
        if len(self._X) > 20_000:  # bound memory
            self._X = self._X[-10_000:]
            self._y = self._y[-10_000:]
        self._since_fit += 1
        if self._since_fit >= self.refit_every and len(self._X) >= 64:
            self._fit()


class OraclePredictor(Predictor):
    """Knows the true distribution per request (injected by the workload);
    used to isolate the scheduling policy from prediction error."""

    def __init__(self):
        self._truth: dict[str, LengthDistribution] = {}

    def register(self, prompt: str, dist: LengthDistribution) -> None:
        self._truth[prompt] = dist

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        return self.predict_batch([prompt], [input_len])[0]

    def predict_batch(self, prompts: list[str], input_lens
                      ) -> list[LengthDistribution]:
        """Batched truth-table lookups (O(1) per prompt — nothing to
        amortize; the override keeps the batch surface uniform)."""
        missing = [p for p in prompts if p not in self._truth]
        if missing:
            raise KeyError("oracle has no registered distribution for prompt")
        return [self._truth[p] for p in prompts]


class PointPredictor(Predictor):
    """Collapse any predictor's distribution onto its mean — what
    point-estimate schedulers (SSJF/LTR) consume."""

    def __init__(self, inner: Predictor):
        self.inner = inner

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        return self.predict_batch([prompt], [input_len])[0]

    def predict_batch(self, prompts: list[str], input_lens
                      ) -> list[LengthDistribution]:
        """Collapse through the inner predictor's *batch* path (scalar
        fallback if its batch path is not authoritative), so a burst
        pays the inner search once."""
        return [LengthDistribution(np.array([max(1, round(d.mean))]),
                                   np.array([1.0]))
                for d in self.inner.predict_many(prompts, input_lens)]

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self.inner.observe(prompt, input_len, output_len)
