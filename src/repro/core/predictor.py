"""Output-length distribution predictors (paper Sec. 3.1 + ablations 4.3.1).

The paper's predictor is *semantic-aware* and *history-based*: embed the
incoming prompt, retrieve recently-served requests whose prompt embedding
has cosine similarity >= tau (default 0.8), and return the empirical
distribution of THEIR output lengths as the prediction.  Training-free,
model-agnostic, <0.5 ms per request.

Ablation baselines (Sec. 4.3.1):
  * ``LengthHistoryPredictor`` — semantic-UNAWARE history-based: retrieves
    by input-length proximity instead of prompt content.
  * ``ProxyModelPredictor`` — semantic-aware LLM-based: a fitted parametric
    head over the prompt embedding (stand-in for the DistillBERT model of
    SSJF with its argmax layer removed so it emits a distribution).  This
    carries training cost and emulation error, which is the paper's point.
  * ``OraclePredictor`` — knows the true per-request distribution; used to
    isolate scheduling-policy effects in tests/benchmarks.
  * ``PointPredictor`` — wraps any predictor, collapsing the distribution
    onto its mean (what SSJF/LTR effectively schedule with).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .embedding import PromptEmbedder
from .history import HistoryStore

__all__ = [
    "LengthDistribution",
    "Predictor",
    "SemanticHistoryPredictor",
    "LengthHistoryPredictor",
    "ProxyModelPredictor",
    "OraclePredictor",
    "PointPredictor",
    "empirical_distribution",
]


@dataclass(frozen=True)
class LengthDistribution:
    """Discrete distribution over output token lengths."""

    lengths: np.ndarray  # (k,) int64, strictly ascending
    probs: np.ndarray    # (k,) float64, sums to 1

    def __post_init__(self):
        object.__setattr__(self, "lengths", np.asarray(self.lengths, np.int64))
        object.__setattr__(self, "probs", np.asarray(self.probs, np.float64))

    @property
    def mean(self) -> float:
        # sequential (cumsum) summation: keeps SSJF's batched priority
        # path bit-identical to this scalar oracle (numpy's pairwise
        # np.sum trees differ between compact and zero-padded arrays)
        return float(np.cumsum(self.lengths * self.probs)[-1])

    def quantile(self, q: float) -> int:
        cdf = np.cumsum(self.probs)
        # float rounding can leave cdf[-1] < q (e.g. 0.9999999998 < 1.0),
        # in which case searchsorted returns len(cdf) — clip to the last
        # support point
        idx = min(int(np.searchsorted(cdf, q)), self.lengths.shape[0] - 1)
        return int(self.lengths[idx])

    def sample(self, rng: np.random.Generator) -> int:
        return int(rng.choice(self.lengths, p=self.probs))

    def mix_uniform(self, weight: float, max_len: int, k: int = 32
                    ) -> "LengthDistribution":
        """Blend with a uniform distribution (paper Fig. 11 noise test:
        'merging a uniform distribution ... following a weight ratio 1:4'
        → weight = 0.2)."""
        grid = np.unique(np.linspace(1, max_len, k).astype(np.int64))
        lengths = np.union1d(self.lengths, grid)
        probs = np.zeros(lengths.shape[0], np.float64)
        probs[np.searchsorted(lengths, self.lengths)] += (1 - weight) * self.probs
        probs[np.searchsorted(lengths, grid)] += weight / grid.size
        return LengthDistribution(lengths, probs / probs.sum())


def empirical_distribution(samples: np.ndarray, max_support: int = 64
                           ) -> LengthDistribution:
    """Empirical distribution of observed lengths, optionally compressed to
    <= max_support points by quantile binning (keeps Gittins cheap)."""
    samples = np.asarray(samples, np.int64)
    if samples.size == 0:
        raise ValueError("cannot build a distribution from zero samples")
    uniq, counts = np.unique(samples, return_counts=True)
    if uniq.size > max_support:
        # quantile-bin to max_support representative points
        qs = np.linspace(0, 1, max_support)
        edges = np.quantile(samples, qs, method="nearest").astype(np.int64)
        edges = np.unique(edges)
        idx = np.clip(np.searchsorted(edges, samples, side="right") - 1,
                      0, edges.size - 1)
        probs = np.bincount(idx, minlength=edges.size).astype(np.float64)
        keep = probs > 0
        return LengthDistribution(edges[keep], probs[keep] / probs.sum())
    return LengthDistribution(uniq, counts.astype(np.float64) / counts.sum())


class Predictor:
    """Interface: predict an output-length distribution for a prompt."""

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        raise NotImplementedError

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        """Feed back a completed request (history-based predictors learn)."""


class SemanticHistoryPredictor(Predictor):
    """The paper's predictor (Sec. 3.1).

    similarity_threshold: cosine threshold tau (default 0.8, Fig. 13a).
    min_matches: below this, progressively relax tau, then fall back to the
        global recent-window marginal (footnote 3's public-dataset
        augmentation is served by ``seed``).
    """

    def __init__(self, embedder: PromptEmbedder | None = None,
                 history: HistoryStore | None = None,
                 similarity_threshold: float = 0.8,
                 min_matches: int = 8,
                 max_support: int = 64,
                 default_length: int = 256):
        self.embedder = embedder or PromptEmbedder()
        self.history = history or HistoryStore(self.embedder.dim)
        self.similarity_threshold = similarity_threshold
        self.min_matches = min_matches
        self.max_support = max_support
        self.default_length = default_length
        self._embed_cache: dict[str, np.ndarray] = {}

    # -- embedding with a tiny memo so observe() reuses predict()'s work
    def _embed(self, prompt: str) -> np.ndarray:
        e = self._embed_cache.get(prompt)
        if e is None:
            e = self.embedder.embed(prompt)
            if len(self._embed_cache) > 4096:
                self._embed_cache.clear()
            self._embed_cache[prompt] = e
        return e

    def seed(self, prompts: list[str], input_lens, output_lens) -> None:
        """Warm-up augmentation with public-dataset records (footnote 3)."""
        embs = self.embedder.embed_batch(prompts)
        self.history.add_batch(embs, input_lens, output_lens)

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        emb = self._embed(prompt)
        tau = self.similarity_threshold
        idx = self.history.search_similar(emb, tau)
        while idx.size < self.min_matches and tau > 0.3:
            tau -= 0.1  # progressive relaxation before global fallback
            idx = self.history.search_similar(emb, tau)
        if idx.size >= 1:
            return empirical_distribution(self.history.output_lengths(idx),
                                          self.max_support)
        glob = self.history.global_output_lengths()
        if glob.size > 0:
            return empirical_distribution(glob, self.max_support)
        return LengthDistribution(np.array([self.default_length]),
                                  np.array([1.0]))

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self.history.add(self._embed(prompt), input_len, output_len)


class LengthHistoryPredictor(Predictor):
    """Semantic-UNAWARE ablation: retrieve history by input-length proximity
    (paper Sec. 4.3.1 baseline 1)."""

    def __init__(self, history: HistoryStore | None = None,
                 rel_tol: float = 0.2, max_support: int = 64,
                 default_length: int = 256):
        self.history = history or HistoryStore(dim=1)
        self.rel_tol = rel_tol
        self.max_support = max_support
        self.default_length = default_length
        self._zero = np.zeros(self.history.dim, np.float32)

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        idx = self.history.search_by_input_len(input_len, self.rel_tol)
        if idx.size >= 1:
            return empirical_distribution(self.history.output_lengths(idx),
                                          self.max_support)
        return LengthDistribution(np.array([self.default_length]),
                                  np.array([1.0]))

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self.history.add(self._zero, input_len, output_len)


class ProxyModelPredictor(Predictor):
    """Semantic-aware LLM-based ablation (paper Sec. 4.3.1 baseline 2).

    Stand-in for a fine-tuned DistillBERT with the argmax layer removed:
    a ridge-regression bucket-logit head over the hashed prompt embedding,
    refit periodically from accumulated (embedding, output_len) pairs.
    This emulates the *class* of model-based distribution predictors: it
    carries fit cost and pays emulation error for rare prompts.
    """

    def __init__(self, embedder: PromptEmbedder | None = None,
                 n_buckets: int = 20, bucket_width: int = 100,
                 refit_every: int = 512, l2: float = 1.0,
                 default_length: int = 256):
        self.embedder = embedder or PromptEmbedder()
        self.n_buckets = n_buckets
        self.bucket_width = bucket_width
        self.refit_every = refit_every
        self.l2 = l2
        self.default_length = default_length
        self._X: list[np.ndarray] = []
        self._y: list[int] = []
        self._W: np.ndarray | None = None  # (dim, n_buckets)
        self._since_fit = 0

    def _bucket(self, output_len: int) -> int:
        return min(self.n_buckets - 1, output_len // self.bucket_width)

    def _fit(self) -> None:
        X = np.stack(self._X)                       # (n, dim)
        Y = np.zeros((X.shape[0], self.n_buckets))  # one-hot targets
        Y[np.arange(X.shape[0]), [self._bucket(y) for y in self._y]] = 1.0
        A = X.T @ X + self.l2 * np.eye(X.shape[1])
        self._W = np.linalg.solve(A, X.T @ Y)
        self._since_fit = 0

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        if self._W is None:
            return LengthDistribution(np.array([self.default_length]),
                                      np.array([1.0]))
        logits = self.embedder.embed(prompt) @ self._W
        logits = logits - logits.max()
        probs = np.exp(logits * 4.0)  # sharpen: ridge scores are soft
        probs = probs / probs.sum()
        centers = (np.arange(self.n_buckets) + 0.5) * self.bucket_width
        keep = probs > 1e-4
        return LengthDistribution(centers[keep].astype(np.int64),
                                  probs[keep] / probs[keep].sum())

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self._X.append(self.embedder.embed(prompt))
        self._y.append(output_len)
        if len(self._X) > 20_000:  # bound memory
            self._X = self._X[-10_000:]
            self._y = self._y[-10_000:]
        self._since_fit += 1
        if self._since_fit >= self.refit_every and len(self._X) >= 64:
            self._fit()


class OraclePredictor(Predictor):
    """Knows the true distribution per request (injected by the workload);
    used to isolate the scheduling policy from prediction error."""

    def __init__(self):
        self._truth: dict[str, LengthDistribution] = {}

    def register(self, prompt: str, dist: LengthDistribution) -> None:
        self._truth[prompt] = dist

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        if prompt not in self._truth:
            raise KeyError("oracle has no registered distribution for prompt")
        return self._truth[prompt]


class PointPredictor(Predictor):
    """Collapse any predictor's distribution onto its mean — what
    point-estimate schedulers (SSJF/LTR) consume."""

    def __init__(self, inner: Predictor):
        self.inner = inner

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        d = self.inner.predict(prompt, input_len)
        return LengthDistribution(np.array([max(1, round(d.mean))]),
                                  np.array([1.0]))

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self.inner.observe(prompt, input_len, output_len)
