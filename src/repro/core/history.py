"""FIFO history store with exact (flat) cosine-similarity search.

Paper Sec. 3.1: "Our history window has a size of 10,000 records and keeps
updating in a FIFO manner. ... We use the efficient FAISS IndexFlat tool to
perform embedding search."  FAISS IndexFlat is an exact brute-force search;
we reproduce the identical algorithm as a single matmul over a pre-allocated
ring buffer — no external dependency, same results, and comparable speed at
the 10k scale (<<1 ms).

The search surface is *batch-first* (batched ingress, PR 3): a burst of B
queries is one ``(B, H)`` cosine matmul (``search_similar_batch``), and the
scalar ``search_similar`` is its B = 1 case.  Because BLAS may reorder the
reduction differently per batch shape, thresholding goes through a
deterministic exact-recheck band (``threshold_matches``) so the match set —
and everything downstream of it — is bit-identical no matter how arrivals
were batched.

The store also supports *seeding* with public-dataset records to cover the
warm-up phase (paper footnote 3: "In cases where the high-similarity
requests are insufficient ... we augment the searching set with the requests
from public datasets").
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["HistoryRecord", "HistoryStore"]

def _sim_band(dim: int) -> float:
    """Half-width of the exact-recheck band around a similarity
    threshold.  BLAS reorders the d-dim reduction differently for
    different batch shapes (a (1, d) @ (d, n) call and a (B, d) @ (d, n)
    call may disagree in the last few ulps), so a raw ``sims >= tau``
    could flip for entries within one reduction-error of tau depending
    on how the query was batched.  Entries inside the band are
    re-decided with a sequential float64 dot, which depends only on the
    stored vectors — making the match set independent of batch shape
    (the batch-ingress parity invariant).  The band must exceed the
    worst-case float32 reduction error for unit vectors, <= dim *
    eps_f32 (~1.5e-5 at dim = 256, ~2.4e-4 at dim = 4096); 4x that —
    never below 1e-4 — leaves a comfortable margin at any dim."""
    return max(1e-4, 4.0 * dim * float(np.finfo(np.float32).eps))


@dataclass(frozen=True)
class HistoryRecord:
    """One completed inference: what the predictor learns from."""

    embedding: np.ndarray  # (dim,) unit vector
    input_len: int
    output_len: int


class HistoryStore:
    """Ring buffer of completed requests + exact cosine search.

    All columns are stored as dense numpy arrays so a similarity query is a
    single (n, d) @ (d,) matvec — the IndexFlatIP equivalent.
    """

    def __init__(self, dim: int, capacity: int = 10_000):
        self.dim = dim
        self.capacity = capacity
        self._band = _sim_band(dim)
        self._emb = np.zeros((capacity, dim), dtype=np.float32)
        self._input_len = np.zeros(capacity, dtype=np.int64)
        self._output_len = np.zeros(capacity, dtype=np.int64)
        self._next = 0  # ring cursor
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def add(self, embedding: np.ndarray, input_len: int, output_len: int) -> None:
        """Record one completed request (FIFO eviction past capacity)."""
        i = self._next
        self._emb[i] = embedding
        self._input_len[i] = int(input_len)
        self._output_len[i] = int(output_len)
        self._next = (i + 1) % self.capacity
        self._size = min(self._size + 1, self.capacity)

    def add_batch(self, embeddings: np.ndarray, input_lens, output_lens) -> None:
        """Record a batch of completions in one vectorized pass.  FIFO ring
        semantics are identical to the equivalent sequence of ``add`` calls."""
        embs = np.asarray(embeddings, np.float32)
        ins = np.asarray(input_lens, np.int64)
        outs = np.asarray(output_lens, np.int64)
        b = embs.shape[0]
        if b == 0:
            return
        start = self._next
        if b >= self.capacity:
            # only the last ``capacity`` records survive the ring anyway
            embs, ins, outs = (embs[-self.capacity:], ins[-self.capacity:],
                               outs[-self.capacity:])
            start = (self._next + b - self.capacity) % self.capacity
        idx = (start + np.arange(embs.shape[0])) % self.capacity
        self._emb[idx] = embs
        self._input_len[idx] = ins
        self._output_len[idx] = outs
        self._next = (self._next + b) % self.capacity
        self._size = min(self._size + b, self.capacity)

    # ---------------------------------------------------------------- search

    def similarity_batch(self, embeddings: np.ndarray) -> np.ndarray:
        """(B, len(self)) cosine similarities in ONE sgemm — the batched
        IndexFlatIP equivalent (queries are unit vectors, rows too)."""
        q = np.asarray(embeddings, np.float32)
        if self._size == 0:
            return np.zeros((q.shape[0], 0), np.float32)
        return q @ self._emb[: self._size].T

    def threshold_matches(self, sims_row: np.ndarray, embedding: np.ndarray,
                          threshold: float) -> np.ndarray:
        """Indices with cosine similarity >= threshold, decided
        *deterministically*: entries whose approximate similarity falls
        inside the dim-scaled recheck window around the threshold are
        re-decided with a sequential float64 dot, so the result does not
        depend on the batch shape that produced ``sims_row`` (see
        ``_sim_band``)."""
        hit = sims_row >= threshold
        near = np.flatnonzero(np.abs(sims_row - threshold) < self._band)
        if near.size:
            exact = np.cumsum(self._emb[near].astype(np.float64)
                              * embedding.astype(np.float64), axis=1)[:, -1]
            hit[near] = exact >= threshold
        return np.flatnonzero(hit)

    def search_similar(self, embedding: np.ndarray, threshold: float
                       ) -> np.ndarray:
        """Indices of stored records with cosine similarity >= threshold.

        Exact flat search (FAISS IndexFlatIP semantics on unit vectors);
        the B=1 case of ``search_similar_batch``.
        """
        if self._size == 0:
            return np.zeros(0, dtype=np.int64)
        emb = np.asarray(embedding, np.float32)
        return self.threshold_matches(self.similarity_batch(emb[None])[0],
                                      emb, threshold)

    def search_similar_batch(self, embeddings: np.ndarray, thresholds
                             ) -> list[np.ndarray]:
        """Per-query match indices for a (B, dim) query block: one (B, H)
        cosine matmul + deterministic per-row thresholding.  ``thresholds``
        is a scalar or a (B,) array (per-row tau)."""
        q = np.asarray(embeddings, np.float32)
        b = q.shape[0]
        if b == 0 or self._size == 0:
            return [np.zeros(0, dtype=np.int64) for _ in range(b)]
        sims = self.similarity_batch(q)
        thr = np.broadcast_to(np.asarray(thresholds, np.float64), (b,))
        return [self.threshold_matches(sims[i], q[i], float(thr[i]))
                for i in range(b)]

    def search_by_input_len(self, input_len: int, rel_tol: float = 0.2,
                            min_matches: int = 8) -> np.ndarray:
        """Semantic-UNAWARE ablation (Sec. 4.3.1 baseline 1): match by
        input-length proximity instead of prompt content.  The B=1 case of
        ``search_by_input_len_batch``."""
        return self.search_by_input_len_batch([input_len], rel_tol,
                                              min_matches)[0]

    def search_by_input_len_batch(self, input_lens, rel_tol: float = 0.2,
                                  min_matches: int = 8) -> list[np.ndarray]:
        """Per-query input-length-proximity matches for a burst.  Integer
        arithmetic throughout, so batch and scalar results are identical by
        construction (no floating-point reduction involved)."""
        il = np.asarray(input_lens, np.int64)
        b = il.shape[0]
        if b == 0 or self._size == 0:
            return [np.zeros(0, dtype=np.int64) for _ in range(b)]
        lens = self._input_len[: self._size]
        tol = np.maximum(1, (rel_tol * np.maximum(1, il)).astype(np.int64))
        out = []
        for i in range(b):
            diff = np.abs(lens - il[i])
            idx = np.nonzero(diff <= tol[i])[0]
            if idx.size < min_matches:
                # widen to the nearest ``min_matches`` records by |Δ len|
                order = np.argsort(diff, kind="stable")
                idx = order[: min(min_matches, self._size)]
            out.append(idx)
        return out

    def output_lengths(self, indices: np.ndarray) -> np.ndarray:
        return self._output_len[indices]

    def input_lengths(self, indices: np.ndarray) -> np.ndarray:
        return self._input_len[indices]

    def global_output_lengths(self) -> np.ndarray:
        """All recorded output lengths (recent-window marginal)."""
        return self._output_len[: self._size].copy()
