"""FIFO history store with exact (flat) cosine-similarity search.

Paper Sec. 3.1: "Our history window has a size of 10,000 records and keeps
updating in a FIFO manner. ... We use the efficient FAISS IndexFlat tool to
perform embedding search."  FAISS IndexFlat is an exact brute-force search;
we reproduce the identical algorithm as a single matmul over a pre-allocated
ring buffer — no external dependency, same results, and comparable speed at
the 10k scale (<<1 ms).

The store also supports *seeding* with public-dataset records to cover the
warm-up phase (paper footnote 3: "In cases where the high-similarity
requests are insufficient ... we augment the searching set with the requests
from public datasets").
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["HistoryRecord", "HistoryStore"]


@dataclass(frozen=True)
class HistoryRecord:
    """One completed inference: what the predictor learns from."""

    embedding: np.ndarray  # (dim,) unit vector
    input_len: int
    output_len: int


class HistoryStore:
    """Ring buffer of completed requests + exact cosine search.

    All columns are stored as dense numpy arrays so a similarity query is a
    single (n, d) @ (d,) matvec — the IndexFlatIP equivalent.
    """

    def __init__(self, dim: int, capacity: int = 10_000):
        self.dim = dim
        self.capacity = capacity
        self._emb = np.zeros((capacity, dim), dtype=np.float32)
        self._input_len = np.zeros(capacity, dtype=np.int64)
        self._output_len = np.zeros(capacity, dtype=np.int64)
        self._next = 0  # ring cursor
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def add(self, embedding: np.ndarray, input_len: int, output_len: int) -> None:
        """Record one completed request (FIFO eviction past capacity)."""
        i = self._next
        self._emb[i] = embedding
        self._input_len[i] = int(input_len)
        self._output_len[i] = int(output_len)
        self._next = (i + 1) % self.capacity
        self._size = min(self._size + 1, self.capacity)

    def add_batch(self, embeddings: np.ndarray, input_lens, output_lens) -> None:
        for e, i, o in zip(embeddings, input_lens, output_lens):
            self.add(e, int(i), int(o))

    # ---------------------------------------------------------------- search

    def search_similar(self, embedding: np.ndarray, threshold: float
                       ) -> np.ndarray:
        """Indices of stored records with cosine similarity >= threshold.

        Exact flat search (FAISS IndexFlatIP semantics on unit vectors).
        """
        if self._size == 0:
            return np.zeros(0, dtype=np.int64)
        sims = self._emb[: self._size] @ embedding.astype(np.float32)
        return np.nonzero(sims >= threshold)[0]

    def search_by_input_len(self, input_len: int, rel_tol: float = 0.2,
                            min_matches: int = 8) -> np.ndarray:
        """Semantic-UNAWARE ablation (Sec. 4.3.1 baseline 1): match by
        input-length proximity instead of prompt content."""
        if self._size == 0:
            return np.zeros(0, dtype=np.int64)
        lens = self._input_len[: self._size]
        tol = max(1, int(rel_tol * max(1, input_len)))
        idx = np.nonzero(np.abs(lens - input_len) <= tol)[0]
        if idx.size < min_matches:
            # widen to the nearest ``min_matches`` records by |Δ input_len|
            order = np.argsort(np.abs(lens - input_len), kind="stable")
            idx = order[: min(min_matches, self._size)]
        return idx

    def output_lengths(self, indices: np.ndarray) -> np.ndarray:
        return self._output_len[indices]

    def input_lengths(self, indices: np.ndarray) -> np.ndarray:
        return self._input_len[indices]

    def global_output_lengths(self) -> np.ndarray:
        """All recorded output lengths (recent-window marginal)."""
        return self._output_len[: self._size].copy()
