"""Gittins index for discrete cost distributions (paper Sec. 3.3).

For a request with (remaining-)cost distribution D, the Gittins index is

    G(D) = inf_{Delta > 0}  E[min(X, Delta)] / P(X <= Delta),   X ~ D.

Smaller G = higher priority.  For M/G/1-style mean-latency scheduling with
known duration distributions, serving the smallest Gittins index is optimal
(Gittins & Jones 1979; Gittins 1989) — this is the paper's queuing policy.

For a *discrete* distribution with support c_1 < ... < c_k the infimum is
attained at some Delta = c_j (the objective is piecewise-linear in Delta
between support points, increasing in Delta past the last mass that the
budget can reach), so the index reduces to a min over k candidate ratios:

    G = min_j  [ sum_{i<=j} c_i p_i + c_j * (1 - sum_{i<=j} p_i) ]
               / sum_{i<=j} p_i

computable with two prefix sums — O(k).  ``gittins_index_batch`` evaluates
a batch of bucketized distributions at once (the form the Pallas kernel in
``repro.kernels.gittins`` accelerates for large cluster schedulers).

Runtime refresh (paper): after a request has consumed ``attained`` cost,
its remaining-cost distribution is D conditioned on X > attained and
shifted; the paper refreshes only at cost-bucket boundaries to bound
overhead and avoid priority thrashing.  That bucketization lives in
``repro.core.scheduler``; here we expose the pure math.
"""

from __future__ import annotations

import numpy as np

from .cost_model import CostDistribution

__all__ = ["gittins_index", "gittins_index_batch", "mean_index",
           "mean_index_batch"]


def gittins_index(dist: CostDistribution, attained: float = 0.0) -> float:
    """Gittins index of the remaining cost after ``attained`` service."""
    d = dist.shift(attained) if attained > 0.0 else dist
    c = d.support
    p = d.probs
    mass = np.cumsum(p)                       # P(X <= c_j)
    spent = np.cumsum(c * p)                  # E[X ; X <= c_j]
    num = spent + c * (1.0 - mass)            # E[min(X, c_j)]
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(mass > 0.0, num / mass, np.inf)
    return float(ratio.min())


def _tail_belief(support: np.ndarray, probs: np.ndarray) -> np.ndarray:
    """Per-row tail belief for exhausted predictions: the largest real
    support value (clamped to >= 1), matching ``CostDistribution.shift``."""
    return np.maximum(
        np.max(np.where(probs > 0.0, support, -np.inf), axis=1), 1.0)


def _condition_batch(support: np.ndarray, probs: np.ndarray,
                     attained: np.ndarray | None):
    """Batched form of ``CostDistribution.shift``: condition each row on
    X > attained[i] and re-origin, entirely with masks (no ragged
    filtering).  Zeroed-out entries contribute exact 0.0 to every cumsum,
    so results at live positions are bit-identical to the scalar path.

    Returns (c, p, alive, exhausted): remaining-cost support, conditioned
    probabilities, live mask, and a mask of rows whose predicted mass is
    fully consumed (None when no row is — the common case, so the tail
    belief is only materialized when needed).
    """
    valid = probs > 0.0                      # padded entries carry prob 0
    if attained is None:
        return support, probs, valid, None
    att = np.maximum(np.asarray(attained, np.float64), 0.0)
    cond = att > 0.0                         # rows that actually shift
    all_cond = bool(cond.all())
    if all_cond:
        alive = valid & (support > att[:, None])
    else:
        alive = valid & (~cond[:, None] | (support > att[:, None]))
    p = np.where(alive, probs, 0.0)
    psum = np.cumsum(p, axis=1)[:, -1]       # sequential, matches .shift()
    exhausted = cond & (psum <= 0.0)
    safe = np.where(psum > 0.0, psum, 1.0)
    if all_cond:
        p /= safe[:, None]                   # p is a fresh temp: in-place
    else:
        p = np.where(cond[:, None], p / safe[:, None], p)
    c = np.where(alive, support - att[:, None], 0.0)
    return c, p, alive, exhausted if exhausted.any() else None


def gittins_index_batch(support: np.ndarray, probs: np.ndarray,
                        attained: np.ndarray | None = None) -> np.ndarray:
    """Vectorized Gittins indices for a batch of distributions.

    support: (n, k) cost support, non-decreasing along axis 1 (for ragged
        batches pad with prob 0; any finite pad support value works —
        padded columns are masked out).
    probs:   (n, k) probabilities (each row sums to 1; padded entries 0).
    attained: optional (n,) cost already consumed per row; each row is
        conditioned on X > attained and re-origined exactly like
        ``CostDistribution.shift`` (including the exhausted-prediction
        tail belief), making this the one-call batched equivalent of
        ``gittins_index(dist_i, attained_i)`` for every i.
    Returns (n,) indices.  This is the numpy oracle for the Pallas kernel.
    """
    support = np.asarray(support, np.float64)
    probs = np.asarray(probs, np.float64)
    c, p, alive, exhausted = _condition_batch(support, probs, attained)
    # pre-zero dead columns: no inf * 0.  The conditioned path already
    # returns c zeroed at dead columns, so only the raw path pays a copy.
    cz = c if attained is not None else np.where(alive, c, 0.0)
    mass = np.cumsum(p, axis=1)
    spent = np.cumsum(cz * p, axis=1)
    num = spent + cz * (1.0 - mass)
    # at every alive column mass >= its own (positive) prob, so ``alive``
    # alone gates the division safely
    ratio = np.where(alive, num / np.where(alive, mass, 1.0), np.inf)
    out = ratio.min(axis=1)
    if exhausted is not None:
        out = np.where(exhausted, _tail_belief(support, probs), out)
    return out


def mean_index(dist: CostDistribution, attained: float = 0.0) -> float:
    """Ablation (paper Fig. 6 / Fig. 11 'Mean'): expected remaining cost."""
    d = dist.shift(attained) if attained > 0.0 else dist
    return d.mean


def mean_index_batch(support: np.ndarray, probs: np.ndarray,
                     attained: np.ndarray | None = None) -> np.ndarray:
    """Batched ``mean_index``: expected remaining cost per row, with the
    same conditioning/tail semantics as ``gittins_index_batch``."""
    support = np.asarray(support, np.float64)
    probs = np.asarray(probs, np.float64)
    c, p, alive, exhausted = _condition_batch(support, probs, attained)
    cz = c if attained is not None else np.where(alive, c, 0.0)
    mean = np.cumsum(cz * p, axis=1)[:, -1]
    if exhausted is not None:
        mean = np.where(exhausted, _tail_belief(support, probs), mean)
    return mean
