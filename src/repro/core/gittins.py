"""Gittins index for discrete cost distributions (paper Sec. 3.3).

For a request with (remaining-)cost distribution D, the Gittins index is

    G(D) = inf_{Delta > 0}  E[min(X, Delta)] / P(X <= Delta),   X ~ D.

Smaller G = higher priority.  For M/G/1-style mean-latency scheduling with
known duration distributions, serving the smallest Gittins index is optimal
(Gittins & Jones 1979; Gittins 1989) — this is the paper's queuing policy.

For a *discrete* distribution with support c_1 < ... < c_k the infimum is
attained at some Delta = c_j (the objective is piecewise-linear in Delta
between support points, increasing in Delta past the last mass that the
budget can reach), so the index reduces to a min over k candidate ratios:

    G = min_j  [ sum_{i<=j} c_i p_i + c_j * (1 - sum_{i<=j} p_i) ]
               / sum_{i<=j} p_i

computable with two prefix sums — O(k).  ``gittins_index_batch`` evaluates
a batch of bucketized distributions at once (the form the Pallas kernel in
``repro.kernels.gittins`` accelerates for large cluster schedulers).

Runtime refresh (paper): after a request has consumed ``attained`` cost,
its remaining-cost distribution is D conditioned on X > attained and
shifted; the paper refreshes only at cost-bucket boundaries to bound
overhead and avoid priority thrashing.  That bucketization lives in
``repro.core.scheduler``; here we expose the pure math.
"""

from __future__ import annotations

import numpy as np

from .cost_model import CostDistribution

__all__ = ["gittins_index", "gittins_index_batch", "mean_index"]


def gittins_index(dist: CostDistribution, attained: float = 0.0) -> float:
    """Gittins index of the remaining cost after ``attained`` service."""
    d = dist.shift(attained) if attained > 0.0 else dist
    c = d.support
    p = d.probs
    mass = np.cumsum(p)                       # P(X <= c_j)
    spent = np.cumsum(c * p)                  # E[X ; X <= c_j]
    num = spent + c * (1.0 - mass)            # E[min(X, c_j)]
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(mass > 0.0, num / mass, np.inf)
    return float(ratio.min())


def gittins_index_batch(support: np.ndarray, probs: np.ndarray) -> np.ndarray:
    """Vectorized Gittins indices for a batch of distributions.

    support: (n, k) cost support, ascending along axis 1 (pad with +inf /
        prob 0 for ragged batches).
    probs:   (n, k) probabilities (each row sums to 1; padded entries 0).
    Returns (n,) indices.  This is the numpy oracle for the Pallas kernel.
    """
    support = np.asarray(support, np.float64)
    probs = np.asarray(probs, np.float64)
    mass = np.cumsum(probs, axis=1)
    spent = np.cumsum(support * probs, axis=1)
    num = spent + support * (1.0 - mass)
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(mass > 1e-12, num / mass, np.inf)
    return ratio.min(axis=1)


def mean_index(dist: CostDistribution, attained: float = 0.0) -> float:
    """Ablation (paper Fig. 6 / Fig. 11 'Mean'): expected remaining cost."""
    d = dist.shift(attained) if attained > 0.0 else dist
    return d.mean
