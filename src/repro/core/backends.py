"""Pluggable priority-evaluation backends for the batched scheduler.

The array-native refresh path (``Scheduler.refresh``) hands each policy a
``BatchView`` — parallel arrays over the dirty subset of live requests —
plus one of these backends, which own the actual batched index math:

  * ``NumpyPriorityBackend``  — float64 vectorized numpy; bit-identical
    to the scalar per-request oracle (``gittins_index`` applied to
    ``CostDistribution.shift``), which is what makes object-path vs
    batch-path simulations reproduce identical schedules.
  * ``PallasPriorityBackend`` — the jit'd Pallas TPU kernel from
    ``repro.kernels.gittins.ops`` with persistent power-of-two batch
    padding (recompiles only at pow2 boundaries) and automatic
    ``interpret=True`` fallback off-TPU.  float32: priorities agree with
    the oracle to ~1e-5 relative, not bitwise.

``make_priority_backend`` resolves "numpy" / "pallas" (and "object",
which the Scheduler intercepts before ever reaching a backend).
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from .gittins import gittins_index_batch, mean_index_batch

__all__ = ["BatchView", "PriorityBackend", "NumpyPriorityBackend",
           "PallasPriorityBackend", "make_priority_backend", "BACKEND_NAMES"]


class BatchView(NamedTuple):
    """Structure-of-arrays slice handed to ``Policy.priority_batch``.

    (n, k) arrays hold bucketized distributions: supports non-decreasing
    along axis 1, padded columns carry prob 0 (support repeats its last
    real value, so row maxima and quantile lookups stay correct).
    """

    cost_sup: np.ndarray    # (n, k) cost support
    cost_probs: np.ndarray  # (n, k) cost probabilities
    len_sup: np.ndarray     # (n, k) output-length support
    len_probs: np.ndarray   # (n, k) output-length probabilities
    generated: np.ndarray   # (n,) output tokens produced
    attained: np.ndarray    # (n,) cost consumed so far
    arrival: np.ndarray     # (n,) arrival timestamps (tie-break encoded)
    input_len: np.ndarray   # (n,) prompt lengths


class PriorityBackend:
    """Batched evaluators for the two cost-distribution indices."""

    name = "base"

    def gittins(self, support, probs, attained) -> np.ndarray:
        raise NotImplementedError

    def mean(self, support, probs, attained) -> np.ndarray:
        raise NotImplementedError


class NumpyPriorityBackend(PriorityBackend):
    """float64 numpy; the reference batched backend."""

    name = "numpy"

    def gittins(self, support, probs, attained) -> np.ndarray:
        return gittins_index_batch(support, probs, attained)

    def mean(self, support, probs, attained) -> np.ndarray:
        return mean_index_batch(support, probs, attained)


class PallasPriorityBackend(PriorityBackend):
    """Gittins indices through the Pallas TPU kernel (interpret-mode on
    CPU); the mean index stays numpy — it is a single cumsum and never
    the bottleneck."""

    name = "pallas"

    def __init__(self, block_n: int = 256, force_pallas: bool = False):
        # imported lazily so repro.core stays importable without jax
        from ..kernels.gittins.ops import gittins_attained_op
        self._op = gittins_attained_op
        self.block_n = block_n
        self.force_pallas = force_pallas

    def gittins(self, support, probs, attained) -> np.ndarray:
        out = self._op(support, probs, attained, block_n=self.block_n,
                       force_pallas=self.force_pallas)
        return np.asarray(out, np.float64)

    def mean(self, support, probs, attained) -> np.ndarray:
        return mean_index_batch(support, probs, attained)


BACKEND_NAMES = ("object", "numpy", "pallas")


def make_priority_backend(name, **kwargs) -> PriorityBackend | None:
    """Resolve a backend spec: an instance passes through; "object"
    returns None (the Scheduler keeps the scalar per-request path)."""
    if isinstance(name, PriorityBackend):
        return name
    if name is None or name == "object":
        return None
    if name == "numpy":
        return NumpyPriorityBackend()
    if name == "pallas":
        return PallasPriorityBackend(**kwargs)
    raise KeyError(f"unknown priority backend {name!r}; have {BACKEND_NAMES}")
