"""Training-free prompt embeddings for semantic similarity search.

The paper's predictor (Sec. 3.1) needs a light-weight prompt embedding to
retrieve similar historical requests.  The paper reports 0.22 ms per
embedding — i.e. something far cheaper than a transformer forward pass.
We use deterministic feature hashing with sign hashing (a sparse
random-projection-equivalent, training-free embedding) over word unigrams,
word bigrams, and intra-word character n-grams.  Cosine similarity between
two such embeddings approximates the weighted token-multiset overlap of
the prompts, which is exactly the "prompt similarity" signal the paper
exploits (Fig. 4).

This is the TPU/CPU-portable stand-in for the DistillBERT embeddings of
(Qiu et al., 2024): training-free, model-agnostic, sub-millisecond.
"""

from __future__ import annotations

import zlib

import numpy as np

__all__ = ["PromptEmbedder"]


class PromptEmbedder:
    """Hash lexical features into a fixed-dimension, L2-normalized vector.

    Features per prompt: word unigrams (weight 1.0), word bigrams (0.5),
    and character 4-grams inside words (0.25, for morphological overlap).
    Deterministic (seeded by ``salt``), stateless, and cheap: one pass over
    the text, two CRC32-derived values per feature (index + sign).
    """

    def __init__(self, dim: int = 256, salt: int = 0x5A6E,
                 bigram_weight: float = 0.5, chargram_weight: float = 0.25):
        if dim <= 0:
            raise ValueError(f"dim must be positive, got {dim}")
        self.dim = dim
        self.salt = salt
        self.bigram_weight = bigram_weight
        self.chargram_weight = chargram_weight
        self._salt_bytes = salt.to_bytes(4, "little")

    def _add(self, vec: np.ndarray, feature: str, weight: float) -> None:
        h = zlib.crc32(feature.encode("utf-8", "ignore") + self._salt_bytes)
        sign = 1.0 if (h >> 16) & 1 else -1.0
        vec[h % self.dim] += sign * weight

    def embed(self, text: str) -> np.ndarray:
        """Embed one prompt. Returns float32 unit vector of shape (dim,)."""
        vec = np.zeros(self.dim, dtype=np.float32)
        words = text.lower().split()
        for w in words:
            self._add(vec, "u:" + w, 1.0)
            if self.chargram_weight > 0.0:
                for i in range(len(w) - 3):
                    self._add(vec, "c:" + w[i:i + 4], self.chargram_weight)
        if self.bigram_weight > 0.0:
            for a, b in zip(words, words[1:]):
                self._add(vec, "b:" + a + " " + b, self.bigram_weight)
        norm = float(np.linalg.norm(vec))
        if norm > 0.0:
            vec /= norm
        return vec

    def embed_batch(self, texts: list[str]) -> np.ndarray:
        """Embed a batch of prompts. Returns (len(texts), dim) float32."""
        if not texts:
            return np.zeros((0, self.dim), dtype=np.float32)
        return np.stack([self.embed(t) for t in texts])
