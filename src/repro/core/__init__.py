"""SageSched core: the paper's contribution as a composable library.

Public API:
    PromptEmbedder, HistoryStore                      (Sec. 3.1 substrate)
    SemanticHistoryPredictor + ablation predictors    (Sec. 3.1 / 4.3.1)
    ResourceBoundCost + ablation cost models          (Sec. 3.2 / 4.3.2)
    gittins_index / gittins_index_batch               (Sec. 3.3 math)
    make_policy: fcfs/fastserve/ssjf/ltr/trail/mean/gittins/sagesched/hedged
    Scheduler: the Fig. 3 workflow facade
    CalibrationMonitor / truncate_rows / prediction_loss  (drift robustness)
"""

from .backends import (BACKEND_NAMES, BatchView, NumpyPriorityBackend,
                       PallasPriorityBackend, PriorityBackend,
                       make_priority_backend)
from .cost_model import (CostDistribution, CostModel, EncDecCost, HybridCost,
                         LinearCost, OutputLengthCost, OverallLengthCost,
                         ResourceBoundCost, bucketize_support,
                         eviction_scores, make_cost_model)
from .embedding import PromptEmbedder
from .gittins import (gittins_index, gittins_index_batch, mean_index,
                      mean_index_batch)
from .history import HistoryRecord, HistoryStore
from .policies import POLICY_NAMES, HedgedPolicy, Policy, make_policy
from .predictor import (LengthDistribution, LengthHistoryPredictor,
                        OraclePredictor, PointPredictor, Predictor,
                        ProxyModelPredictor, SemanticHistoryPredictor,
                        empirical_distribution)
from .robust import CalibrationMonitor, crps, prediction_loss, truncate_rows
from .scheduler import BatchState, ScheduledRequest, Scheduler

__all__ = [
    "CostDistribution", "CostModel", "EncDecCost", "HybridCost", "LinearCost",
    "OutputLengthCost", "OverallLengthCost", "ResourceBoundCost",
    "bucketize_support", "eviction_scores", "make_cost_model",
    "PromptEmbedder",
    "gittins_index", "gittins_index_batch", "mean_index", "mean_index_batch",
    "BACKEND_NAMES", "BatchView", "NumpyPriorityBackend",
    "PallasPriorityBackend", "PriorityBackend", "make_priority_backend",
    "HistoryRecord", "HistoryStore",
    "POLICY_NAMES", "HedgedPolicy", "Policy", "make_policy",
    "LengthDistribution",
    "LengthHistoryPredictor", "OraclePredictor", "PointPredictor",
    "Predictor", "ProxyModelPredictor", "SemanticHistoryPredictor",
    "empirical_distribution", "BatchState", "ScheduledRequest",
    "Scheduler",
    "CalibrationMonitor", "crps", "prediction_loss", "truncate_rows",
]
