"""Resource-bound-based service-cost modeling (paper Sec. 3.2).

The paper's key observation: whether the backend is *memory-bound* (cost =
cumulative KVCache occupation, ``sum_{l=1}^{I+O} l * U_MT``) or
*compute-bound* (cost = cumulative attention compute,
``sum_{l=I}^{I+O} l * U_CT``), the service cost of a request with input
length I and output length O follows the same paradigm::

    C(I, O) = O^2 / 2 + I * O        (unit constants cancel in rank order)

We implement that model, the two ablation baselines from Sec. 4.3.2
(output-length-only and weighted-overall-length), and the per-architecture
adaptations documented in DESIGN.md Sec. 4 (linear cost for attention-free
SSMs, mixed cost for hybrids, enc-dec cost with one-shot encoder term).

Every model exposes:
  * ``total(I, O)``          — scalar cost of a full request,
  * ``attained(I, o)``       — cost already *consumed* after generating
                                ``o`` of the eventual O tokens (used to
                                refresh the Gittins index at runtime),
  * ``distribution(I, length_dist)`` — pushforward of an output-length
                                distribution through ``total``.

``attained`` is exact: it is the same cumulative sum truncated at ``o``,
so remaining cost = total − attained, consistent with SRPT/Gittins theory.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = [
    "CostModel",
    "ResourceBoundCost",
    "OutputLengthCost",
    "OverallLengthCost",
    "LinearCost",
    "HybridCost",
    "EncDecCost",
    "CostDistribution",
    "bucketize_support",
    "eviction_scores",
    "make_cost_model",
    "quantile_index",
]


def eviction_scores(ranks: np.ndarray, swap_costs: np.ndarray,
                    memory_weight: float) -> np.ndarray:
    """Capacity-forced-eviction scores — HIGHER means evict FIRST.

    The paper's hybrid true-service-cost says preempting a request is not
    free: its KV must be swapped back in before it can resume, so the
    eviction decision should weigh *service urgency* (the policy's
    priority ranking) against the *memory-restoration cost* (held KV
    bytes ~ predicted swap IO — ``ServiceModel.swap_time`` is affine in
    held bytes, so the two terms merge into one).  Both terms are
    normalized to [0, 1], making the trade-off scale-free across cost
    models whose raw priorities live in arbitrary units:

        score = rank / (n-1)  -  memory_weight * swap / max(swap)

    ``ranks``: position in the policy's order() (0 = most urgent);
    ``swap_costs``: predicted restore cost per candidate (seconds, or
    held tokens/bytes as a proxy); ``memory_weight = 0`` reduces to
    pure reversed priority order (the vLLM baseline).
    """
    ranks = np.asarray(ranks, np.float64)
    n = ranks.shape[0]
    rank_norm = ranks / max(1, n - 1)
    swap = np.asarray(swap_costs, np.float64)
    top = swap.max()
    swap_norm = swap / top if top > 0 else np.zeros_like(swap)
    return rank_norm - float(memory_weight) * swap_norm


def quantile_index(probs: np.ndarray, q: float) -> int:
    """Index of the smallest support point whose CDF reaches ``q``.

    Float rounding can leave cdf[-1] < q (e.g. 0.9999999998 < 1.0), in
    which case searchsorted returns len(cdf) — clip to the last support
    point.  Shared by ``CostDistribution.quantile`` and
    ``LengthDistribution.quantile``.
    """
    cdf = np.cumsum(probs)
    return min(int(np.searchsorted(cdf, q)), probs.shape[0] - 1)


def bucketize_support(support: np.ndarray, probs: np.ndarray, k: int
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Pack a ragged discrete distribution into exactly ``k`` points.

    When the distribution has <= k points it is *padded*: the support
    repeats its last (largest) value and the padded probabilities are 0,
    which keeps the support non-decreasing and is exactly inert for every
    batched priority computation (they mask on ``probs > 0``).  When it
    has > k points it is *compressed* by equal-mass binning, with each
    bin represented by its conditional mean — this is the only lossy path
    and is avoided in practice by BatchState's column auto-growth.
    """
    support = np.asarray(support, np.float64)
    probs = np.asarray(probs, np.float64)
    k0 = support.shape[0]
    if k0 == k:
        return support.copy(), probs.copy()
    if k0 < k:
        sup = np.concatenate([support, np.full(k - k0, support[-1])])
        p = np.concatenate([probs, np.zeros(k - k0)])
        return sup, p
    # compress: equal-mass bins, conditional-mean representatives
    cdf = np.cumsum(probs)
    edges = np.searchsorted(cdf, np.linspace(0.0, 1.0, k + 1)[1:-1],
                            side="left")
    bins = np.unique(np.concatenate([[0], edges + 1, [k0]]))
    sup = np.empty(k, np.float64)
    p = np.zeros(k, np.float64)
    for j in range(len(bins) - 1):
        lo, hi = bins[j], bins[j + 1]
        m = probs[lo:hi].sum()
        p[j] = m
        sup[j] = (support[lo:hi] @ probs[lo:hi]) / m if m > 0 \
            else support[lo:hi].mean()
    used = len(bins) - 1
    sup[used:] = sup[used - 1]
    p = p / p.sum()
    return np.maximum.accumulate(sup), p


@dataclass(frozen=True)
class CostDistribution:
    """Discrete cost distribution: support (ascending) + probabilities."""

    support: np.ndarray  # (k,) float64, strictly ascending
    probs: np.ndarray    # (k,) float64, sums to 1

    def __post_init__(self):
        object.__setattr__(self, "support", np.asarray(self.support, np.float64))
        object.__setattr__(self, "probs", np.asarray(self.probs, np.float64))

    @property
    def mean(self) -> float:
        # sequential (cumsum) summation so the batched refresh path —
        # which runs cumsum over zero-padded (n, k) rows — is bit-identical
        # to this scalar oracle
        return float(np.cumsum(self.support * self.probs)[-1])

    def bucketize(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        """Fixed-k (support, probs) arrays for BatchState packing: padded
        (repeat-last support, zero prob) when <= k points, equal-mass
        compressed otherwise.  See ``bucketize_support``."""
        return bucketize_support(self.support, self.probs, k)

    def quantile(self, q: float) -> float:
        """Smallest support point with CDF >= q.  Routing on an upper
        quantile instead of the mean is the robust-placement knob of
        ``CostAwareRouter(route_quantile=...)``."""
        return float(self.support[quantile_index(self.probs, q)])

    def truncate(self, attained: float) -> "CostDistribution | None":
        """Condition on X > ``attained`` WITHOUT re-origining — the
        mid-flight posterior update (repro.core.robust), the absolute-
        support sibling of ``shift``: ``shift`` answers "what remains
        from here" for a Gittins evaluation, ``truncate`` updates the
        stored belief itself so every later consumer (means, quantiles,
        shed scores, further shifts) sees only the unfalsified mass.
        Returns None when everything is falsified (caller substitutes a
        tail belief).  Sequential cumsum renormalizer: bit-identical to
        the batched ``robust.truncate_rows``."""
        alive = self.support > attained
        if not alive.any():
            return None
        p = self.probs[alive]
        return CostDistribution(self.support[alive], p / np.cumsum(p)[-1])

    def shift(self, attained: float) -> "CostDistribution":
        """Condition on X > ``attained`` and re-origin at it (the Bayesian
        update behind the paper's runtime Gittins refresh: mass at costs the
        request has already consumed without finishing is impossible and is
        conditioned out).  If the whole predicted mass is exhausted, the
        remaining cost collapses to "imminent completion"."""
        alive = self.support > attained
        if not alive.any():
            # Prediction exhausted: the request already outran every
            # predicted outcome.  LLM length distributions have decreasing
            # hazard rates (lognormal-like), so the rational belief is a
            # LONG remaining tail, not imminent completion — assume one
            # more max-support's worth of cost (pinning such requests to
            # top priority instead measurably inflates mean TTLT;
            # EXPERIMENTS.md §Perf).
            tail = max(float(self.support[-1]), 1.0)
            return CostDistribution(np.array([tail]), np.array([1.0]))
        rem = self.support[alive] - attained
        probs = self.probs[alive]
        # sequential normalizer (see ``mean``): keeps scalar and batched
        # conditioning bit-identical
        return CostDistribution(rem, probs / np.cumsum(probs)[-1])


class CostModel:
    """Base class; subclasses override ``total`` (vectorized over O)."""

    name = "base"

    def total(self, input_len, output_len):
        raise NotImplementedError

    def attained(self, input_len: int, generated: int) -> float:
        """Cost consumed so far, after ``generated`` output tokens."""
        return float(self.total(input_len, generated))

    def attained_batch(self, input_lens: np.ndarray, generated: np.ndarray
                       ) -> np.ndarray:
        """Vectorized ``attained`` over parallel (n,) arrays.  Subclasses
        override with closed forms; this fallback loops (correct for any
        model, slow — it exists so custom models keep working)."""
        return np.array([self.attained(int(i), int(g))
                         for i, g in zip(np.asarray(input_lens),
                                         np.asarray(generated))], np.float64)

    def distribution(self, input_len: int, lengths: np.ndarray,
                     probs: np.ndarray) -> CostDistribution:
        """Pushforward of an output-length distribution through ``total``.

        ``lengths``/``probs`` describe P(O = lengths[i]) = probs[i].
        """
        costs = np.asarray(self.total(input_len, np.asarray(lengths, np.float64)))
        probs = np.asarray(probs, np.float64)
        if costs.size and np.all(np.diff(costs) > 0):
            # every model here is monotone in O over an ascending support,
            # so the sort/unique/merge below is almost always the identity
            # — skip it (bit-identical: the general path's stable argsort,
            # unique and add.at reduce to copies when costs are strictly
            # ascending)
            return CostDistribution(costs, probs / probs.sum())
        order = np.argsort(costs, kind="stable")
        costs, probs = costs[order], probs[order]
        uniq, inv = np.unique(costs, return_inverse=True)
        merged = np.zeros_like(uniq)
        np.add.at(merged, inv, probs)
        merged = merged / merged.sum()
        return CostDistribution(uniq, merged)

    def distribution_batch(self, input_lens, length_dists
                           ) -> list[CostDistribution]:
        """Batched pushforward: one ``CostDistribution`` per
        (input_len, LengthDistribution) pair.  Supports are ragged, so
        the merge stays per-row; the batched-ingress win is amortizing
        the *prediction* and the BatchState writes around this call.
        Equals the sequence of scalar ``distribution`` calls exactly.
        """
        return [self.distribution(int(il), ld.lengths, ld.probs)
                for il, ld in zip(input_lens, length_dists)]


class ResourceBoundCost(CostModel):
    """The paper's model: C = O^2/2 + I*O (Sec. 3.2)."""

    name = "resource_bound"

    def total(self, input_len, output_len):
        o = np.asarray(output_len, np.float64)
        return o * o / 2.0 + float(input_len) * o

    def attained_batch(self, input_lens, generated):
        i = np.asarray(input_lens, np.float64)
        g = np.asarray(generated, np.float64)
        return g * g / 2.0 + i * g


class OutputLengthCost(CostModel):
    """Ablation: C = O (SSJF / LTR / TRAIL cost proxy)."""

    name = "output_length"

    def total(self, input_len, output_len):
        return np.asarray(output_len, np.float64)

    def attained_batch(self, input_lens, generated):
        return np.asarray(generated, np.float64).copy()


class OverallLengthCost(CostModel):
    """Ablation: C = I + 2*O (VTC-style weighted token count,
    Sheng et al. 2024; the paper doubles the output weight)."""

    name = "overall_length"

    def total(self, input_len, output_len):
        return float(input_len) + 2.0 * np.asarray(output_len, np.float64)

    def attained_batch(self, input_lens, generated):
        return np.asarray(input_lens, np.float64) \
            + 2.0 * np.asarray(generated, np.float64)


class LinearCost(CostModel):
    """SSM adaptation: constant state, constant per-step cost →
    C = (I + O) (DESIGN.md Sec. 4, mamba2)."""

    name = "linear"

    def total(self, input_len, output_len):
        return float(input_len) + np.asarray(output_len, np.float64)

    def attained_batch(self, input_lens, generated):
        return np.asarray(input_lens, np.float64) \
            + np.asarray(generated, np.float64)


class HybridCost(CostModel):
    """Hybrid (Zamba2): alpha * quadratic attention term from the shared
    attention blocks + beta * linear SSM term."""

    name = "hybrid"

    def __init__(self, attn_fraction: float = 0.15, ssm_fraction: float = 0.85,
                 ssm_step_weight: float = 64.0):
        # ssm_step_weight converts "one SSM step" into KV-token-step units so
        # the two terms are commensurable (d_state-sized recurrent state).
        self.alpha = attn_fraction
        self.beta = ssm_fraction * ssm_step_weight

    def total(self, input_len, output_len):
        o = np.asarray(output_len, np.float64)
        quad = o * o / 2.0 + float(input_len) * o
        lin = float(input_len) + o
        return self.alpha * quad + self.beta * lin

    def attained_batch(self, input_lens, generated):
        i = np.asarray(input_lens, np.float64)
        g = np.asarray(generated, np.float64)
        return self.alpha * (g * g / 2.0 + i * g) + self.beta * (i + g)


class EncDecCost(CostModel):
    """Encoder-decoder (Seamless backbone): one-shot encoder cost ~ I^2
    (prefill-like), decoder self-attention quadratic in O, cross-attention
    linear in I per decoded token."""

    name = "enc_dec"

    def __init__(self, encoder_weight: float = 0.5):
        self.encoder_weight = encoder_weight

    def total(self, input_len, output_len):
        o = np.asarray(output_len, np.float64)
        i = float(input_len)
        return o * o / 2.0 + i * o + self.encoder_weight * i * i

    def attained(self, input_len: int, generated: int) -> float:
        # encoder cost is paid up-front at prefill
        i = float(input_len)
        g = float(generated)
        return g * g / 2.0 + i * g + self.encoder_weight * i * i

    def attained_batch(self, input_lens, generated):
        i = np.asarray(input_lens, np.float64)
        g = np.asarray(generated, np.float64)
        return g * g / 2.0 + i * g + self.encoder_weight * i * i


_REGISTRY = {
    "resource_bound": ResourceBoundCost,
    "output_length": OutputLengthCost,
    "overall_length": OverallLengthCost,
    "linear": LinearCost,
    "hybrid": HybridCost,
    "enc_dec": EncDecCost,
}


def make_cost_model(name: str, **kwargs) -> CostModel:
    if name not in _REGISTRY:
        raise KeyError(f"unknown cost model {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)
