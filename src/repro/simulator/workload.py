"""Synthetic workload generation calibrated to the paper's datasets.

The paper evaluates on three request datasets (Fig. 1(b), Sec. 4.1):

  * ShareGPT            — conversational: short/medium inputs, medium
                          outputs with heavy right tail.
  * Alpaca-Summarization — long inputs (documents), short outputs.
  * Document-Write      — short inputs (instructions), long outputs.

Two structural properties of real traces matter for reproducing the
paper's results and are built in:

  1. **Semantic clusters** (Fig. 4 premise): prompts form clusters; prompts
     within a cluster share vocabulary (high embedding cosine similarity)
     and share an *output-length distribution*.  The true output length of
     a request is a sample from its cluster's distribution — this is the
     ground truth the semantic-aware predictor can recover and the
     semantic-unaware ones cannot.
  2. **Per-request uncertainty** (Fig. 1(a)): even conditioned on the
     cluster, the output length is random (temperature-0.6 sampling).

Arrivals are Poisson at a configurable RPS (Sec. 4.1).  One generated
workload is a single cluster-global arrival stream: the single-node
simulator (``simulator.NodeSimulator``, paper Sec. 4.2–4.3 experiments)
consumes it directly, while the event-driven multi-node loop
(``cluster.simulate_cluster``, the Sec. 4.4 scalability topology)
routes each ``SimRequest`` to a serving node *at its arrival time* —
requests carry no node affinity here; placement is the router's job.
For cluster sweeps at fixed per-node load, scale ``rps`` with the node
count (8 RPS/node in the paper's Fig. 12 setup).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field

import numpy as np

__all__ = ["SemanticCluster", "DatasetProfile", "SimRequest",
           "make_profile", "DATASET_NAMES", "generate_workload",
           "generate_session_workload"]

DATASET_NAMES = ("sharegpt", "alpaca", "write")

# a compact word bank; clusters draw disjoint-ish vocab subsets from it
_WORDS = (
    "model train data neural layer token sample batch learn logic matrix "
    "vector tensor graph node edge path search sort merge hash tree heap "
    "stack queue list array string parse regex compile link load store fetch "
    "cache memory disk file socket packet route server client thread lock "
    "mutex atomic async await yield stream buffer pixel image audio video "
    "frame codec signal filter noise wave photon atom molecule protein gene "
    "cell tissue organ heart brain nerve blood bone muscle skin liver kidney "
    "story dragon castle knight wizard forest river mountain ocean island "
    "city village market bridge tower garden temple palace desert winter "
    "summer spring autumn morning evening night shadow light colour music "
    "poem novel essay letter report summary review article chapter verse "
    "contract clause statute court judge jury verdict appeal motion brief "
    "revenue profit margin equity asset bond stock option future hedge risk"
).split()


@dataclass
class SemanticCluster:
    """A family of semantically-similar prompts sharing an output-length
    distribution (lognormal, clipped)."""

    cluster_id: str
    template: str         # shared instruction prefix (template-like prompts)
    vocab: list[str]
    input_mu: float       # lognormal params for input length
    input_sigma: float
    output_mu: float      # lognormal params for output length
    output_sigma: float
    max_output: int = 4096
    max_input: int = 8192
    mutation: float = 0.15  # fraction of free words drawn off-cluster
    # Early-termination mode: with prob ``short_prob`` the model answers
    # briefly (clarification, refusal, early <EOS>) — the multimodality
    # visible in the paper's Fig. 1(a)/2(a) output-length histograms.
    short_prob: float = 0.0
    short_lo: int = 8
    short_hi: int = 96

    def sample_prompt(self, rng: np.random.Generator, n_free: int = 12) -> str:
        """Real request families share an instruction template ("Summarize
        the following report: ...") plus variable payload words."""
        n_mut = int(round(n_free * self.mutation))
        words = list(rng.choice(self.vocab, size=n_free - n_mut))
        words += list(rng.choice(_WORDS, size=n_mut))
        rng.shuffle(words)
        return self.template + " " + " ".join(words)

    def sample_input_len(self, rng: np.random.Generator) -> int:
        v = int(rng.lognormal(self.input_mu, self.input_sigma))
        return int(np.clip(v, 8, self.max_input))

    def sample_output_len(self, rng: np.random.Generator) -> int:
        if self.short_prob > 0.0 and rng.random() < self.short_prob:
            return int(rng.integers(self.short_lo, self.short_hi + 1))
        v = int(rng.lognormal(self.output_mu, self.output_sigma))
        return int(np.clip(v, 4, self.max_output))

    def true_length_samples(self, rng: np.random.Generator,
                            n: int = 512) -> np.ndarray:
        """Ground-truth output-length sample set (for oracle predictors and
        predictor-accuracy evaluation)."""
        return np.array([self.sample_output_len(rng) for _ in range(n)])


@dataclass
class DatasetProfile:
    name: str
    clusters: list[SemanticCluster] = field(default_factory=list)


def _lognormal_params(median: float, sigma: float) -> tuple[float, float]:
    return float(np.log(median)), sigma


def make_profile(name: str, n_clusters: int = 12,
                 seed: int | None = None) -> DatasetProfile:
    """Build a dataset profile with per-cluster I/O length statistics drawn
    around the dataset-level medians observed in the paper's Fig. 1(b)."""
    if name not in DATASET_NAMES:
        raise KeyError(f"unknown dataset {name!r}; have {DATASET_NAMES}")
    if seed is None:
        seed = zlib.crc32(name.encode()) % (2**31)  # process-stable
    rng = np.random.default_rng(seed)
    # dataset-level (input_median, output_median) anchors
    anchors = {
        "sharegpt": (220.0, 260.0, 0.9),   # conversational, heavy tail
        "alpaca":   (1800.0, 150.0, 0.6),  # summarization: long in, short out
        "write":    (140.0, 1100.0, 0.5),  # writing: short in, long out
    }
    in_med, out_med, out_sig = anchors[name]
    templates = {
        "sharegpt": "please chat with me and explain in detail about",
        "alpaca": "summarize the following document into key points covering",
        "write": "write a long detailed piece in the requested style about",
    }
    clusters = []
    for k in range(n_clusters):
        vocab = list(rng.choice(_WORDS, size=18, replace=False))
        topic = " ".join(rng.choice(vocab, size=4, replace=False))
        template = f"{templates[name]} {topic} [{name}-{k}]"
        # cluster-level medians jitter around dataset anchors (x0.4 .. x2.2)
        imed = in_med * float(rng.uniform(0.4, 2.2))
        omed = out_med * float(rng.uniform(0.4, 2.2))
        imu, isig = _lognormal_params(imed, 0.25)
        omu, osig = _lognormal_params(omed, out_sig * float(rng.uniform(0.6, 1.3)))
        clusters.append(SemanticCluster(
            cluster_id=f"{name}-{k}", template=template, vocab=vocab,
            input_mu=imu, input_sigma=isig,
            output_mu=omu, output_sigma=osig,
            short_prob=float(rng.uniform(0.05, 0.35))))
    return DatasetProfile(name=name, clusters=clusters)


@dataclass
class SimRequest:
    """One request as the simulator sees it.

    The three prefix fields describe the *sharing structure* of session
    workloads (all default to "no sharing", so every existing generator
    and test is unchanged): requests with the same ``prefix_group``
    belong to one prefix chain (a multi-turn session, or a tenant pool
    sharing a system prompt).  ``shared_prefix_len`` is how many leading
    tokens of THIS prompt are shared with *earlier* members of the group
    (adoptable from a prefix cache); ``sharable_prefix_len`` is how many
    of its leading tokens *later* members will share (what it publishes
    — a session turn publishes its whole prompt because the next turn
    extends it; a tenant request publishes only the system prompt, since
    siblings diverge right after it)."""

    request_id: str
    arrival: float            # seconds
    prompt: str
    input_len: int
    true_output_len: int      # hidden from the scheduler until completion
    dataset: str
    cluster: SemanticCluster
    prefix_group: str = ""
    shared_prefix_len: int = 0
    sharable_prefix_len: int = 0
    # multiplicative drift applied to this request's true output length
    # (generate_workload(drift_scale=...)); 1.0 = undrifted.  Recorded so
    # drift-aware baselines (e.g. the regret bench's oracle) can
    # reconstruct the drifted truth a predictor trained on the original
    # clusters cannot see.
    drift_factor: float = 1.0


def _drift_factor(i: int, n: int, scale: float, start: float,
                  ramp: float, mode: str) -> float:
    """Length-scale multiplier for request ``i`` of ``n`` under a drift
    schedule.  ``start``/``ramp`` are fractions of the trace: drift
    begins at ``start * n`` and (for ``ramp`` mode) reaches full
    ``scale`` after another ``ramp * n`` requests.  Modes:

      * ``ramp``      — linear 1 -> scale over the ramp window, then flat
                        (a dataset-mix shift settling in);
      * ``step``      — instant jump to ``scale`` at ``start`` (a
                        deployment flipping the traffic);
      * ``oscillate`` — alternates 1x / ``scale`` every ``ramp * n``
                        requests after ``start`` (the adversarial case:
                        any frozen correction is wrong half the time).
    """
    pos = i - start * n
    if pos < 0:
        return 1.0
    if mode == "step":
        return scale
    span = max(1.0, ramp * n)
    if mode == "oscillate":
        return scale if int(pos // span) % 2 == 0 else 1.0
    return 1.0 + (scale - 1.0) * min(1.0, pos / span)  # ramp


def generate_workload(profiles: list[DatasetProfile], n_requests: int,
                      rps: float, seed: int = 0, *,
                      burst_factor: float = 1.0,
                      burst_period_s: float = 10.0,
                      burst_duty: float = 0.2,
                      drift_scale: float = 1.0,
                      drift_start: float = 0.5,
                      drift_ramp: float = 0.25,
                      drift_mode: str = "ramp") -> list[SimRequest]:
    """Poisson arrivals at ``rps``; each request uniformly picks a dataset
    profile then a cluster (mixed-dataset experiment when len(profiles)>1).

    ``burst_factor > 1`` modulates the Poisson rate: for the first
    ``burst_duty`` fraction of every ``burst_period_s`` window the rate
    is ``burst_factor * rps`` — the flash-crowd overload pattern the
    gateway's admission control is tested against.  ``burst_factor=1``
    (default) draws the exact same RNG sequence as the unmodulated
    generator, so every seeded workload in existing experiments is
    unchanged.

    ``drift_scale != 1`` injects *prediction drift*: true output lengths
    are multiplied by a per-request factor following ``drift_mode``
    (see ``_drift_factor``) while prompts/clusters are untouched — so
    any predictor trained or seeded on the original clusters is
    honestly, progressively wrong.  Applied AFTER sampling (same
    RNG-compatibility pattern as ``burst_factor``): with the default
    scale of 1.0 the trace is bit-identical to the undrifted one, and a
    drifted trace differs only in ``true_output_len``/``drift_factor``.
    """
    if drift_mode not in ("ramp", "step", "oscillate"):
        raise ValueError(f"unknown drift_mode {drift_mode!r}")
    rng = np.random.default_rng(seed)
    t = 0.0
    out: list[SimRequest] = []
    for i in range(n_requests):
        rate = rps
        if burst_factor != 1.0 and (t % burst_period_s
                                    ) < burst_duty * burst_period_s:
            rate = rps * burst_factor
        t += float(rng.exponential(1.0 / rate))
        prof = profiles[int(rng.integers(len(profiles)))]
        cluster = prof.clusters[int(rng.integers(len(prof.clusters)))]
        # draw order (prompt, input, output) is part of the seed contract
        prompt = cluster.sample_prompt(rng)
        input_len = cluster.sample_input_len(rng)
        tol = cluster.sample_output_len(rng)
        df = 1.0
        if drift_scale != 1.0:
            df = _drift_factor(i, n_requests, drift_scale, drift_start,
                               drift_ramp, drift_mode)
            if df != 1.0:
                tol = max(1, int(round(tol * df)))
        out.append(SimRequest(
            request_id=f"req-{i:06d}",
            arrival=t,
            prompt=prompt,
            input_len=input_len,
            true_output_len=tol,
            dataset=prof.name,
            cluster=cluster,
            drift_factor=df))
    return out


def generate_session_workload(profiles: list[DatasetProfile],
                              n_sessions: int, rps: float, seed: int = 0, *,
                              turns: tuple[int, int] = (2, 4),
                              think_time_s: float = 4.0,
                              tenant_prob: float = 0.4,
                              n_tenants: int = 4,
                              system_prompt_tokens: int = 64,
                              turn_user_tokens: int = 24
                              ) -> list[SimRequest]:
    """Session arrivals — the compound workload class prefix sharing
    unlocks (LLMSched's stage-structured requests).  Sessions arrive
    Poisson at ``rps`` and take one of two sharing shapes:

      * **multi-turn chat** (prob ``1 - tenant_prob``): 2..N turns where
        turn j's prompt is the whole accumulated conversation (previous
        prompt + previous answer + a fresh user message), so each turn
        shares its predecessor's full context (``shared_prefix_len``)
        and publishes its own full prompt for the next turn
        (``sharable_prefix_len == input_len``).  Turns are spaced by
        exponential think time.
      * **shared-system-prompt tenant** (prob ``tenant_prob``): a
        one-shot request whose first ``system_prompt_tokens`` tokens are
        the tenant's fixed system prompt — shared with every other
        request of that tenant, diverging immediately after (so only the
        system prompt is published as sharable).

    Deterministic per seed; returned sorted by arrival time.  Output
    lengths still come from the semantic clusters, so predictors behave
    exactly as on the one-shot workloads."""
    lo, hi = int(turns[0]), int(turns[1])
    if lo < 1 or hi < lo:
        raise ValueError(f"bad turns range {turns!r}")
    rng = np.random.default_rng(seed)
    t = 0.0
    out: list[SimRequest] = []
    for i in range(n_sessions):
        t += float(rng.exponential(1.0 / rps))
        prof = profiles[int(rng.integers(len(profiles)))]
        cluster = prof.clusters[int(rng.integers(len(prof.clusters)))]
        if rng.random() < tenant_prob:
            tenant = int(rng.integers(n_tenants))
            user_len = cluster.sample_input_len(rng)
            out.append(SimRequest(
                request_id=f"sess-{i:05d}-t0",
                arrival=t,
                prompt=(f"[tenant-{tenant} system] "
                        + cluster.sample_prompt(rng)),
                input_len=system_prompt_tokens + user_len,
                true_output_len=cluster.sample_output_len(rng),
                dataset=prof.name,
                cluster=cluster,
                prefix_group=f"tenant-{tenant}",
                shared_prefix_len=system_prompt_tokens,
                sharable_prefix_len=system_prompt_tokens))
            continue
        n_turns = int(rng.integers(lo, hi + 1))
        base_prompt = cluster.sample_prompt(rng)
        arrival = t
        ctx = 0
        for j in range(n_turns):
            user_len = int(rng.integers(8, 2 * turn_user_tokens + 1))
            input_len = ctx + user_len
            out_len = cluster.sample_output_len(rng)
            out.append(SimRequest(
                request_id=f"sess-{i:05d}-t{j}",
                arrival=arrival,
                prompt=f"{base_prompt} [turn {j}]",
                input_len=input_len,
                true_output_len=out_len,
                dataset=prof.name,
                cluster=cluster,
                prefix_group=f"sess-{i:05d}",
                shared_prefix_len=ctx,
                sharable_prefix_len=input_len))
            ctx = input_len + out_len
            arrival += float(rng.exponential(think_time_s))
    out.sort(key=lambda r: (r.arrival, r.request_id))
    return out
