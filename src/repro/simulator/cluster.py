"""Multi-node cluster simulation + scheduling-overhead measurement.

Reproduces the paper's Sec. 4.4 scalability study (Fig. 12): a central
SageSched scheduler in front of up to 64 nodes, load scaled proportionally
(8 RPS per node), queue depth up to 1000.  We measure the *real* wall-clock
cost of the predicting and scheduling stages (embedding + flat search +
Gittins + ordered insertion) under the aggregate load, because that — not
the simulated serving time — is the scheduler overhead the paper reports.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from ..core.cost_model import CostModel, ResourceBoundCost
from ..core.gittins import gittins_index
from ..core.predictor import SemanticHistoryPredictor
from .service_model import NodeSpec
from .simulator import NodeSimulator, SimResult
from .workload import SimRequest

__all__ = ["ClusterResult", "simulate_cluster", "measure_scheduler_overhead"]


@dataclass
class ClusterResult:
    node_results: list[SimResult]
    mean_ttlt: float
    mean_ttft: float

    @property
    def n_nodes(self) -> int:
        return len(self.node_results)


def simulate_cluster(requests: list[SimRequest], scheduler_factory,
                     n_nodes: int, spec: NodeSpec | None = None
                     ) -> ClusterResult:
    """Dispatch requests to nodes (join-shortest-outstanding-work, the
    Llumnix-style router) and simulate each node independently."""
    buckets: list[list[SimRequest]] = [[] for _ in range(n_nodes)]
    outstanding = np.zeros(n_nodes)
    # decay outstanding work between arrivals at a nominal service rate so
    # early requests don't permanently bias routing
    last_t = 0.0
    drain_rate = 2000.0  # cost-units/s, nominal
    for r in sorted(requests, key=lambda x: x.arrival):
        outstanding = np.maximum(0.0, outstanding
                                 - (r.arrival - last_t) * drain_rate)
        last_t = r.arrival
        n = int(np.argmin(outstanding))
        buckets[n].append(r)
        outstanding[n] += r.input_len + 2.0 * 256  # admission-time estimate
    results = []
    for n in range(n_nodes):
        sim = NodeSimulator(scheduler_factory(), spec)
        results.append(sim.run(buckets[n]))
    all_m = [m for res in results for m in res.metrics]
    return ClusterResult(
        node_results=results,
        mean_ttlt=float(np.mean([m.ttlt for m in all_m])),
        mean_ttft=float(np.mean([m.ttft for m in all_m])))


def measure_scheduler_overhead(n_nodes: int, rps_per_node: float = 8.0,
                               queue_depth: int = 1000,
                               history_size: int = 10_000,
                               n_probe: int = 200,
                               seed: int = 0) -> dict:
    """Wall-clock per-request predict + schedule cost at cluster scale.

    Mirrors the paper's measurement: a single scheduler handles
    ``n_nodes * rps_per_node`` RPS with up to ``queue_depth`` buffered
    requests and a full 10k history window; fixed output length 1000.
    Returns per-request latencies in milliseconds.
    """
    rng = np.random.default_rng(seed)
    predictor = SemanticHistoryPredictor()
    cost_model: CostModel = ResourceBoundCost()
    # populate the history window
    words = ("alpha beta gamma delta epsilon zeta eta theta iota kappa "
             "lambda mu nu xi omicron pi rho sigma tau upsilon").split()
    prompts = [" ".join(rng.choice(words, size=16)) for _ in range(256)]
    for _ in range(history_size // 256):
        for p in prompts:
            predictor.observe(p, 128, int(rng.integers(50, 2000)))

    # a standing queue of queue_depth scaled by cluster load factor
    load = min(1.0, n_nodes * rps_per_node / (64 * 8.0))
    depth = max(8, int(queue_depth * load))
    queue: list[tuple[float, str]] = [(float(rng.uniform(0, 1e6)), f"q{i}")
                                      for i in range(depth)]
    queue.sort()

    t_pred, t_sched = [], []
    aggregate_rps = n_nodes * rps_per_node
    for i in range(n_probe):
        prompt = " ".join(rng.choice(words, size=16))
        t0 = time.perf_counter()
        dist = predictor.predict(prompt, 128)
        cd = cost_model.distribution(128, dist.lengths, dist.probs)
        g = gittins_index(cd)
        t1 = time.perf_counter()
        # ordered insertion + head dispatch against the standing queue,
        # plus the per-arrival share of periodic refreshes: the central
        # scheduler refreshes ~depth/10 indices per arrival interval
        import bisect as _b
        _b.insort(queue, (g, f"p{i}"))
        n_refresh = max(1, depth // 10)
        for j in range(n_refresh):
            gittins_index(cd, attained=float(j + 1))
        queue.pop(0)
        t2 = time.perf_counter()
        t_pred.append((t1 - t0) * 1e3)
        t_sched.append((t2 - t1) * 1e3)
    return {
        "n_nodes": n_nodes,
        "aggregate_rps": aggregate_rps,
        "queue_depth": depth,
        "predict_ms": float(np.mean(t_pred)),
        "schedule_ms": float(np.mean(t_sched)),
        "total_ms": float(np.mean(t_pred) + np.mean(t_sched)),
    }
