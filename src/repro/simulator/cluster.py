"""Cluster-scale scheduling: one shared-BatchState scheduler, many nodes.

Reproduces the paper's Sec. 4.4 scalability study (Fig. 12): a single
central SageSched scheduler in front of up to 64 nodes, load scaled
proportionally (8 RPS per node), queue depth up to 1000.  Three layers:

  * **ClusterScheduler** — the central scheduler: ONE ``repro.core.
    Scheduler`` whose BatchState holds every live request across all
    nodes (a ``node_id`` column joins the SoA vectors).  ``refresh()``
    recomputes all dirty priorities cluster-wide in one batched backend
    pass; per-node ranking is ``order(node_id=n)`` — a masked lexsort
    over the shared arrays.  Each node drives the scheduler through a
    ``NodeSchedulerView``, which binds the node's identity into the
    surface ``NodeSimulator`` expects.

  * **Routers** — pluggable placement policies.  ``JoinShortestWork
    Router`` is the Llumnix-style baseline: a decayed outstanding-token
    counter fed by the fixed admission-time guess ``input_len + 2*256``.
    ``CostAwareRouter`` replaces the guess with the request's predicted
    ``CostDistribution`` mean (the same predictor + cost model the
    scheduler uses) and respects each node's KV-memory headroom through
    a per-node ``repro.serving.kv_cache.KVCacheManager``.

  * **Event-driven loop** — ``simulate_cluster`` interleaves arrival /
    step-complete / finish events across nodes: requests are routed at
    their global arrival times against *live* cluster state, and a node
    never fast-forwards a decode run past an unrouted arrival (the
    ``horizon`` handed to ``NodeSimulator.step``).  ``shared_state=
    False`` runs the identical loop with one private Scheduler per node
    — the fanout baseline the parity tests compare against
    (tests/test_cluster.py asserts metric *equality* under identical
    JSOW routing).

``measure_scheduler_overhead`` times the paper's Fig. 12 quantities —
per-request predict and schedule wall-clock at cluster load — against
this real batched path (admit into shared state, cluster-wide refresh,
node-masked order), not a hand-rolled sorted-list stand-in.  See
docs/cluster_scheduling.md.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..core.cost_model import CostModel, ResourceBoundCost
from ..core.predictor import Predictor, SemanticHistoryPredictor
from ..core.scheduler import Scheduler
from ..serving.kv_cache import KVCacheManager
from .service_model import NodeSpec
from .simulator import NodeSimulator, SimResult
from .workload import SimRequest

__all__ = [
    "ClusterResult", "ClusterScheduler", "NodeSchedulerView",
    "Router", "JoinShortestWorkRouter", "CostAwareRouter", "make_router",
    "ROUTER_NAMES", "NodeKill", "NodeSlow", "simulate_cluster",
    "measure_scheduler_overhead",
]


# ----------------------------------------------------------- fault events

@dataclass(frozen=True)
class NodeKill:
    """Kill node ``node_id`` at simulated time ``at``: its in-flight
    requests are re-routed to surviving nodes (host-resident swap
    payloads move with them; device-resident KV is re-prefilled) or
    aborted when no node remains."""

    node_id: int
    at: float


@dataclass(frozen=True)
class NodeSlow:
    """Slow node ``node_id`` down by ``factor`` from time ``at`` on —
    thermal throttling / degraded interconnect.  Compounding: two
    NodeSlow events multiply."""

    node_id: int
    at: float
    factor: float = 4.0


# ---------------------------------------------------------------- routers

class Router:
    """Placement policy: assigns each arriving request to a node.

    ``route`` is called once per request, at its global arrival time, in
    arrival order (ties processed in input order — see the event loop).
    ``on_complete`` lets stateful routers release per-request
    accounting when the serving node finishes the request.
    """

    name = "base"
    dead: frozenset = frozenset()   # nodes removed from placement

    def mark_dead(self, node_id: int) -> None:
        """Remove a node from future placement decisions (node-kill
        fault).  Instance-level copy-on-write so the class default
        stays shared and empty."""
        self.dead = set(self.dead) | {int(node_id)}

    def route(self, req: SimRequest) -> int:
        raise NotImplementedError

    def route_batch(self, reqs: list[SimRequest]) -> list[int]:
        """Route a burst of same-tick arrivals.  Placement is inherently
        sequential (each decision shifts the load the next one sees), so
        the default loops ``route`` in input order; routers with an
        expensive per-request stage (the cost router's prediction)
        override this to batch that stage and keep only the cheap
        placement loop sequential."""
        return [self.route(r) for r in reqs]

    def on_complete(self, request_id: str, node_id: int) -> None:
        pass


class JoinShortestWorkRouter(Router):
    """Join-shortest-outstanding-work on an admission-time token guess.

    The Llumnix-style baseline the paper's evaluation assumes: each
    request adds ``input_len + 2 * output_guess`` outstanding tokens to
    its node; outstanding work decays between arrivals at a nominal
    drain rate so early requests don't permanently bias routing.  Blind
    to demand uncertainty — the fixed guess is exactly what
    ``CostAwareRouter`` replaces.
    """

    name = "jsow"

    def __init__(self, n_nodes: int, drain_rate: float = 2000.0,
                 output_guess: float = 256.0):
        self.n_nodes = n_nodes
        self.drain_rate = drain_rate    # cost-units/s, nominal
        self.output_guess = output_guess
        self.outstanding = np.zeros(n_nodes)
        self._last_t = 0.0

    def route(self, req: SimRequest) -> int:
        # no expensive per-request stage to amortize here, so the base
        # class's sequential route_batch IS this router's burst form
        self.outstanding = np.maximum(
            0.0, self.outstanding
            - (req.arrival - self._last_t) * self.drain_rate)
        self._last_t = req.arrival
        if self.dead:
            masked = self.outstanding.copy()
            masked[list(self.dead)] = np.inf
            n = int(np.argmin(masked))
        else:
            n = int(np.argmin(self.outstanding))
        self.outstanding[n] += req.input_len + 2.0 * self.output_guess
        return n


class CostAwareRouter(Router):
    """Route on predicted service cost + live KV-memory headroom.

    Two uncertainty-aware upgrades over ``JoinShortestWorkRouter``
    (cf. LLMSched's uncertainty-aware DAG placement, arXiv:2504.03444,
    and the robust-routing argument of arXiv:2508.14544 — routing
    quality hinges on cost estimates that track prediction uncertainty):

      * outstanding work per node is the sum of *predicted cost means*
        (``CostModel.distribution`` pushforward of the length
        prediction) of the requests still assigned there — released on
        completion, so the counter tracks live queue state instead of a
        decayed admission-time guess;
      * each node's KV budget is mirrored in a ``KVCacheManager``
        (repro.serving.kv_cache) charged with ``input_len + E[output]``
        tokens per request; nodes whose headroom cannot take the
        arriving request are avoided unless every node is saturated
        (then: least outstanding predicted work, ties to the largest
        headroom — outstanding keeps tracking queued requests even when
        the slot mirror is exhausted, so overload spreads instead of
        funneling to whichever node's mirror froze first).

    The router predicts once per request — for a burst, once per request
    in ONE ``predict_batch`` call (``route_batch``) — and the prediction
    is handed to ``Scheduler.admit`` through the node view
    (``take_prediction``), so the expensive semantic-history lookup is
    not paid twice.

    ``route_quantile=q`` routes on the q-quantile of the predicted cost
    distribution instead of its mean (robust placement under prediction
    uncertainty, arXiv:2508.14544): the support/probs are already
    computed at route time, so the knob costs one searchsorted.
    """

    name = "cost"

    def __init__(self, n_nodes: int, predictor: Predictor,
                 cost_model: CostModel | None = None,
                 spec: NodeSpec | None = None,
                 route_quantile: float | None = None):
        self.n_nodes = n_nodes
        self.predictor = predictor
        self.cost_model = cost_model or ResourceBoundCost()
        self.route_quantile = route_quantile
        if route_quantile is not None:
            if not 0.0 < route_quantile <= 1.0:
                raise ValueError(f"route_quantile must be in (0, 1], "
                                 f"got {route_quantile}")
            self.name = f"cost@q{route_quantile:g}"
        spec = spec or NodeSpec()
        cap = spec.kv_capacity_tokens
        self.kv = [KVCacheManager(n_slots=spec.max_batch, max_seq_len=cap,
                                  capacity_tokens=cap)
                   for _ in range(n_nodes)]
        self.outstanding = np.zeros(n_nodes)   # predicted cost units
        self._cost_of: dict[str, float] = {}
        self._dist_of: dict[str, object] = {}  # rid -> LengthDistribution

    def headroom(self, node_id: int) -> int:
        kv = self.kv[node_id]
        return kv.capacity_tokens - kv.used_tokens

    def take_prediction(self, request_id: str):
        """Hand the route-time length prediction to the admitting node
        (None for requests this router never saw)."""
        return self._dist_of.pop(request_id, None)

    def route(self, req: SimRequest) -> int:
        return self.route_batch([req])[0]

    def route_batch(self, reqs: list[SimRequest]) -> list[int]:
        """Batch the expensive stage — ONE ``predict_batch`` + cost
        pushforward sweep over the burst — then place sequentially (each
        placement charges the outstanding/KV state the next one sees)."""
        if not reqs:
            return []
        dists = self.predictor.predict_many(
            [r.prompt for r in reqs], [r.input_len for r in reqs])
        cost_dists = self.cost_model.distribution_batch(
            [r.input_len for r in reqs], dists)
        return [self._place(r, dist, cd)
                for r, dist, cd in zip(reqs, dists, cost_dists)]

    def _place(self, req: SimRequest, dist, cost_dist) -> int:
        cost = cost_dist.mean if self.route_quantile is None \
            else cost_dist.quantile(self.route_quantile)
        need_kv = int(req.input_len + dist.mean)
        fits = np.array([self.kv[n].can_admit(need_kv)
                         for n in range(self.n_nodes)])
        out = self.outstanding
        if self.dead:
            fits[list(self.dead)] = False
            out = out.copy()
            out[list(self.dead)] = np.inf
        if fits.any():
            # among nodes with headroom: least outstanding predicted work
            masked = np.where(fits, out, np.inf)
            n = int(np.argmin(masked))
        else:
            # cluster saturated: least outstanding predicted work (the
            # KV mirror freezes once its slot pool is exhausted, so
            # headroom alone would funnel all overload to one node);
            # ties go to the node with the most KV headroom; dead nodes
            # carry inf outstanding so they only win if every node died
            heads = np.array([self.headroom(i)
                              for i in range(self.n_nodes)], np.float64)
            n = int(np.lexsort((-heads, out))[0])
        kv = self.kv[n]
        if kv.free_slots > 0 and kv.blocks_for(need_kv) <= kv.free_blocks:
            # mirror the token charge; under deep backlog (> max_batch
            # queued requests) the slot pool — or, post block-table
            # refactor, the physical block pool — is exhausted: the node
            # is saturated anyway, so skip the mirror rather than crash
            # (on_complete's holds() check keeps release() symmetric)
            kv.allocate(req.request_id, need_kv)
        self.outstanding[n] += cost
        self._cost_of[req.request_id] = cost
        self._dist_of[req.request_id] = dist
        return n

    def on_complete(self, request_id: str, node_id: int) -> None:
        if self.kv[node_id].holds(request_id):
            self.kv[node_id].release(request_id)
        self.outstanding[node_id] -= self._cost_of.pop(request_id, 0.0)
        self._dist_of.pop(request_id, None)


ROUTER_NAMES = ("jsow", "cost")


def make_router(name, n_nodes: int, *, predictor: Predictor | None = None,
                cost_model: CostModel | None = None,
                spec: NodeSpec | None = None,
                route_quantile: float | None = None) -> Router:
    """Resolve a router spec; instances pass through.  ``route_quantile``
    selects quantile-of-cost routing for the cost router (robust to
    heavy-tailed predictions; only meaningful with ``name="cost"``)."""
    if isinstance(name, Router):
        if route_quantile is not None:
            raise ValueError("route_quantile cannot be applied to an "
                             "already-constructed Router instance; pass "
                             "CostAwareRouter(..., route_quantile=...) "
                             "directly")
        return name
    if name == "jsow":
        if route_quantile is not None:
            raise ValueError("route_quantile only applies to the cost "
                             "router")
        return JoinShortestWorkRouter(n_nodes)
    if name == "cost":
        if predictor is None:
            raise ValueError("cost router needs the central predictor")
        return CostAwareRouter(n_nodes, predictor, cost_model, spec,
                               route_quantile=route_quantile)
    raise KeyError(f"unknown router {name!r}; have {ROUTER_NAMES}")


# ------------------------------------------------------- central scheduler

class NodeSchedulerView:
    """One node's facade over a (possibly shared) Scheduler.

    Exposes exactly the surface ``NodeSimulator`` drives.  With
    ``masked=True`` the underlying scheduler is cluster-shared:
    ``admit`` stamps the node id and parameterless ``order`` calls
    become node-masked lexsorts, so the node only ever ranks its own
    queue while refreshes stay cluster-wide.  With ``masked=False`` the
    scheduler is private to the node (the fanout baseline) and calls
    pass straight through.  Either way ``on_complete`` notifies the
    router so placement accounting tracks live state.
    """

    def __init__(self, scheduler: Scheduler, node_id: int, *,
                 masked: bool, router: Router | None = None):
        self.scheduler = scheduler
        self.node_id = node_id
        self.masked = masked
        self.router = router

    # lifecycle -----------------------------------------------------------

    def admit(self, request_id: str, prompt: str, input_len: int,
              arrival: float | None = None):
        # reuse the router's route-time prediction when it made one
        # (cost router) instead of re-running the semantic lookup
        ld = self.router.take_prediction(request_id) \
            if hasattr(self.router, "take_prediction") else None
        return self.scheduler.admit(
            request_id, prompt, input_len, arrival=arrival,
            node_id=self.node_id if self.masked else -1, length_dist=ld)

    def admit_batch(self, request_ids, prompts, input_lens, *,
                    arrivals=None):
        """Batched admission for a burst landing on this node: node-id
        stamping + per-request reuse of route-time predictions, then one
        ``Scheduler.admit_batch`` pass over the shared state."""
        lds = None
        if hasattr(self.router, "take_prediction"):
            lds = [self.router.take_prediction(r) for r in request_ids]
        return self.scheduler.admit_batch(
            request_ids, prompts, input_lens, arrivals=arrivals,
            node_ids=self.node_id if self.masked else -1,
            length_dists=lds)

    def on_complete(self, request_id: str, output_len: int) -> None:
        self.scheduler.on_complete(request_id, output_len)
        if self.router is not None:
            self.router.on_complete(request_id, self.node_id)

    def on_abort(self, request_id: str) -> None:
        self.scheduler.on_abort(request_id)
        if self.router is not None:
            self.router.on_complete(request_id, self.node_id)

    # passthrough ---------------------------------------------------------

    def order(self, request_ids=None, **kwargs):
        if request_ids is None and self.masked:
            return self.scheduler.order(node_id=self.node_id, **kwargs)
        return self.scheduler.order(request_ids, **kwargs)

    def on_progress(self, request_id: str, generated: int) -> None:
        self.scheduler.on_progress(request_id, generated)

    def on_progress_many(self, request_ids, generated) -> None:
        self.scheduler.on_progress_many(request_ids, generated)

    def min_tokens_to_refresh(self, request_ids) -> float:
        return self.scheduler.min_tokens_to_refresh(request_ids)

    def tokens_to_refresh(self, request_id: str) -> float:
        return self.scheduler.tokens_to_refresh(request_id)

    def set_now(self, now: float) -> None:
        self.scheduler.set_now(now)

    def get(self, request_id: str):
        return self.scheduler.get(request_id)

    def __contains__(self, request_id: str) -> bool:
        return request_id in self.scheduler

    @property
    def policy(self):
        return self.scheduler.policy

    @property
    def runtime_refreshing(self) -> bool:
        return self.scheduler.runtime_refreshing

    @property
    def preemptive(self) -> bool:
        return self.scheduler.preemptive

    @property
    def stats(self) -> dict:
        return self.scheduler.stats


class ClusterScheduler:
    """The paper's central-scheduler topology as a first-class object.

    One shared ``Scheduler`` (one BatchState spanning every node's live
    requests) + a placement ``Router``.  ``view(n)`` hands node *n* its
    ``NodeSchedulerView``; ``route(req)`` makes the placement decision;
    ``refresh()`` is the cluster-wide batched priority recomputation;
    ``order(node_id=n)`` ranks one node's queue by masked lexsort.
    """

    def __init__(self, scheduler: Scheduler | None = None,
                 n_nodes: int = 1, router="jsow",
                 spec: NodeSpec | None = None,
                 route_quantile: float | None = None):
        # explicit None-check: Scheduler defines __len__, so an *empty*
        # scheduler is falsy and `scheduler or Scheduler()` would silently
        # swap a caller's configured scheduler for a default one
        self.scheduler = Scheduler() if scheduler is None else scheduler
        self.n_nodes = n_nodes
        self.router = make_router(router, n_nodes,
                                  predictor=self.scheduler.predictor,
                                  cost_model=self.scheduler.cost_model,
                                  spec=spec, route_quantile=route_quantile)

    def view(self, node_id: int) -> NodeSchedulerView:
        return NodeSchedulerView(self.scheduler, node_id, masked=True,
                                 router=self.router)

    def route(self, req: SimRequest) -> int:
        return self.router.route(req)

    def route_batch(self, reqs: list[SimRequest]) -> list[int]:
        """Place a burst of same-tick arrivals: the router's expensive
        stage (prediction) runs once, batched, for the whole burst."""
        return self.router.route_batch(reqs)

    def refresh(self) -> int:
        return self.scheduler.refresh()

    def order(self, node_id: int | None = None, **kwargs) -> list[str]:
        return self.scheduler.order(node_id=node_id, **kwargs)

    def outstanding_by_node(self) -> np.ndarray:
        return self.scheduler.outstanding_by_node(self.n_nodes)

    def __len__(self) -> int:
        return len(self.scheduler)


# ------------------------------------------------------------- event loop

@dataclass
class ClusterResult:
    node_results: list[SimResult]
    mean_ttlt: float
    mean_ttft: float
    router: str = "jsow"
    requests_per_node: list[int] = field(default_factory=list)
    aborted: list[str] = field(default_factory=list)  # no node left to adopt
    migrated: int = 0           # in-flight requests re-routed off dead nodes

    @property
    def n_nodes(self) -> int:
        return len(self.node_results)

    @property
    def metrics(self):
        return [m for res in self.node_results for m in res.metrics]


def simulate_cluster(requests: list[SimRequest], scheduler_factory,
                     n_nodes: int, spec: NodeSpec | None = None, *,
                     router="jsow", shared_state: bool = True,
                     route_quantile: float | None = None,
                     faults=None,
                     node_kwargs: dict | None = None) -> ClusterResult:
    """Event-driven multi-node simulation under a central scheduler.

    Arrival, step-complete, and finish events interleave across nodes:
    the loop always advances whichever entity is earliest in simulated
    time — routing the next request once every busy node has caught up
    to its arrival, otherwise stepping the furthest-behind node one
    scheduling round (capped at the next global arrival, so routing
    decisions always see live queue state).  *Same-tick* arrivals (equal
    timestamps) are coalesced into one burst: routed together through
    ``Router.route_batch`` (one batched prediction for the cost router)
    and admitted per node through ``admit_batch`` — still in input
    order, so placement is deterministic.  Node ties break by node
    index (regression-tested).

    shared_state=True (default): ``scheduler_factory()`` builds ONE
    scheduler whose BatchState holds the whole cluster's requests
    (central SageSched, paper Sec. 4.4).  shared_state=False: one
    private scheduler per node — the fanout baseline; under identical
    routing both modes produce identical request metrics
    (tests/test_cluster.py parity tests).

    route_quantile: see ``CostAwareRouter`` (cost router only).

    faults: optional list of ``NodeKill`` / ``NodeSlow`` events,
    interleaved with arrivals in simulated-time order.  A kill drains
    the node (``NodeSimulator.kill``): swapped-out requests keep their
    host-resident payload and pay swap-in on the adoptive node;
    device-resident ones re-prefill, keeping already-streamed tokens.
    Orphans are re-routed through the (dead-node-masked) router, or
    recorded in ``ClusterResult.aborted`` when no node survives.

    node_kwargs: extra keyword arguments for every ``NodeSimulator``
    (e.g. ``prefill_chunk``, ``block_size``, ``prefix_sharing`` — the
    session-workload sharing experiments run through here).
    """
    reqs = sorted(requests, key=lambda r: r.arrival)
    if shared_state:
        cs = ClusterScheduler(scheduler_factory(), n_nodes, router=router,
                              spec=spec, route_quantile=route_quantile)
        router_obj = cs.router
        views = [cs.view(n) for n in range(n_nodes)]
    else:
        scheds = [scheduler_factory() for _ in range(n_nodes)]
        router_obj = make_router(router, n_nodes,
                                 predictor=scheds[0].predictor,
                                 cost_model=scheds[0].cost_model, spec=spec,
                                 route_quantile=route_quantile)
        views = [NodeSchedulerView(scheds[n], n, masked=False,
                                   router=router_obj)
                 for n in range(n_nodes)]
    sims = [NodeSimulator(views[n], spec, node_id=n,
                          **(node_kwargs or {}))
            for n in range(n_nodes)]
    per_node = [0] * n_nodes
    fault_q = sorted(faults or [], key=lambda f: (f.at, f.node_id))
    fi, aborted, migrated = 0, [], 0

    i, n_req = 0, len(reqs)
    while True:
        busy = [s for s in sims if s.busy]
        t_next = reqs[i].arrival if i < n_req else float("inf")
        t_fault = fault_q[fi].at if fi < len(fault_q) else float("inf")
        now_min = min((s.now for s in busy), default=float("inf"))
        if fi < len(fault_q) and t_fault <= min(t_next, now_min) + 1e-12:
            # fault fires before the next arrival and before any busy
            # node's frontier — kills beat same-tick arrivals so the
            # burst routes around the dead node
            f = fault_q[fi]
            fi += 1
            if isinstance(f, NodeSlow):
                sims[f.node_id].slow_down(f.factor)
            else:
                orphans = sims[f.node_id].kill(f.at)
                router_obj.mark_dead(f.node_id)
                if not any(s.alive for s in sims):
                    aborted.extend(lv.req.request_id for lv in orphans)
                elif orphans:
                    homes = router_obj.route_batch(
                        [lv.req for lv in orphans])
                    for lv, nid in zip(orphans, homes):
                        sims[nid].adopt(lv, f.at)
                        per_node[nid] += 1
                        migrated += 1
            continue
        if i < n_req and (not busy or t_next <= now_min + 1e-12):
            if not any(s.alive for s in sims):
                # whole cluster is down: remaining arrivals can never be
                # served — record them instead of routing into a wall
                aborted.extend(r.request_id for r in reqs[i:])
                i = n_req
                continue
            j = i + 1  # coalesce the same-tick burst (identical stamps)
            while j < n_req and reqs[j].arrival <= t_next + 1e-12:
                j += 1
            burst = reqs[i:j]
            i = j
            for r, nid in zip(burst, router_obj.route_batch(burst)):
                sims[nid].push(r)
                per_node[nid] += 1
            continue
        if not busy:
            break
        s = min(busy, key=lambda s: (s.now, s.node_id))
        s.step(horizon=min(t_next, t_fault))

    results = [s.finish() for s in sims]
    all_m = [m for res in results for m in res.metrics]
    return ClusterResult(
        node_results=results,
        mean_ttlt=float(np.mean([m.ttlt for m in all_m])) if all_m
        else float("nan"),
        mean_ttft=float(np.mean([m.ttft for m in all_m])) if all_m
        else float("nan"),
        router=getattr(router_obj, "name", str(router)),
        requests_per_node=per_node,
        aborted=aborted,
        migrated=migrated)


# ------------------------------------------------- Fig. 12 overhead probe

def measure_scheduler_overhead(n_nodes: int, rps_per_node: float = 8.0,
                               queue_depth: int = 1000,
                               history_size: int = 10_000,
                               n_probe: int = 200,
                               seed: int = 0,
                               backend: str = "numpy",
                               policy: str = "sagesched",
                               bucket_size: int = 200) -> dict:
    """Wall-clock per-request predict + schedule cost at cluster scale.

    Mirrors the paper's Fig. 12 measurement — a single central scheduler
    handling ``n_nodes * rps_per_node`` RPS with a standing cluster-wide
    queue (depth scaled by load factor, up to ``queue_depth``) and a full
    10k history window — but drives the *real* batched decision path:

      predict stage   ``Scheduler.admit`` — semantic-history predict,
                      cost pushforward, initial priority, row append
                      into the cluster-shared BatchState;
      schedule stage  the per-arrival share of periodic refreshes
                      (~depth/10 rows cross their cost-bucket boundary
                      per arrival interval) recomputed in ONE cluster-
                      wide ``refresh()`` pass through ``backend``, plus
                      the arriving node's dispatch ranking
                      (``order(node_id=...)`` masked lexsort).

    Returns per-request stage latencies in milliseconds.  ``backend``
    picks the priority backend ("numpy" vectorized float64, "pallas"
    TPU kernel — interpret-mode off-TPU, correctness only).
    """
    from ..core.policies import make_policy

    rng = np.random.default_rng(seed)
    predictor = SemanticHistoryPredictor()
    # populate the history window
    words = ("alpha beta gamma delta epsilon zeta eta theta iota kappa "
             "lambda mu nu xi omicron pi rho sigma tau upsilon").split()
    prompts = [" ".join(rng.choice(words, size=16)) for _ in range(256)]
    for _ in range(history_size // 256):
        for p in prompts:
            predictor.observe(p, 128, int(rng.integers(50, 2000)))

    sched = Scheduler(predictor=predictor, cost_model=ResourceBoundCost(),
                      policy=make_policy(policy), bucket_size=bucket_size,
                      priority_backend=backend)

    # a standing cluster-wide queue of queue_depth scaled by load factor,
    # requests spread over the nodes round-robin
    load = min(1.0, n_nodes * rps_per_node / (64 * 8.0))
    depth = max(8, int(queue_depth * load))
    ids = []
    for i in range(depth):
        rid = f"q{i}"
        prompt = " ".join(rng.choice(words, size=16))
        sched.admit(rid, prompt, int(rng.integers(16, 1024)),
                    arrival=float(i), node_id=i % n_nodes)
        ids.append(rid)
    gen = np.zeros(depth, np.int64)
    sched.refresh()      # settle the standing queue

    n_refresh = max(1, depth // 10)   # rows crossing a bucket per arrival
    t_pred, t_sched = [], []
    aggregate_rps = n_nodes * rps_per_node
    cursor = 0
    for i in range(n_probe):
        prompt = " ".join(rng.choice(words, size=16))
        node = i % n_nodes
        t0 = time.perf_counter()
        sched.admit(f"p{i}", prompt, 128, arrival=float(depth + i),
                    node_id=node)
        t1 = time.perf_counter()
        # the per-arrival share of periodic refreshes: push a rotating
        # slice of the standing queue across its next bucket boundary,
        # recompute cluster-wide in one batched pass, then rank the
        # arriving node's queue (the dispatch decision)
        take = [(cursor + j) % depth for j in range(n_refresh)]
        gen[take] += bucket_size
        cursor = (cursor + n_refresh) % depth
        sched.on_progress_many([ids[j] for j in take], gen[take])
        sched.refresh()
        sched.order(node_id=node)
        t2 = time.perf_counter()
        sched.on_abort(f"p{i}")  # keep the standing depth constant
        t_pred.append((t1 - t0) * 1e3)
        t_sched.append((t2 - t1) * 1e3)
    return {
        "n_nodes": n_nodes,
        "aggregate_rps": aggregate_rps,
        "queue_depth": depth,
        "backend": backend,
        "policy": policy,
        "refresh_rows_per_arrival": n_refresh,
        "predict_ms": float(np.mean(t_pred)),
        "schedule_ms": float(np.mean(t_sched)),
        "total_ms": float(np.mean(t_pred) + np.mean(t_sched)),
    }
