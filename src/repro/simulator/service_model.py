"""Analytic service-time model for one accelerator node.

Calibrated to the paper's Sec. 3.2 measurements (Fig. 5):

  * decode iteration time is **linear in the accumulated sequence length**
    of the batch (per-step attention reads the whole KVCache), plus a
    batch-size term (FFN/GEMM work per token) plus a fixed term (weight
    reads + dispatch);
  * the node is **memory-bound** when the KVCache byte traffic dominates,
    **compute-bound** when the per-token FLOPs dominate — both regimes
    emerge from the same max(compute, memory) formulation below;
  * KVCache capacity caps the admissible batch (Fig. 2(b)/5(a)).

Default constants model the paper's larger testbed (Qwen3-32B on one
H800-96GB); ``a40_llama8b()`` models the smaller one and
``tpu_v5e_pod8_32b()`` the TPU adaptation from DESIGN.md.
The constants only set the scale; the scheduler comparisons depend on the
*structure* (linearity in KV tokens + capacity bound), which follows the
paper's measurements.
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = ["NodeSpec", "ServiceModel", "ScaledServiceModel"]


@dataclass(frozen=True)
class NodeSpec:
    """Hardware + model constants for one serving node."""

    name: str = "h800-qwen3-32b"       # the paper's Sec. 4.1 testbed node
    peak_flops: float = 990e12          # dense bf16 FLOP/s (H100-class)
    hbm_bandwidth: float = 3.35e12      # bytes/s (H800 HBM3)
    hbm_bytes: float = 96 * 2**30       # total HBM
    weight_bytes: float = 64e9          # ~32B params bf16
    flops_per_token: float = 64e9       # ~2 * params per generated token
    kv_bytes_per_token: float = 262144  # 64L * 8kvh * 128d * 2(KV) * 2B
    mfu: float = 0.55                   # achievable fraction of peak
    mbu: float = 0.8                    # achievable fraction of HBM bw
    swap_bandwidth: float = 64e9        # host link (swap in/out)
    swap_overlap: float = 0.8           # fraction hidden by overlapping
    fixed_overhead_s: float = 2e-4      # dispatch / collective latency
    max_batch: int = 256
    kv_reserve_fraction: float = 0.1    # activations + fragmentation slack

    @property
    def kv_capacity_tokens(self) -> int:
        free = (self.hbm_bytes - self.weight_bytes) * (1 - self.kv_reserve_fraction)
        return max(1, int(free / self.kv_bytes_per_token))


def h800_qwen32b() -> NodeSpec:
    """The paper's larger testbed: Qwen3-32B on one H800-PCIe-96GB."""
    return NodeSpec()


def a40_llama8b() -> NodeSpec:
    """The paper's smaller testbed: Llama3.1-8B on one A40-PCIe-48GB."""
    return NodeSpec(
        name="a40-llama3.1-8b",
        peak_flops=150e12, hbm_bandwidth=696e9, hbm_bytes=48 * 2**30,
        weight_bytes=16e9, flops_per_token=16e9,
        kv_bytes_per_token=131072)  # 32L * 8kvh * 128d * 2 * 2B


def tpu_v5e_pod8_32b() -> NodeSpec:
    """TPU adaptation (DESIGN.md): 8-chip v5e slice serving a 32B model."""
    return NodeSpec(
        name="tpu-v5e-x8-32b",
        peak_flops=8 * 197e12, hbm_bandwidth=8 * 819e9,
        hbm_bytes=8 * 16 * 2**30, weight_bytes=64e9,
        flops_per_token=64e9, kv_bytes_per_token=262144)


@dataclass
class ServiceModel:
    spec: NodeSpec = field(default_factory=NodeSpec)

    # ------------------------------------------------------------- decode

    def decode_iteration_time(self, batch_size: int, total_kv_tokens: int
                              ) -> float:
        """One decode step for a batch holding ``total_kv_tokens`` context.

        compute:  B tokens * flops_per_token / (mfu * peak)
        memory:   weight reads + KV reads, at mbu * bandwidth
        The node is compute- or memory-bound depending on which dominates —
        the paper's Fig. 5(a) regimes.
        """
        s = self.spec
        compute = batch_size * s.flops_per_token / (s.mfu * s.peak_flops)
        mem_bytes = s.weight_bytes + total_kv_tokens * s.kv_bytes_per_token
        memory = mem_bytes / (s.mbu * s.hbm_bandwidth)
        return s.fixed_overhead_s + max(compute, memory)

    def decode_run_time(self, batch_size: int, start_kv_tokens: int,
                        n_steps: int) -> float:
        """Closed-form time for ``n_steps`` consecutive decode steps with a
        fixed active set (each step adds ``batch_size`` KV tokens).

        Exact when the binding regime does not flip mid-run; the simulator
        only uses runs short enough (<= one bucket) for this to hold to
        first order, and regime flips within a run only smooth the max().
        """
        s = self.spec
        if n_steps <= 0:
            return 0.0
        compute = batch_size * s.flops_per_token / (s.mfu * s.peak_flops)
        bw = s.mbu * s.hbm_bandwidth
        # memory term summed over steps: n*W + kv_bpt*(n*T0 + B*n(n-1)/2)
        kv_tokens_sum = (n_steps * start_kv_tokens
                         + batch_size * n_steps * (n_steps - 1) // 2)
        mem_time = (n_steps * s.weight_bytes
                    + kv_tokens_sum * s.kv_bytes_per_token) / bw
        comp_time = n_steps * compute
        return n_steps * s.fixed_overhead_s + max(comp_time, mem_time)

    # ------------------------------------------------------------ prefill

    def prefill_time(self, input_tokens: int) -> float:
        """Prefill is compute-bound (Sarathi/DistServe observation):
        quadratic attention + linear FFN over the prompt."""
        s = self.spec
        ffn = input_tokens * s.flops_per_token
        # attention ~ flops_per_token is dominated by FFN until long ctx;
        # approximate the quadratic part against a 4k knee
        attn = input_tokens * max(0, input_tokens - 512) * (s.flops_per_token / 8192)
        return s.fixed_overhead_s + (ffn + attn) / (s.mfu * s.peak_flops)

    def prefill_chunk_time(self, chunk_tokens: int, past_tokens: int
                           ) -> float:
        """One Sarathi-style prefill chunk of ``chunk_tokens`` against an
        already-cached prefix of ``past_tokens``: linear FFN over the
        chunk + attention of the chunk's queries against the full prefix
        (same 512-token knee as ``prefill_time``), plus one iteration's
        fixed overhead — the per-chunk dispatch cost that makes chunking
        a throughput/TTFT trade, not a free lunch."""
        s = self.spec
        ffn = chunk_tokens * s.flops_per_token
        ctx = past_tokens + chunk_tokens
        attn = chunk_tokens * max(0, ctx - 512) * (s.flops_per_token / 8192)
        return s.fixed_overhead_s + (ffn + attn) / (s.mfu * s.peak_flops)

    def prefill_time_shared(self, input_tokens: int,
                            cached_prefix: int) -> float:
        """Prefill cost when the leading ``cached_prefix`` tokens' KV is
        already resident (adopted from the prefix index — the engine's
        copy-on-write sharing): only the remainder is computed, as one
        chunk attending to the cached prefix.  ``cached_prefix <= 0``
        degrades to the atomic ``prefill_time``; a fully-cached prompt
        still pays one dispatch (the engine always recomputes at least
        the final position).  Composed from the primitives, so
        ``ScaledServiceModel`` inherits the scaling."""
        cached = max(0, min(int(cached_prefix), int(input_tokens)))
        if cached == 0:
            return self.prefill_time(input_tokens)
        return self.prefill_chunk_time(max(1, input_tokens - cached),
                                       cached)

    def prefill_time_chunked(self, input_tokens: int,
                             chunk: int | None) -> float:
        """Total prefill time when split into ``chunk``-token pieces
        (``None`` or >= input_tokens: the atomic ``prefill_time``)."""
        if not chunk or chunk >= input_tokens:
            return self.prefill_time(input_tokens)
        total, done = 0.0, 0
        while done < input_tokens:
            take = min(chunk, input_tokens - done)
            total += self.prefill_chunk_time(take, done)
            done += take
        return total

    # --------------------------------------------------------------- swap

    def swap_time(self, kv_tokens: int, block_size: int = 1) -> float:
        """Un-overlapped cost of swapping a request's KV in or out.

        ``block_size > 1`` rounds the transfer up to whole KV blocks —
        the block-table accounting of ``serving.kv_cache.KVCacheManager``.
        Both the real engine and the simulator charge preemptions through
        THIS function, so the two layers share one preemption cost model.
        """
        s = self.spec
        if block_size > 1:
            kv_tokens = -(-int(kv_tokens) // block_size) * block_size
        raw = kv_tokens * s.kv_bytes_per_token / s.swap_bandwidth
        return raw * (1.0 - s.swap_overlap)


@dataclass
class ScaledServiceModel(ServiceModel):
    """A node running uniformly slower (or faster) by a constant factor —
    thermal throttling, a degraded interconnect, or an injected
    slow-node fault (``NodeSimulator.slow_down``).  Every primitive time
    is scaled, so the simulator's closed-form fast-forward math stays
    internally consistent; composite helpers (``prefill_time_chunked``)
    inherit the scaling through the primitives they call."""

    factor: float = 1.0

    def decode_iteration_time(self, batch_size, total_kv_tokens):
        return self.factor * super().decode_iteration_time(
            batch_size, total_kv_tokens)

    def decode_run_time(self, batch_size, start_kv_tokens, n_steps):
        return self.factor * super().decode_run_time(
            batch_size, start_kv_tokens, n_steps)

    def prefill_time(self, input_tokens):
        return self.factor * super().prefill_time(input_tokens)

    def prefill_chunk_time(self, chunk_tokens, past_tokens):
        return self.factor * super().prefill_chunk_time(
            chunk_tokens, past_tokens)

    def swap_time(self, kv_tokens, block_size=1):
        return self.factor * super().swap_time(kv_tokens, block_size)
