"""Discrete-event simulator of one continuous-batching serving node.

Faithful to the vLLM-style execution model the paper builds on:

  * iteration-level (continuous) batching: the active set can change at
    every iteration boundary (Orca / Yu et al. 2022);
  * paged KVCache with a hard token-capacity; admission requires prompt KV
    plus growth headroom; hitting the capacity forces eviction (Fig. 2(b));
  * preemption by swap with (mostly overlapped) IO cost, as the paper
    assumes for Gittins refresh / FastServe demotion — charged through
    the same block-aligned ``ServiceModel.swap_time`` the real engine's
    KVCacheManager accounting uses (``block_size`` parameter);
  * prefill runs as its own iteration, atomically by default; with
    ``prefill_chunk`` set it advances Sarathi-style — at most that many
    prompt tokens per round, mixed with single decode iterations of the
    running batch (the execution model of ``ServingEngine``'s chunked
    prefill plan);
  * capacity-forced eviction picks victims via
    ``Scheduler.eviction_order`` — priority plus an optional
    ``memory_weight`` term (held KV ≈ predicted swap cost), shared with
    the real engine.

The simulator is *event-compressed*: between scheduling events (arrival,
completion, priority-refresh boundary, capacity exhaustion) the active set
is constant, so whole decode runs advance in one closed-form step
(ServiceModel.decode_run_time).  This makes 10k-request × 8-policy sweeps
tractable on one CPU while remaining iteration-exact in time accounting.

Incremental stepping (cluster mode)
-----------------------------------
``NodeSimulator`` is an *incrementally steppable* engine: arrivals are
fed through ``push()`` (non-decreasing arrival order), one scheduling
round runs per ``step()``, and ``finish()`` collects the ``SimResult``.
The classic one-shot ``run()`` is literally push-everything + step-until-
drained, so a standalone node and a node inside the event-driven cluster
loop (repro.simulator.cluster) execute the same rounds.  ``step()`` takes
a ``horizon`` — the next cluster-global arrival time — so a node never
fast-forwards a decode run past a routing decision it hasn't seen; with a
single node the horizon is its own next arrival, which reproduces the
original monolithic loop exactly.

The ``scheduler`` handed to a NodeSimulator is either a real
``repro.core.Scheduler`` (standalone) or a per-node
``NodeSchedulerView`` over the cluster-shared scheduler (then parameter-
less ``order()`` calls become node-masked lexsorts over the shared
BatchState).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.scheduler import Scheduler
from .service_model import NodeSpec, ScaledServiceModel, ServiceModel
from .workload import SimRequest

__all__ = ["RequestMetrics", "SimResult", "NodeSimulator", "simulate"]


@dataclass
class RequestMetrics:
    request_id: str
    dataset: str
    arrival: float
    input_len: int
    output_len: int
    ttft: float = float("nan")   # time to first token (s)
    ttlt: float = float("nan")   # time to last token (s)
    n_preemptions: int = 0
    node_id: int = -1            # serving node (cluster mode)

    @property
    def tpot(self) -> float:
        return self.ttlt / max(1, self.output_len)


@dataclass
class SimResult:
    metrics: list[RequestMetrics]
    makespan: float
    n_iterations: int
    n_preemptions: int
    n_evictions: int
    scheduler_stats: dict

    def _vals(self, attr: str, dataset: str | None = None) -> np.ndarray:
        return np.array([getattr(m, attr) for m in self.metrics
                         if dataset is None or m.dataset == dataset])

    def mean_ttlt(self, dataset: str | None = None) -> float:
        return float(self._vals("ttlt", dataset).mean())

    def mean_ttft(self, dataset: str | None = None) -> float:
        return float(self._vals("ttft", dataset).mean())

    def p99_ttlt(self) -> float:
        return float(np.quantile(self._vals("ttlt"), 0.99))

    def mean_tpot(self) -> float:
        return float(np.mean([m.tpot for m in self.metrics]))


@dataclass
class _Live:
    """Node-side runtime state for one request."""

    req: SimRequest
    metrics: RequestMetrics
    generated: int = 0
    prefilled: bool = False
    prefill_done: int = 0       # prompt tokens prefilled (chunked mode)
    cached_prefix: int = 0      # prompt tokens adopted from the node's
                                # prefix cache (prefix-sharing mode)
    resident_kv: int = 0        # KV tokens currently in HBM
    swapped: bool = False       # preempted with KV moved to host
    pending_swap_in: int = 0    # KV tokens to restore before decoding

    @property
    def kv_if_resident(self) -> int:
        return self.req.input_len + self.generated


class NodeSimulator:
    """One serving node driven by a repro.core.Scheduler (or a per-node
    view over a cluster-shared one)."""

    def __init__(self, scheduler: Scheduler,
                 spec: NodeSpec | None = None,
                 admit_headroom: float = 0.95,
                 preemption_hysteresis: float = 0.5,
                 node_id: int = -1,
                 prefill_chunk: int | None = None,
                 block_size: int = 1,
                 memory_weight: float = 0.0,
                 prefix_sharing: bool = False):
        self.scheduler = scheduler
        self.model = ServiceModel(spec or NodeSpec())
        self.admit_headroom = admit_headroom
        # A waiting request displaces a running one only if its priority
        # beats the running request's priority scaled by this factor —
        # the anti-thrashing counterpart of the paper's bucketized refresh
        # (Sec. 3.3: "thrashing risk ... may frequently reverse").
        self.preemption_hysteresis = preemption_hysteresis
        # Sarathi-style chunked prefill: at most this many prompt tokens
        # prefill per scheduling round, mixed with the decode batch
        # (None = atomic, the seed behavior).
        self.prefill_chunk = prefill_chunk
        # KV block granularity: swap costs are charged on block-aligned
        # token counts — the same ServiceModel.swap_time / block math the
        # real engine's KVCacheManager accounting uses (1 = token-exact,
        # the seed behavior).
        self.block_size = block_size
        # memory term in capacity-forced eviction (Scheduler.
        # eviction_order): 0 = pure reversed priority (seed behavior).
        self.memory_weight = memory_weight
        # Prefix sharing: requests carrying a ``prefix_group`` adopt the
        # group's longest published block-aligned prefix instead of re-
        # prefilling it, priced through ServiceModel.prefill_time_shared
        # — the same function documented for the real engine's saved
        # work.  The prefix cache is node-local (mirrors the engine's
        # per-node KV pool), so cluster routing decides how much reuse a
        # session actually sees.  Off (default): seed behavior.
        self.prefix_sharing = prefix_sharing
        self._group_cached: dict[str, int] = {}
        self.prefill_tokens_reused = 0
        self.node_id = node_id
        self.now = 0.0
        self.n_iterations = 0
        self.n_preemptions = 0
        self.n_evictions = 0
        self._cap = int(self.model.spec.kv_capacity_tokens
                        * self.admit_headroom)
        self._pending: list[SimRequest] = []   # routed, not yet admitted
        self._next = 0                         # index into _pending
        self._live: dict[str, _Live] = {}
        self._done: list[RequestMetrics] = []
        self._prev_active: list[str] = []
        self.alive = True                      # cleared by kill()
        self._adopted: list[tuple[float, _Live]] = []  # migrated in-flight

    # ----------------------------------------------------------- feeding

    @property
    def busy(self) -> bool:
        """True while this node still has admitted or pending work."""
        return self.alive and (self._next < len(self._pending)
                               or bool(self._live) or bool(self._adopted))

    def push(self, r: SimRequest) -> None:
        """Feed one arrival (callers must push in arrival order — the
        cluster loop routes at global arrival times, so this holds)."""
        if not self.alive:
            raise RuntimeError(f"node {self.node_id} is dead")
        self._pending.append(r)

    # ------------------------------------------------------------- faults

    def slow_down(self, factor: float) -> None:
        """Degrade (or, factor < 1, upgrade) this node's service rate by
        a constant factor — the injected slow-node fault.  Applied as a
        ``ScaledServiceModel`` wrapper so every analytic time the
        event-compressed fast-forward relies on stays consistent."""
        self.model = ScaledServiceModel(spec=self.model.spec,
                                        factor=factor * getattr(
                                            self.model, "factor", 1.0))

    def kill(self, t: float) -> list[_Live]:
        """Fail this node at time ``t``.  Every in-flight request is
        withdrawn from the (possibly cluster-shared) scheduler — its
        BatchState row is removed, so no ``node_id`` row dangles — and
        returned, along with still-pending routed arrivals, for the
        cluster loop to re-route or abort.  Host-resident swap payloads
        survive the node (the orphan stays ``swapped`` and pays swap-in
        on its new node); device-resident KV dies with it (the orphan
        re-prefills, keeping the tokens already streamed out).  A dead
        node accepts no further work and reports not busy."""
        self.now = max(self.now, t)
        self.alive = False
        orphans: list[_Live] = []
        for rid, lv in list(self._live.items()):
            self.scheduler.on_abort(rid)   # drops the row, releases the
            if not lv.swapped:             # router's placement accounting
                lv.prefilled = False       # device KV lost: re-prefill
                lv.prefill_done = 0
                lv.cached_prefix = 0       # dead node's prefix cache too
                lv.resident_kv = 0
            lv.metrics.n_preemptions += 1
            orphans.append(lv)
        self._live.clear()
        for r in self._pending[self._next:]:
            self.scheduler.on_abort(r.request_id)  # router release only
            orphans.append(_Live(req=r, metrics=RequestMetrics(
                request_id=r.request_id, dataset=r.dataset,
                arrival=r.arrival, input_len=r.input_len,
                output_len=r.true_output_len, node_id=self.node_id)))
        del self._pending[self._next:]
        for _, lv in self._adopted:
            self.scheduler.on_abort(lv.req.request_id)
            orphans.append(lv)
        self._adopted.clear()
        self._prev_active = []
        return orphans

    def adopt(self, lv: _Live, t: float) -> None:
        """Accept a re-routed in-flight request from a failed node; it is
        re-admitted into this node's scheduler (view) once the local
        clock reaches ``t``, carrying its original arrival stamp and any
        progress already made."""
        if not self.alive:
            raise RuntimeError(f"node {self.node_id} is dead")
        lv.metrics.node_id = self.node_id
        self._adopted.append((float(t), lv))

    # ------------------------------------------------------------- round

    def _admit_arrivals(self) -> None:
        """Admit every due pending arrival in ONE batched admission —
        the scheduler's ``admit_batch`` predicts the whole burst with a
        single batched history search and appends all rows in one
        BatchState pass (bit-identical to sequential admits)."""
        lo = self._next
        hi = lo
        while (hi < len(self._pending)
               and self._pending[hi].arrival <= self.now + 1e-12):
            hi += 1
        if hi > lo:
            self._next = hi
            due = self._pending[lo:hi]
            self.scheduler.admit_batch(
                [r.request_id for r in due], [r.prompt for r in due],
                [r.input_len for r in due],
                arrivals=[r.arrival for r in due])
            for r in due:
                self._live[r.request_id] = _Live(
                    req=r,
                    metrics=RequestMetrics(
                        request_id=r.request_id, dataset=r.dataset,
                        arrival=r.arrival, input_len=r.input_len,
                        output_len=r.true_output_len, node_id=self.node_id))
        if self._adopted:
            # migrated in-flight requests re-enter once their handover
            # time is reached, keeping original arrivals and progress
            due_ad = [lv for ta, lv in self._adopted
                      if ta <= self.now + 1e-12]
            if due_ad:
                self._adopted = [(ta, lv) for ta, lv in self._adopted
                                 if ta > self.now + 1e-12]
                for lv in due_ad:
                    r = lv.req
                    self.scheduler.admit(r.request_id, r.prompt,
                                         r.input_len, arrival=r.arrival)
                    if lv.generated:
                        self.scheduler.on_progress(r.request_id,
                                                   lv.generated)
                    self._live[r.request_id] = lv

    def _cached_prefix_for(self, req: SimRequest) -> int:
        """Block-aligned prompt prefix adoptable from this node's prefix
        cache, capped below the full prompt (the engine always computes
        at least the final position — its block holding the rewind point
        stays private)."""
        if not self.prefix_sharing or not req.prefix_group:
            return 0
        avail = self._group_cached.get(req.prefix_group, 0)
        bs = max(1, self.block_size)
        m = min(req.shared_prefix_len, avail, req.input_len - 1)
        return max(0, (m // bs) * bs)

    def _publish_prefix(self, req: SimRequest) -> None:
        """After a prefill completes, publish the request's sharable
        leading blocks for later group members (a session turn publishes
        its whole prompt; a tenant request only its system prompt)."""
        if not self.prefix_sharing or not req.prefix_group:
            return
        bs = max(1, self.block_size)
        pub = (min(req.sharable_prefix_len, req.input_len) // bs) * bs
        g = req.prefix_group
        if pub > self._group_cached.get(g, 0):
            self._group_cached[g] = pub

    def _select_active(self, prev_active: list[str]) -> list[str]:
        """Greedy admission in scheduler-priority order under the KV
        capacity + max-batch constraints.  Non-preemptive policies keep
        the previous active set unconditionally.  The ranking itself
        is one scheduler call — a single np.lexsort over the
        BatchState arrays under a batched backend (order() refreshes
        all dirty priorities wholesale first)."""
        live = self._live
        max_batch = self.model.spec.max_batch
        if self.scheduler.preemptive:
            # rank with hysteresis: running requests' priorities are
            # scaled down so marginal reversals don't trigger swaps
            candidates = self.scheduler.order(
                running=set(prev_active),
                hysteresis=self.preemption_hysteresis)
            active, used = [], 0
        else:
            active = [r for r in prev_active if r in live]
            used = sum(live[r].kv_if_resident for r in active)
            waiting = [r for r in live if r not in set(active)]
            candidates = self.scheduler.order(waiting)
        for rid in candidates:
            if rid in active or len(active) >= max_batch:
                continue
            need = live[rid].kv_if_resident
            if used + need <= self._cap:
                active.append(rid)
                used += need
        return active

    def step(self, horizon: float = float("inf")) -> None:
        """One scheduling round: admit due arrivals, pick the active set,
        advance prefill/decode until the next event, record completions.
        Decode fast-forward is capped at the node's own next pending
        arrival *and* at ``horizon`` (the next cluster-global arrival —
        a routing decision this node must not simulate past)."""
        if not self.alive:
            return
        live = self._live
        cap = self._cap
        self._admit_arrivals()
        self.scheduler.set_now(self.now)
        if not live:
            # idle: jump to the next pending arrival / adoption handover
            nxt = [self._pending[self._next].arrival] \
                if self._next < len(self._pending) else []
            nxt += [ta for ta, _ in self._adopted]
            if nxt:
                self.now = max(self.now, min(nxt))
            return

        prev_active = self._prev_active
        active = self._select_active(prev_active)
        if not active:
            # queue non-empty but nothing fits (e.g. giant prompt while
            # actives were preempted away) — shouldn't happen with
            # preemptive policies; guard by forcing the top request
            top = self.scheduler.order(list(live.keys()))[0]
            active = [top]

        # account preemptions (previously active, now displaced)
        for rid in prev_active:
            if rid in live and rid not in active:
                lv = live[rid]
                if lv.resident_kv > 0:
                    lv.swapped = True
                    lv.resident_kv = 0
                    lv.metrics.n_preemptions += 1
                    self.n_preemptions += 1

        iter_time = 0.0

        # swap-in restored requests — charged through the SAME block-
        # aligned ServiceModel.swap_time the real engine's accounting uses
        for rid in active:
            lv = live[rid]
            if lv.swapped:
                iter_time += self.model.swap_time(lv.kv_if_resident,
                                                  self.block_size)
                lv.swapped = False
            if lv.prefilled:
                lv.resident_kv = lv.kv_if_resident

        # prefills: atomic (seed behavior), or Sarathi chunks under a
        # per-round token budget, mixed with the decode batch below
        if self.prefill_chunk:
            budget = self.prefill_chunk
            for rid in active:
                lv = live[rid]
                if lv.prefilled or budget <= 0:
                    continue
                if lv.prefill_done == 0:
                    # chunked prefill starts at the divergence point:
                    # the adopted prefix is already (virtually) resident
                    lv.cached_prefix = self._cached_prefix_for(lv.req)
                    lv.prefill_done = lv.cached_prefix
                    self.prefill_tokens_reused += lv.cached_prefix
                take = min(budget, lv.req.input_len - lv.prefill_done)
                iter_time += self.model.prefill_chunk_time(take,
                                                           lv.prefill_done)
                lv.prefill_done += take
                budget -= take
                self.n_iterations += 1
                if lv.prefill_done >= lv.req.input_len:
                    lv.prefilled = True
                    self._publish_prefix(lv.req)
                    if lv.generated == 0:   # a migrated request re-
                        lv.generated = 1    # prefills but keeps its
                        lv.metrics.ttft = (self.now + iter_time  # progress
                                           - lv.req.arrival)     # and ttft
                    lv.resident_kv = lv.kv_if_resident
                    self.scheduler.on_progress(rid, lv.generated)
        else:
            for rid in active:
                lv = live[rid]
                if not lv.prefilled:
                    lv.cached_prefix = self._cached_prefix_for(lv.req)
                    self.prefill_tokens_reused += lv.cached_prefix
                    iter_time += self.model.prefill_time_shared(
                        lv.req.input_len, lv.cached_prefix)
                    lv.prefilled = True
                    lv.prefill_done = lv.req.input_len
                    self._publish_prefix(lv.req)
                    if lv.generated == 0:   # see chunked branch: migrated
                        lv.generated = 1    # requests keep progress/ttft
                        lv.metrics.ttft = (self.now + iter_time
                                           - lv.req.arrival)
                    lv.resident_kv = lv.kv_if_resident
                    self.n_iterations += 1
                    self.scheduler.on_progress(rid, lv.generated)

        # decode fast-forward: fixed decode set until the next event.
        # In chunked mode, requests still mid-prefill sit out the decode
        # and cap the run at ONE mixed iteration (their next chunk is a
        # scheduling event of its own).
        decoding = [rid for rid in active if live[rid].prefilled]
        mid_prefill = len(decoding) < len(active)
        batch = [live[rid] for rid in decoding]
        remaining = [lv.req.true_output_len - lv.generated for lv in batch]
        steps = max(0, min(remaining)) if batch else 0
        if mid_prefill:
            steps = min(steps, 1)
        # runtime_refreshing also covers mid-flight posterior updates
        # (frozen policies still reorder when a posterior cut is crossed)
        if batch and getattr(self.scheduler, "runtime_refreshing",
                             self.scheduler.policy.refreshing):
            to_refresh = self.scheduler.min_tokens_to_refresh(decoding)
            if to_refresh > 0 and np.isfinite(to_refresh):
                steps = min(steps, int(to_refresh))
        B = len(batch)
        total_kv = sum(lv.resident_kv for lv in batch)
        if steps > 0:
            # capacity exhausted: force eviction until one decode step of
            # growth fits.  Victims come from Scheduler.eviction_order —
            # priority PLUS the memory term (held KV ~ predicted swap
            # cost), the same ranking the real engine uses.
            while (cap - total_kv) < len(decoding) and len(decoding) > 1:
                victim = self.scheduler.eviction_order(
                    decoding,
                    held_tokens={r: live[r].resident_kv for r in decoding},
                    swap_cost=lambda t: self.model.swap_time(
                        t, self.block_size),
                    memory_weight=self.memory_weight)[0]
                lv = live[victim]
                total_kv -= lv.resident_kv
                lv.swapped = True
                lv.resident_kv = 0
                lv.metrics.n_preemptions += 1
                self.n_evictions += 1
                decoding = [r for r in decoding if r != victim]
                active = [r for r in active if r != victim]
            batch = [live[rid] for rid in decoding]
            B = len(batch)
            remaining = [lv.req.true_output_len - lv.generated
                         for lv in batch]
            steps = min(steps, max(1, min(remaining)))
            headroom = max(1, (cap - total_kv) // B)
            steps = min(steps, int(headroom))
            # cap the run so the next scheduling event (this node's next
            # pending arrival, or the cluster's next routing decision)
            # can be scheduled against
            if self._next < len(self._pending):
                next_t = min(self._pending[self._next].arrival, horizon)
            else:
                next_t = horizon
            if np.isfinite(next_t):
                gap = next_t - (self.now + iter_time)
                lo, hi = 1, steps
                while lo < hi:  # max k with run_time(k) <= gap
                    mid = (lo + hi + 1) // 2
                    if self.model.decode_run_time(B, total_kv, mid) <= gap:
                        lo = mid
                    else:
                        hi = mid - 1
                    if hi <= lo:
                        break
                steps = max(1, lo)
            iter_time += self.model.decode_run_time(B, total_kv, steps)
            self.n_iterations += steps
            for lv in batch:
                lv.generated += steps
                lv.resident_kv = lv.kv_if_resident
        elif not batch:
            pass  # pure-prefill round (chunked mode)
        elif all(lv.req.true_output_len <= lv.generated for lv in batch):
            pass  # all completing right after prefill
        elif iter_time == 0.0:
            # no prefill, no decode progress possible: single step
            iter_time += self.model.decode_iteration_time(B, total_kv)
            self.n_iterations += 1
            for lv in batch:
                if lv.generated < lv.req.true_output_len:
                    lv.generated += 1
                    lv.resident_kv = lv.kv_if_resident

        self.now += iter_time

        # progress + completions (progress reported wholesale: one
        # dirty-mark pass under a batched backend)
        progressing: list[str] = []
        for rid in active:
            lv = live[rid]
            if lv.generated >= lv.req.true_output_len:
                lv.metrics.ttlt = self.now - lv.req.arrival
                if not np.isfinite(lv.metrics.ttft):
                    lv.metrics.ttft = lv.metrics.ttlt
                self._done.append(lv.metrics)
                self.scheduler.on_complete(rid, lv.req.true_output_len)
                del live[rid]
            else:
                progressing.append(rid)
        self.scheduler.on_progress_many(
            progressing, [live[r].generated for r in progressing])
        self._prev_active = [r for r in active if r in live]

    # ------------------------------------------------------------------ run

    def finish(self) -> SimResult:
        return SimResult(metrics=self._done, makespan=self.now,
                         n_iterations=self.n_iterations,
                         n_preemptions=self.n_preemptions,
                         n_evictions=self.n_evictions,
                         scheduler_stats=dict(self.scheduler.stats))

    def run(self, requests: list[SimRequest]) -> SimResult:
        """One-shot simulation: feed every arrival, step until drained."""
        for r in sorted(requests, key=lambda r: r.arrival):
            self.push(r)
        while self.busy:
            self.step()
        return self.finish()


def simulate(requests: list[SimRequest], scheduler: Scheduler,
             spec: NodeSpec | None = None, **node_kwargs) -> SimResult:
    """Convenience one-shot simulation.  ``node_kwargs`` pass through to
    ``NodeSimulator`` (e.g. ``prefill_chunk``, ``block_size``,
    ``memory_weight``)."""
    return NodeSimulator(scheduler, spec, **node_kwargs).run(requests)
