"""Discrete-event serving simulator (paper Sec. 4 evaluation vehicle)."""

from .cluster import (ClusterResult, ClusterScheduler, CostAwareRouter,
                      JoinShortestWorkRouter, NodeKill, NodeSchedulerView,
                      NodeSlow, Router, ROUTER_NAMES, make_router,
                      measure_scheduler_overhead, simulate_cluster)
from .service_model import (NodeSpec, ScaledServiceModel, ServiceModel,
                            a40_llama8b, h800_qwen32b, tpu_v5e_pod8_32b)
from .simulator import NodeSimulator, RequestMetrics, SimResult, simulate
from .workload import (DATASET_NAMES, DatasetProfile, SemanticCluster,
                       SimRequest, generate_workload, make_profile)

__all__ = [
    "ClusterResult", "ClusterScheduler", "CostAwareRouter",
    "JoinShortestWorkRouter", "NodeSchedulerView", "Router", "ROUTER_NAMES",
    "make_router", "measure_scheduler_overhead", "simulate_cluster",
    "NodeKill", "NodeSlow", "NodeSpec", "ScaledServiceModel",
    "ServiceModel", "a40_llama8b", "h800_qwen32b",
    "tpu_v5e_pod8_32b", "NodeSimulator", "RequestMetrics",
    "SimResult", "simulate", "DATASET_NAMES", "DatasetProfile",
    "SemanticCluster", "SimRequest", "generate_workload", "make_profile",
]
