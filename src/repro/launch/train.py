"""Training launcher: sharded train loop on the local mesh (reduced
config on CPU; the production-mesh path is exercised by dryrun.py).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 20
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCH_IDS, get_config
from ..data import lm_batches
from ..models import build_model
from ..sharding import resolve_specs, rules_for
from ..training import (AdamW, make_lr_schedule, make_train_step,
                        save_checkpoint)
from .mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    model = build_model(cfg)
    mesh = make_local_mesh()
    rules = rules_for(cfg, "train", mesh)
    pspecs = resolve_specs(model.param_specs(), rules)
    ns = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                      is_leaf=lambda x: isinstance(x, P))
    params = jax.device_put(model.init(jax.random.PRNGKey(0)), ns)
    opt = AdamW(learning_rate=args.lr, moment_dtype=cfg.moment_dtype)
    state = opt.init(params)
    sched = make_lr_schedule(warmup=max(2, args.steps // 10),
                             total=args.steps)
    data = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)
    with mesh:
        step_fn = jax.jit(make_train_step(model, opt, sched))
        t0 = time.time()
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            params, state, metrics = step_fn(params, state, batch)
            if step % 5 == 0 or step == args.steps - 1:
                print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time() - t0) / (step + 1):.2f}s/step)",
                      flush=True)
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, state, step=args.steps)
        print("checkpoint ->", args.checkpoint)


if __name__ == "__main__":
    main()
