import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, record memory analysis, cost analysis, and
roofline terms.  MUST be run as its own process (the XLA_FLAGS lines above
execute before any jax import — 512 placeholder host devices).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                      # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCH_IDS, SHAPE_IDS, get_config, get_shape  # noqa: E402
from ..models import build_model             # noqa: E402
from ..models.layers import shapes_from_template  # noqa: E402
from ..sharding import (activation_sharding, batch_axes, kv_cache_spec,  # noqa: E402
                        logits_spec, resolve_specs, rules_for,
                        ssm_state_spec)
from ..training.optimizer import AdamW, AdamWState  # noqa: E402
from ..training.train_loop import make_train_step   # noqa: E402
from .mesh import make_production_mesh       # noqa: E402
from .roofline import (HW, analytic_floors, collective_bytes,  # noqa: E402
                        model_flops, roofline_terms)  # noqa: E402

SKIPS = {
    # (arch, shape): reason — documented in DESIGN.md Sec. 5
    ("seamless-m4t-medium", "long_500k"):
        "enc-dec with full cross-attention has no sub-quadratic 500k path",
}


def serve_mode(cfg) -> str:
    """'serve' or 'serve_big' (2-D weight storage) by per-chip weight size."""
    per_dev = cfg.param_count() * 2 / cfg.model_parallel
    return "serve_big" if per_dev > 10e9 else "serve"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape, mesh):
    """ShapeDtypeStructs + PartitionSpecs for the step inputs (no alloc)."""
    B, S = shape.global_batch, shape.seq_len
    b_ax = batch_axes(mesh, B)
    D = cfg.d_model
    batch, specs = {}, {}
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            se = S // 2
            batch["frames"] = _sds((B, se, D), jnp.bfloat16)
            batch["tokens"] = _sds((B, S - se), jnp.int32)
            specs["frames"] = P(b_ax, None, None)
            specs["tokens"] = P(b_ax, None)
        elif cfg.family == "vlm":
            pt = min(cfg.n_frontend_tokens, S // 2)
            batch["patches"] = _sds((B, pt, D), jnp.bfloat16)
            batch["tokens"] = _sds((B, S - pt), jnp.int32)
            specs["patches"] = P(b_ax, None, None)
            specs["tokens"] = P(b_ax, None)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
            specs["tokens"] = P(b_ax, None)
        if shape.kind == "train":
            batch["labels"] = _sds(batch["tokens"].shape, jnp.int32)
            specs["labels"] = P(b_ax, None)
    return batch, specs


def cache_specs(cfg, model, shape, mesh, mode):
    """Decode-cache ShapeDtypeStructs + PartitionSpecs."""
    B, S = shape.global_batch, shape.seq_len
    s_max = cfg.window if cfg.attention_kind == "sliding_window" else S
    enc_len = min(cfg.n_frontend_tokens or 4096, 4096)
    shapes = model.cache_shapes(B, s_max, enc_len=enc_len)
    kv_spec = kv_cache_spec(cfg, mode, mesh, B)
    specs = {}
    for name in shapes:
        if name in ("k", "v", "cross_k", "cross_v"):
            specs[name] = kv_spec
        elif name == "ssm":
            specs[name] = ssm_state_spec(cfg, mode, mesh, B)
    return shapes, specs


def build_case(arch: str, shape_name: str, mesh):
    """Returns (fn, example_args, in_shardings, out_shardings, meta)."""
    shape = get_shape(shape_name)
    long_ctx = shape_name == "long_500k"
    cfg = get_config(arch, long_context=long_ctx)
    model = build_model(cfg)
    mode = "train" if shape.kind == "train" else serve_mode(cfg)
    rules = rules_for(cfg, mode, mesh)
    param_specs = resolve_specs(model.param_specs(), rules)
    param_shapes = shapes_from_template(model.template())
    batch_shapes, batch_pspecs = input_specs(cfg, shape, mesh)
    b_ax = batch_axes(mesh, shape.global_batch)

    def ns(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        opt = AdamW(moment_dtype=cfg.moment_dtype)
        step = make_train_step(model, opt)
        opt_shapes = AdamWState(
            count=_sds((), jnp.int32),
            m=jax.tree.map(lambda s: _sds(s.shape, jnp.dtype(cfg.moment_dtype)),
                           param_shapes),
            v=jax.tree.map(lambda s: _sds(s.shape, jnp.dtype(cfg.moment_dtype)),
                           param_shapes))
        opt_specs = AdamWState(count=P(), m=param_specs, v=param_specs)
        in_sh = (ns(param_specs), ns(opt_specs), ns(batch_pspecs))
        out_sh = (ns(param_specs), ns(opt_specs),
                  ns({"loss": P(), "lm_loss": P(), "aux_loss": P()}))
        args = (param_shapes, opt_shapes, batch_shapes)
        return step, args, in_sh, out_sh, dict(cfg=cfg, mode=mode,
                                               donate=(0, 1))

    if shape.kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch)
        csh, csp = cache_specs(cfg, model, shape, mesh, mode)
        # prefill returns cache sized by actual sequence; rebuild spec tree
        in_sh = (ns(param_specs), ns(batch_pspecs))
        out_sh = (ns(logits_spec(mesh, mode, shape.global_batch)), ns(csp))
        args = (param_shapes, batch_shapes)
        return prefill, args, in_sh, out_sh, dict(cfg=cfg, mode=mode,
                                                  donate=())

    # decode
    def decode(params, token, cache, cache_len):
        return model.decode_step(params, token, cache, cache_len)
    csh, csp = cache_specs(cfg, model, shape, mesh, mode)
    B = shape.global_batch
    token = _sds((B, 1), jnp.int32)
    cache_len = _sds((B,), jnp.int32)
    in_sh = (ns(param_specs), ns(P(b_ax, None)), ns(csp), ns(P(b_ax)))
    out_sh = (ns(logits_spec(mesh, mode, B)), ns(csp))
    args = (param_shapes, token, csh, cache_len)
    return decode, args, in_sh, out_sh, dict(cfg=cfg, mode=mode, donate=(2,))


def run_case(arch: str, shape_name: str, multi_pod: bool,
             check_fit: bool = False) -> dict:
    t0 = time.time()
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": SKIPS[(arch, shape_name)]}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    fn, args, in_sh, out_sh, meta = build_case(arch, shape_name, mesh)
    cfg, mode = meta["cfg"], meta["mode"]
    shape = get_shape(shape_name)

    act_spec, heads_spec, inner_spec, state_spec = None, None, None, None
    expert_spec = NamedSharding(mesh, P("model", None, None))
    if mode == "train":
        # Megatron-style sequence parallelism on the residual stream:
        # bounds the per-device rematerialized activation memory.  The
        # heads constraint prevents involuntary full-replication reshards
        # in the QKV backward under 2-D weight sharding (§Perf).
        b_ax = batch_axes(mesh, None)
        act_spec = NamedSharding(mesh, P(b_ax, "model", None))
        heads_spec = NamedSharding(mesh, P(b_ax, None, "model", None))
        inner_spec = NamedSharding(mesh, P(b_ax, None, "model"))
        state_spec = NamedSharding(mesh, P(b_ax, "model", None, None))
    with mesh:
        ctx = activation_sharding(act_spec, heads_spec, inner_spec,
                                  state_spec, expert_spec)
        with ctx:
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=meta["donate"])
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    floors = analytic_floors(cfg, shape, n_chips)
    terms = roofline_terms(max(flops, floors["flops_floor"]),
                           max(bytes_acc, floors["bytes_floor"]),
                           max(coll["total"], floors["collective_floor"]))
    mf = model_flops(cfg, shape, n_chips)
    rec = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "mode": mode, "status": "ok",
        "n_chips": n_chips,
        "flops_per_chip": max(flops, floors["flops_floor"]),
        "bytes_per_chip": max(bytes_acc, floors["bytes_floor"]),
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "analytic_floors": floors,
        "collective_bytes_per_chip": max(coll["total"],
                                         floors["collective_floor"]),
        "hlo_collective_bytes_per_chip": coll["total"],
        "collective_breakdown": {k: v for k, v in coll.items()
                                 if k not in ("total", "counts")},
        "collective_counts": coll["counts"],
        "roofline": terms,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": (mf / max(flops, floors["flops_floor"]))
            if flops else None,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "output_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)
                           - getattr(mem, "alias_size_in_bytes", 0)),
        },
        "fits_hbm": None,
        "compile_s": round(time.time() - t0, 1),
    }
    rec["fits_hbm"] = bool(rec["memory"]["peak_bytes"] <= HW["hbm_bytes"])
    return rec


# ---------------------------------------------------- serving min-tp report

MIN_TP_ARCHS = ("deepseek-moe-16b", "nemotron-4-340b")


def min_tp_report(archs=MIN_TP_ARCHS, *, n_slots: int = 64,
                  max_seq_len: int = 4096, page_size: int = 16,
                  max_tp: int = 256) -> dict:
    """Smallest serving width that fits one shard per chip, per arch and
    per parallel mode — priced by ``serving.sharded.estimate_device_bytes``
    (pure template arithmetic, no allocation, no compile), so sweeping a
    pow2 tp ladder over 340B-param configs is instant.

    The exact-vs-efficient gap IS the report's point: exact mode
    replicates every Megatron weight, so its min tp is set by the full
    parameter footprint; efficient mode divides the projections too and
    typically fits several rungs earlier."""
    from ..serving.sharded import estimate_device_bytes
    out = {}
    for arch in archs:
        cfg = get_config(arch)
        model = build_model(cfg)
        n_pages = n_slots * (-(-max_seq_len // page_size)) + 1  # + scratch
        rec = {}
        for parallel in ("exact", "efficient"):
            ladder, fit = [], None
            tp = 1
            while tp <= max_tp:
                est = estimate_device_bytes(
                    model, tp=tp, parallel=parallel, n_pages=n_pages,
                    page_size=page_size, n_slots=n_slots)
                fits = est["total_bytes"] <= HW["hbm_bytes"]
                ladder.append({
                    "tp": tp, "fits": fits,
                    "total_gib": round(est["total_bytes"] / 2**30, 2),
                    "weights_gib": round(est["weights_bytes"] / 2**30, 2),
                    "kv_pool_gib": round(est["kv_pool_bytes"] / 2**30, 2),
                    "replicated_gib":
                        round(est["replicated_bytes"] / 2**30, 2),
                    "fallbacks": list(est["report"]["fallbacks"]),
                })
                if fit is None and fits:
                    fit = tp
                tp *= 2
            rec[parallel] = {"min_tp": fit, "ladder": ladder}
        out[arch] = rec
    return out


def print_min_tp(report: dict) -> None:
    hbm = HW["hbm_bytes"] / 2**30
    print(f"serving min-tp report (HBM budget {hbm:.0f} GiB/chip, "
          f"64 slots x 4k ctx KV pool):")
    for arch, rec in report.items():
        for parallel, r in rec.items():
            print(f"  {arch:18s} {parallel:9s} min_tp={r['min_tp']}")
            for rung in r["ladder"]:
                mark = "fits" if rung["fits"] else "OOM "
                print(f"    tp={rung['tp']:<4d} {mark} "
                      f"total={rung['total_gib']:8.2f} GiB "
                      f"(weights {rung['weights_gib']:.2f}, "
                      f"kv {rung['kv_pool_gib']:.2f}, "
                      f"replicated {rung['replicated_gib']:.2f})"
                      + (f" fallbacks={rung['fallbacks']}"
                         if rung["fallbacks"] else ""))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=SHAPE_IDS)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape x mesh)")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--min-tp", action="store_true",
                    help="serving min-tp report (deepseek-moe-16b + "
                         "nemotron-4-340b, exact vs efficient) instead "
                         "of lowering cases")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    if args.min_tp:
        report = min_tp_report(
            (args.arch,) if args.arch else MIN_TP_ARCHS)
        print_min_tp(report)
        with open(args.out, "w") as f:
            json.dump({"min_tp": report}, f, indent=1)
        return

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    def key(a, s, mp):
        return f"{a}|{s}|{'multi' if mp else 'single'}"

    cases = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPE_IDS:
                cases.append((a, s, False))
                if not args.single_pod_only:
                    cases.append((a, s, True))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cases.append((args.arch, args.shape, args.multi_pod))

    for a, s, mp in cases:
        k = key(a, s, mp)
        if k in results and results[k].get("status") in ("ok", "skipped"):
            print(f"[cached] {k}")
            continue
        print(f"[dryrun] {k} ...", flush=True)
        try:
            rec = run_case(a, s, mp)
        except Exception as e:  # record failures — they are bugs to fix
            rec = {"arch": a, "shape": s, "multi_pod": mp,
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        results[k] = rec
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if rec["status"] == "ok":
            r = rec["roofline"]
            print(f"  ok: dominant={r['dominant']} "
                  f"compute={r['compute_s']:.2e}s memory={r['memory_s']:.2e}s "
                  f"collective={r['collective_s']:.2e}s "
                  f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
                  f"fits={rec['fits_hbm']} ({rec['compile_s']}s)", flush=True)
        else:
            print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}",
                  flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)}")


if __name__ == "__main__":
    main()
