"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds (per-step):

    compute    = HLO_FLOPs_per_chip / (mfu_peak)        [197 TF/s bf16]
    memory     = HLO_bytes_per_chip / HBM_bw            [819 GB/s]
    collective = collective_bytes_per_chip / link_bw    [~50 GB/s ICI]

``compiled.cost_analysis()`` reports per-partition FLOPs/bytes (the SPMD
module is per-device).  Collective bytes are not in cost_analysis: we
parse the optimized HLO and sum the result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(post-partitioning shapes are per-device, so the sum approximates bytes
moved per chip; all-reduce is counted twice — reduce-scatter+all-gather).
"""

from __future__ import annotations

import re

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops",
           "decode_flop_split"]

HW = {
    "peak_flops": 197e12,      # bf16 / chip (TPU v5e)
    "hbm_bw": 819e9,           # bytes/s / chip
    "hbm_bytes": 16 * 2**30,   # per chip
    "link_bw": 50e9,           # bytes/s / chip ICI
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(segment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(segment):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-op byte totals from optimized (per-device) HLO."""
    out = {op: 0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, _, rhs = ls.partition("=")
        m = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+", lhs)
        if m is None:
            continue
        opm = re.match(r"\s*(?:\([^)]*\)|[\w\[\],{}:#\s]*?)\s*"
                       r"(all-gather|all-reduce|reduce-scatter|all-to-all"
                       r"|collective-permute)(?:-start)?\(", rhs)
        if opm is None:
            continue
        op = opm.group(1)
        # result shapes are on the RHS before the op name
        seg = rhs[: opm.end()]
        b = _shape_bytes(seg)
        if op == "all-reduce":
            b *= 2  # RS + AG equivalent traffic
        out[op] += b
        counts[op] += 1
    out["total"] = sum(out[o] for o in _COLLECTIVES)
    out["counts"] = counts
    return out


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float, hw: dict = HW) -> dict:
    compute = flops_per_chip / hw["peak_flops"]
    memory = bytes_per_chip / hw["hbm_bw"]
    collective = coll_bytes_per_chip / hw["link_bw"]
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    return terms


def analytic_floors(cfg, shape, n_chips: int) -> dict:
    """Analytic lower bounds on per-chip FLOPs and HBM bytes per step.

    XLA's cost_analysis counts a while-loop body ONCE, so scan-over-layers
    models under-report by ~n_layers (x grad_accum for training).  These
    closed-form floors (2ND inference / 6ND training FLOPs; one weight
    read + KV traffic for memory) recover the true scale; the reported
    roofline terms take max(HLO, floor).  Collective terms keep the HLO
    value and are flagged as per-loop-body lower bounds in EXPERIMENTS.md.
    """
    n_active = cfg.active_param_count()
    param_bytes = cfg.param_count() * 2  # bf16
    B, S = shape.global_batch, shape.seq_len
    if cfg.family in ("ssm", "hybrid"):
        kv_bpt = 0.0
    else:
        kv_bpt = (cfg.n_layers * cfg.n_kv_heads * cfg.head_dim * 2 * 2)
    mp = max(1, cfg.model_parallel)
    data_par = max(1, n_chips // mp)
    coll = 0.0
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * n_active * tokens
        # fwd + bwd weight reads + grad write + opt read/write (bf16-ish)
        mem = 4.0 * param_bytes * max(1, cfg.grad_accum) \
            + 2.0 * tokens * kv_bpt
        # collective floor: FSDP per-layer weight gathers (fwd+bwd, per
        # microbatch) + gradient reduce-scatter/all-gather
        if cfg.fsdp:
            coll += (2.0 * max(1, cfg.grad_accum) * param_bytes / mp
                     * (1.0 - 1.0 / data_par))
        coll += 2.0 * param_bytes / mp * (1.0 - 1.0 / data_par)  # grad AR
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * n_active * tokens \
            + 2.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim * B * S * S
        mem = param_bytes + tokens * kv_bpt
        # TP: one activation all-gather + one reduce per layer (per chip)
        coll += 2.0 * cfg.n_layers * (tokens / data_par) * cfg.d_model * 2
    else:  # decode: one token per sequence over the full cache
        s_cache = (cfg.window if cfg.attention_kind == "sliding_window"
                   else S)
        flops = 2.0 * n_active * B \
            + 2.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim * B * s_cache
        mem = param_bytes + B * s_cache * kv_bpt
        coll += 2.0 * cfg.n_layers * (B / data_par) * cfg.d_model * 2
    return {"flops_floor": flops / n_chips, "bytes_floor": mem / n_chips,
            "collective_floor": coll}


def model_flops(cfg, shape, n_chips: int) -> float:
    """Useful model FLOPs per step per chip: 6*N*D train, 2*N*D inference
    (N = active params for MoE)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        mult = 2.0
    return mult * n_active * tokens / n_chips


def decode_flop_split(cfg, *, tp: int, parallel: str, batch: int,
                      s_cache: int) -> dict:
    """Per-decode-step FLOP accounting split by placement: which
    component FLOPs the rule table actually divides over the mesh
    ("off-replica") vs what every device repeats ("replicated").

    This is the deterministic half of the exact-vs-efficient benchmark:
    wall-clock on a host-device testbed is noise, but the partitioner's
    placement is a pure function of the rule table, so the claim
    "efficient moves >= 2x more FLOPs off-replica than exact at tp=4"
    is assertable in CI.  ``off_replica`` is the per-device work each
    sharded component *sheds* relative to running replicated:
    component_flops * (1 - 1/tp).
    """
    from ..sharding.partitioning import decode_rule_table
    rules, report = decode_rule_table(cfg, tp, parallel=parallel)
    D, dh = cfg.d_model, cfg.head_dim
    H, KV, L = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    gate = 3 if cfg.activation == "swiglu" else 2

    # (flops per token, sharded?) per component
    comp = {
        "qkv_proj": (2.0 * D * (H + 2 * KV) * dh * L,
                     rules.get("heads") is not None),
        "wo_proj": (2.0 * H * dh * D * L,
                    rules.get("heads_out") is not None),
        # scores + weighted sum over the cache; lse-split stripes the
        # page axis, so attention compute divides even when the kv-head
        # sharding fell back
        "attention": (4.0 * H * dh * s_cache * L,
                      rules.get("pool_kv") is not None
                      or report["attention"] == "lse-split"),
        "lm_head": (2.0 * D * cfg.padded_vocab,
                    rules.get("vocab") is not None),
    }
    if cfg.family == "moe":
        moe_layers = L - cfg.first_k_dense
        routed = (2.0 * gate * D * cfg.moe_d_ff * cfg.experts_per_token
                  * moe_layers)
        shared = (2.0 * gate * D * cfg.moe_d_ff * cfg.n_shared_experts
                  * moe_layers)
        comp["moe_routed"] = (routed, rules.get("expert") is not None)
        # shared experts are a plain MLP — they follow the mlp axis
        comp["moe_shared"] = (shared, rules.get("mlp") is not None)
        if cfg.first_k_dense:
            dff = cfg.dense_d_ff or cfg.d_ff
            comp["mlp"] = (2.0 * gate * D * dff * cfg.first_k_dense,
                           rules.get("mlp") is not None)
    else:
        comp["mlp"] = (2.0 * gate * D * cfg.d_ff * L,
                       rules.get("mlp") is not None)

    total = sum(f for f, _ in comp.values()) * batch
    sharded = sum(f for f, s in comp.values() if s) * batch
    off = sharded * (1.0 - 1.0 / max(1, tp))
    return {
        "tp": tp, "parallel": parallel,
        "total_flops": total,
        "sharded_flops": sharded,
        "replicated_flops": total - sharded,
        "off_replica_flops": off,
        "per_device_flops": total - off,
        "components": {k: {"flops": f * batch, "sharded": s}
                       for k, (f, s) in comp.items()},
    }
