"""Production meshes for the multi-pod dry-run.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real single-device CPU).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate 1x1 mesh over the real local device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
