"""Production meshes for the multi-pod dry-run.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; everything else
sees the real single-device CPU).
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(*, tp: int = 1, data: int = 1):
    """(data, model) mesh over the devices this process actually has —
    accelerators or host-platform CPU devices alike (``jax.make_mesh``
    assumes the full accelerator complement and trips on dev boxes).

    Defaults to the degenerate 1x1 smoke-test mesh.  Axis sizes are
    validated against ``jax.device_count()``; on a CPU box, more host
    devices come from ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (set before jax is imported), which is how CI drives the sharded
    serving parity suite."""
    if tp < 1 or data < 1:
        raise ValueError(f"make_local_mesh: bad axis sizes data={data} "
                         f"tp={tp}")
    need = data * tp
    have = jax.device_count()
    if need > have:
        raise ValueError(
            f"make_local_mesh: data={data} x model={tp} needs {need} "
            f"devices but jax sees {have} ({jax.devices()[0].platform}); "
            "on a CPU dev box set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} before "
            "importing jax")
    devices = np.array(jax.devices()[:need]).reshape(data, tp)
    return jax.sharding.Mesh(devices, ("data", "model"))
