"""Serving launcher: run the end-to-end engine demo on any --arch
(reduced variant on CPU; on a TPU slice the same engine drives the full
config through the dry-run-proven shardings).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --policy sagesched --n-requests 12
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..configs import ARCH_IDS, get_config
from ..core import Scheduler, make_policy
from ..core.policies import POLICY_NAMES
from ..data import ByteTokenizer
from ..models import build_model
from ..serving import ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--policy", default="sagesched", choices=POLICY_NAMES)
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-seq-len", type=int, default=192)
    ap.add_argument("--step-mode", default="fused",
                    choices=("fused", "orchestrated"),
                    help="fused = one jitted device call per decode "
                         "(multi-)step; orchestrated = host-side loop")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="decode tokens per host round-trip (fused mode)")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — TPU slice required")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.family == "encdec":
        raise SystemExit("the CLI serving demo drives decoder-only archs; "
                         "see tests/test_models_smoke.py for enc-dec paths")
    tok = ByteTokenizer()
    engine = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy(args.policy)),
        n_slots=args.n_slots, max_seq_len=args.max_seq_len, seed=0,
        step_mode=args.step_mode, decode_steps=args.decode_steps)

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    reqs = []
    topics = ["summarize the report", "write a story", "explain the code",
              "translate the phrase"]
    for i in range(args.n_requests):
        prompt = f"{topics[i % len(topics)]} case {i}"
        r = ServeRequest(
            request_id=f"req-{i}", prompt=prompt,
            prompt_tokens=tok.encode(prompt)[:64],
            max_new_tokens=int(rng.integers(8, 48)),
            eos_token=tok.eos_id, arrival=t0 + i * 0.01)
        engine.submit(r)
        reqs.append(r)
    engine.run_until_done()
    print(f"arch={cfg.name} policy={args.policy} "
          f"{engine.metrics.summary(reqs)}")


if __name__ == "__main__":
    main()
