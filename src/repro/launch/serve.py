"""Serving launcher: run the end-to-end engine demo on any --arch
(reduced variant on CPU; on a TPU slice the same engine drives the full
config through the dry-run-proven shardings).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --policy sagesched --n-requests 12
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from ..configs import ARCH_IDS, get_config
from ..core import Scheduler, make_policy
from ..core.policies import POLICY_NAMES
from ..data import ByteTokenizer
from ..models import build_model
from ..serving import Gateway, GatewayConfig, ServeRequest, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--policy", default="sagesched", choices=POLICY_NAMES)
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--max-seq-len", type=int, default=192)
    ap.add_argument("--step-mode", default="fused",
                    choices=("fused", "orchestrated"),
                    help="fused = one jitted device call per decode "
                         "(multi-)step; orchestrated = host-side loop")
    ap.add_argument("--decode-steps", type=int, default=1,
                    help="decode tokens per host round-trip (fused mode)")
    ap.add_argument("--tp", type=int, default=1,
                    help="mesh-parallel width (sharded KV pool + expert "
                         "parallelism, bit-identical output — docs/"
                         "sharded_serving.md).  Needs tp devices: on a "
                         "CPU box set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--parallel", default="exact",
                    choices=("exact", "efficient"),
                    help="exact = bit-identical sharding (KV pool + "
                         "experts only); efficient = Megatron column/row-"
                         "parallel projections + vocab-sharded lm_head + "
                         "LSE-split attention, tolerance-based parity "
                         "(docs/sharded_serving.md 'Efficient mode')")
    ap.add_argument("--device-memory-gb", type=float, default=None,
                    help="per-device HBM budget for the build-time memory "
                         "preflight (refuses configs that cannot fit one "
                         "shard; default: no check)")
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — TPU slice required")
    ap.add_argument("--gateway", action="store_true",
                    help="serve through the bounded-admission gateway "
                         "(ACCEPT/QUEUE/SHED + deadlines + retries) "
                         "instead of raw submit")
    ap.add_argument("--max-inflight", type=int, default=None,
                    help="gateway in-flight cap (default 4 * n_slots)")
    ap.add_argument("--max-queue", type=int, default=64,
                    help="gateway per-tenant queue bound")
    ap.add_argument("--shed-policy", default="cost",
                    choices=("cost", "tail"),
                    help="cost = shed worst predicted-cost quantile; "
                         "tail = FCFS tail-drop")
    ap.add_argument("--ttft-deadline", type=float, default=None,
                    help="abort if first token misses this many seconds")
    ap.add_argument("--ttlt-deadline", type=float, default=None,
                    help="abort if last token misses this many seconds")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="retry budget for shed requests")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=not args.full)
    if cfg.family == "encdec":
        raise SystemExit("the CLI serving demo drives decoder-only archs; "
                         "see tests/test_models_smoke.py for enc-dec paths")
    tok = ByteTokenizer()
    engine = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy(args.policy)),
        n_slots=args.n_slots, max_seq_len=args.max_seq_len, seed=0,
        step_mode=args.step_mode, decode_steps=args.decode_steps,
        tp=args.tp, parallel=args.parallel,
        device_memory_gb=args.device_memory_gb)
    if engine.plan is not None:
        print(f"mesh: {engine.sharding_report()}")

    rng = np.random.default_rng(0)
    t0 = time.monotonic()
    reqs = []
    topics = ["summarize the report", "write a story", "explain the code",
              "translate the phrase"]
    for i in range(args.n_requests):
        prompt = f"{topics[i % len(topics)]} case {i}"
        r = ServeRequest(
            request_id=f"req-{i}", prompt=prompt,
            prompt_tokens=tok.encode(prompt)[:64],
            max_new_tokens=int(rng.integers(8, 48)),
            eos_token=tok.eos_id, arrival=t0 + i * 0.01,
            ttft_deadline_s=args.ttft_deadline,
            ttlt_deadline_s=args.ttlt_deadline)
        reqs.append(r)

    if args.gateway:
        gw = Gateway(engine, GatewayConfig(
            max_inflight=args.max_inflight,
            max_queue_per_tenant=args.max_queue,
            max_total_queue=4 * args.max_queue,
            shed_policy=args.shed_policy,
            max_retries=args.max_retries))
        verdicts = gw.offer_batch(reqs)
        gw.run_until_drained()
        counts = {v.value: verdicts.count(v) for v in set(verdicts)}
        print(f"gateway verdicts: {counts}")
    else:
        for r in reqs:
            engine.submit(r)
        engine.run_until_done()
    print(f"arch={cfg.name} policy={args.policy} "
          f"{engine.metrics.summary(reqs)}")


if __name__ == "__main__":
    main()
