"""Fault injection for the serving stack, plus post-fault invariants.

The overload/robustness story (gateway shedding, degraded prediction-free
scheduling, swap-fault recompute, node kill/slow in the cluster
simulator) is only trustworthy if every failure mode can be *provoked on
demand* and the system's invariants checked afterwards.  This module is
that provocation kit:

  * ``VirtualClock`` — an injectable monotonic clock (``ServingEngine.
    clock`` / ``Gateway``) so deadline storms and retry backoff are
    deterministic and instant in tests;
  * ``FlakyPredictor`` — wraps any ``repro.core.Predictor`` and, over a
    chosen call window, raises (``outage``), returns wildly-wrong point
    masses (``corrupt``), or replays its first answer forever
    (``stale``) — the predictor-failure modes that must push the
    scheduler into degraded prediction-free mode (flat prior, FCFS-ish)
    rather than crash admission;
  * ``inject_kv_fault`` — a context manager that makes one
    ``KVCacheManager`` instance's ``swap_in`` raise ``KVFaultError`` or
    its ``grow`` report exhaustion over a chosen call window, exercising
    the engine's recompute-on-lost-payload and pressure-relief paths;
  * ``assert_engine_quiesced`` — the post-fault invariant bundle: block
    accounting conserves exactly and every submitted request reached a
    terminal state with a recorded reason.

Node-level faults (kill / slow-down) live in the simulator itself:
``repro.simulator.NodeKill`` / ``NodeSlow`` events handed to
``simulate_cluster(..., faults=[...])``.  Overload injection lives in
the workload generator (``generate_workload(..., burst_factor=...)``).
"""

from __future__ import annotations

from contextlib import contextmanager

import numpy as np

from ..core.predictor import LengthDistribution, Predictor

__all__ = ["VirtualClock", "PredictorUnavailable", "KVFaultError",
           "FlakyPredictor", "scale_distribution", "inject_kv_fault",
           "assert_engine_quiesced"]


def scale_distribution(dist: LengthDistribution, scale: float,
                       bias: float = 0.0) -> LengthDistribution:
    """Length-scale a predicted distribution: lengths become
    ``round(length * scale + bias)`` (floored at 1); collided support
    points merge their mass.  Used by the ``drift`` fault mode and by
    the drift bench's oracle-truth construction, so both sides of the
    regret comparison transform predictions identically."""
    lens = np.maximum(
        np.round(dist.lengths * float(scale) + float(bias)), 1.0
    ).astype(np.int64)
    uniq, inv = np.unique(lens, return_inverse=True)
    probs = np.zeros(uniq.shape[0])
    np.add.at(probs, inv, dist.probs)
    return LengthDistribution(uniq, probs)


class VirtualClock:
    """A hand-advanced monotonic clock, duck-compatible with
    ``time.monotonic`` (callable returning seconds).  Inject as
    ``ServingEngine(clock=VirtualClock())`` to make TTFT/TTLT deadlines
    and gateway retry backoff deterministic."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("a monotonic clock cannot go backwards")
        self.now += float(dt)
        return self.now


class PredictorUnavailable(RuntimeError):
    """The injected predictor-outage error (timeout / dead sidecar)."""


class KVFaultError(RuntimeError):
    """The injected KV-plane error (lost swap payload, failed DMA)."""


class FlakyPredictor(Predictor):
    """Wrap ``inner`` and misbehave over calls [fail_after, fail_after +
    n_failures).  Counting is per *request* (one batched predict over a
    burst of k prompts counts k), so fault windows line up with request
    indices regardless of how callers batch.

    modes: ``outage`` raises ``PredictorUnavailable``; ``corrupt``
    returns a point mass at ``corrupt_scale *`` the true predicted mean
    (confidently, arbitrarily wrong); ``stale`` replays the first answer
    it ever produced (a stuck / delayed predictor); ``drift`` keeps
    answering confidently but with a length scale that ramps from 1x at
    the window's start to ``drift_scale`` at its end (plus an additive
    ``drift_bias`` ramping the same way) — the predictor nobody notices
    is broken, because it never throws.  Unlike the other modes, drift
    is the failure the scheduler can only detect *statistically*
    (calibration monitoring) and survive *adaptively* (posteriors,
    hedging) — see repro.core.robust.
    """

    MODES = ("outage", "corrupt", "stale", "drift")

    def __init__(self, inner: Predictor, mode: str = "outage",
                 fail_after: int = 0, n_failures: int | None = None,
                 corrupt_scale: float = 16.0, drift_scale: float = 2.0,
                 drift_bias: float = 0.0):
        if mode not in self.MODES:
            raise ValueError(f"mode must be one of {self.MODES}, got {mode!r}")
        self.inner = inner
        self.mode = mode
        self.fail_after = int(fail_after)
        self.n_failures = (float("inf") if n_failures is None
                           else int(n_failures))
        self.corrupt_scale = float(corrupt_scale)
        self.drift_scale = float(drift_scale)
        self.drift_bias = float(drift_bias)
        self.calls = 0
        self.faults = 0
        self._stale: LengthDistribution | None = None

    def _in_window(self) -> bool:
        i = self.calls
        self.calls += 1
        hit = self.fail_after <= i < self.fail_after + self.n_failures
        if hit:
            self.faults += 1
        return hit

    def predict(self, prompt: str, input_len: int) -> LengthDistribution:
        if not self._in_window():
            dist = self.inner.predict(prompt, int(input_len))
            if self._stale is None:
                self._stale = dist
            return dist
        if self.mode == "outage":
            raise PredictorUnavailable(
                f"injected predictor outage (call {self.calls - 1})")
        if self.mode == "stale" and self._stale is not None:
            return self._stale
        dist = self.inner.predict(prompt, int(input_len))
        if self.mode == "corrupt":
            wrong = max(1, int(dist.mean * self.corrupt_scale))
            return LengthDistribution(np.array([wrong], np.int64),
                                      np.array([1.0]))
        if self.mode == "drift":
            i = self.calls - 1  # _in_window already advanced the counter
            frac = 1.0 if not np.isfinite(self.n_failures) else \
                min(1.0, (i - self.fail_after + 1) / self.n_failures)
            s = 1.0 + (self.drift_scale - 1.0) * frac
            return scale_distribution(dist, s, self.drift_bias * frac)
        return dist  # stale mode before any healthy call was seen

    def predict_batch(self, prompts, input_lens):
        # loop the scalar path so the per-request fault window holds
        return [self.predict(p, int(il))
                for p, il in zip(prompts, input_lens)]

    def observe(self, prompt: str, input_len: int, output_len: int) -> None:
        self.inner.observe(prompt, input_len, output_len)


@contextmanager
def inject_kv_fault(kv, method: str = "swap_in", at_call: int = 0,
                    n_calls: int | None = None):
    """Make ONE KVCacheManager instance's ``method`` fail over calls
    [at_call, at_call + n_calls): ``grow`` reports exhaustion (returns
    False — the engine's normal memory-pressure signal), any other
    method raises ``KVFaultError`` (a lost swap payload / failed DMA —
    ``ServingEngine._admit`` recovers by dropping the payload and
    recomputing prefill).  Yields a stats dict (``calls``/``faults``);
    the instance is restored on exit even if the body raises."""
    orig = getattr(kv, method)
    lo = int(at_call)
    hi = lo + (float("inf") if n_calls is None else int(n_calls))
    stats = {"calls": 0, "faults": 0}

    def wrapper(*args, **kwargs):
        i = stats["calls"]
        stats["calls"] += 1
        if lo <= i < hi:
            stats["faults"] += 1
            if method == "grow":
                return False
            raise KVFaultError(f"injected {method} fault (call {i})")
        return orig(*args, **kwargs)

    setattr(kv, method, wrapper)
    try:
        yield stats
    finally:
        if kv.__dict__.get(method) is wrapper:
            del kv.__dict__[method]  # re-expose the bound class method


def assert_engine_quiesced(engine) -> None:
    """Post-fault invariant bundle for a drained ``ServingEngine``:

      * KV block accounting conserves exactly
        (``KVCacheManager.assert_conserved``), and the prefix index
        matches a from-scratch rebuild (``check_prefix_index``);
      * no shared-block refcount outlives its readers: with every
        request terminal, the refcount map must be empty — sharing has
        dropped back to private-only (nothing), only refcount-0 cached
        prefix blocks may remain;
      * no request is still live;
      * every non-FINISHED terminal request carries a ``finish_reason``
        (nothing vanished without an attributable cause).
    """
    engine.kv.assert_conserved()
    engine.kv.check_prefix_index()
    from ..serving.request import RequestState
    stuck = {rid: r.state.value
             for rid, r in engine._requests.items() if not r.done}
    if stuck:
        raise AssertionError(f"engine not quiesced; live requests: {stuck}")
    lingering = engine.kv.live_refcounts()
    if lingering:
        shared = {b: c for b, c in lingering.items() if c > 1}
        raise AssertionError(
            "blocks still referenced after every request reached a "
            f"terminal state: {lingering} (shared: {shared})")
    unexplained = [
        rid for rid, r in engine._requests.items()
        if r.state in (RequestState.ABORTED, RequestState.SHED)
        and not r.finish_reason]
    if unexplained:
        raise AssertionError(
            f"terminal requests without a finish_reason: {unexplained}")
