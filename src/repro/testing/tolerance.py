"""Tolerance contract for parallel="efficient" serving parity.

Exact mode's contract is trivial: token streams are bit-identical to
the single-device engine.  Efficient mode reorders float contractions
(row-parallel psums, vocab-sharded reductions, LSE-combined attention
stripes), so its contract is statistical: last-ulp logit drift may flip
a token exactly where the sampling decision was already a coin toss —
two logits within one ulp of each other, or a categorical draw landing
within one ulp of a CDF boundary.  MoE amplifies this (a flipped
routing pick swaps whole expert FFNs), which is why the bar is a match
*rate* over long decodes, not a per-token guarantee.

``assert_tokens_close`` is that contract, shared by the parity tests,
the benchmark harness, and anyone wiring a new mesh layout: streams
must agree position-by-position at >= ``min_match_rate`` (0.999), and
any divergence must be *suffix* drift — once one token flips, the
autoregressive state differs and all later mismatches are expected, so
only the first divergence point per stream is charged against the
rate.  ``bit_identical=True`` restores the exact-mode contract (used
at tp=1, where efficient mode degenerates to no resharding at all).
"""

from __future__ import annotations

import numpy as np

__all__ = ["assert_tokens_close", "TokenMismatch"]


class TokenMismatch(AssertionError):
    """Raised with the per-stream divergence diagnostics attached."""

    def __init__(self, msg, mismatches):
        super().__init__(msg)
        self.mismatches = mismatches


def _first_divergence(got, want):
    """Index of the first differing position, or None if equal (the
    shorter stream's early stop counts as a divergence at its end)."""
    n = min(len(got), len(want))
    for i in range(n):
        if got[i] != want[i]:
            return i
    if len(got) != len(want):
        return n
    return None


def assert_tokens_close(got, want, *, min_match_rate: float = 0.999,
                        bit_identical: bool = False,
                        logits=None, ref_logits=None,
                        max_logit_diff: float = 5e-2,
                        label: str = "") -> dict:
    """Check generated token streams against a reference.

    got/want: sequence of streams (each a sequence of token ids), or a
    single stream of ints.  Returns a stats dict (matched, compared,
    rate, divergences) on success so callers can log the margin.

    The rate counts positions up to each stream's first divergence:
    autoregressive drift past a flip is not independent evidence.  With
    ``bit_identical=True`` any divergence fails.  When ``logits`` /
    ``ref_logits`` are given (arrays of matching shape), their max
    abs diff must stay under ``max_logit_diff`` — catching layouts that
    are only agreeing by sampling luck.
    """
    if got and isinstance(got[0], (int, np.integer)):
        got, want = [got], [want]
    if len(got) != len(want):
        raise TokenMismatch(
            f"{label}: {len(got)} streams vs {len(want)} reference "
            "streams", [])

    matched = compared = 0
    mismatches = []
    for si, (g, w) in enumerate(zip(got, want)):
        g, w = list(g), list(w)
        d = _first_divergence(g, w)
        if d is None:
            matched += len(w)
            compared += len(w)
        else:
            matched += d
            compared += d + 1   # charge exactly the flip position
            mismatches.append(
                {"stream": si, "pos": d,
                 "got": g[d] if d < len(g) else None,
                 "want": w[d] if d < len(w) else None})
    if bit_identical and mismatches:
        raise TokenMismatch(
            f"{label}: expected bit-identical streams, "
            f"{len(mismatches)} diverged (first: {mismatches[0]})",
            mismatches)
    rate = matched / compared if compared else 1.0
    if rate < min_match_rate:
        raise TokenMismatch(
            f"{label}: greedy/sampled match rate {rate:.4f} < "
            f"{min_match_rate} ({matched}/{compared} positions; "
            f"first divergences: {mismatches[:4]})", mismatches)

    stats = {"matched": matched, "compared": compared, "rate": rate,
             "divergences": len(mismatches)}
    if logits is not None and ref_logits is not None:
        diff = float(np.max(np.abs(
            np.asarray(logits, np.float32)
            - np.asarray(ref_logits, np.float32))))
        stats["max_logit_diff"] = diff
        if diff > max_logit_diff:
            raise TokenMismatch(
                f"{label}: max logit drift {diff:.3e} > "
                f"{max_logit_diff:.3e} — the layout is numerically "
                "wrong, not just reordered", mismatches)
    return stats
