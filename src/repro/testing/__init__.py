"""Reusable fault-injection + invariant-checking harness (repro.testing).

Everything here is production-importable (no pytest dependency): the
benchmark harness drives the same fault matrix CI asserts on.
"""

from .faults import (FlakyPredictor, KVFaultError, PredictorUnavailable,
                     VirtualClock, assert_engine_quiesced, inject_kv_fault,
                     scale_distribution)
from .tolerance import TokenMismatch, assert_tokens_close

__all__ = ["FlakyPredictor", "KVFaultError", "PredictorUnavailable",
           "TokenMismatch", "VirtualClock", "assert_engine_quiesced",
           "assert_tokens_close", "inject_kv_fault", "scale_distribution"]
