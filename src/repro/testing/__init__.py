"""Reusable fault-injection + invariant-checking harness (repro.testing).

Everything here is production-importable (no pytest dependency): the
benchmark harness drives the same fault matrix CI asserts on.
"""

from .faults import (FlakyPredictor, KVFaultError, PredictorUnavailable,
                     VirtualClock, assert_engine_quiesced, inject_kv_fault)

__all__ = ["FlakyPredictor", "KVFaultError", "PredictorUnavailable",
           "VirtualClock", "assert_engine_quiesced", "inject_kv_fault"]
