"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch, n_chunks) — chunks innermost so the (H, P, N) inter-chunk
state lives in VMEM scratch and is carried across sequential grid steps.
Within a chunk everything is matmuls (MXU): the quadratic intra-chunk
term, the state read-out, and the state update — the state-space-duality
insight mapped directly onto TPU tiling (DESIGN.md hardware adaptation:
this replaces the CUDA kernel's warp-level parallel scan with a
chunked-matmul formulation, which is how SSD is *meant* to run on matrix
units).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
            chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0].astype(jnp.float32)       # (q, H, P)
    dt = dt_ref[0].astype(jnp.float32)     # (q, H)
    a = a_ref[0].astype(jnp.float32)       # (q, H)
    bm = b_ref[0].astype(jnp.float32)      # (q, N)
    cm = c_ref[0].astype(jnp.float32)      # (q, N)
    q = chunk

    la = jnp.log(jnp.maximum(a, 1e-20))    # (q, H)
    cum = jnp.cumsum(la, axis=0)           # (q, H)
    seg = cum[:, None, :] - cum[None, :, :]            # (q, q, H)
    causal = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(causal[:, :, None], jnp.exp(seg), 0.0)   # (q,q,H)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (q,q)
    w = scores[:, :, None] * lmat                       # (q, q, H)
    xdt = x * dt[:, :, None]                            # (q, H, P)
    # y_intra[i,h,p] = sum_j w[i,j,h] * xdt[j,h,p]
    y_intra = jnp.einsum("ijh,jhp->ihp", w, xdt)
    # carried-in state contribution
    state = state_scr[...]                              # (H, P, N)
    decay_in = jnp.exp(cum)                             # (q, H)
    y_inter = jnp.einsum("in,hpn,ih->ihp", cm, state, decay_in)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    decay_out = jnp.exp(cum[-1:, :] - cum)              # (q, H)
    dstate = jnp.einsum("jn,jhp,jh->hpn", bm, xdt, decay_out)
    total = jnp.exp(cum[-1, :])                         # (H,)
    state_scr[...] = state * total[:, None, None] + dstate


def ssd_scan_kernel(x, dt, a_decay, bmat, cmat, *, chunk: int = 256,
                    interpret: bool = False):
    """x: (B, S, H, P); dt, a_decay: (B, S, H); bmat/cmat: (B, S, N).
    S must be a multiple of ``chunk``.  Returns y: (B, S, H, P)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk

    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, h), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_decay, bmat, cmat)
