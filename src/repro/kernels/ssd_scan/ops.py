"""jit'd wrapper for the SSD scan kernel (ref fallback off-TPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_kernel
from .ref import ssd_reference

__all__ = ["ssd_scan_op"]


@functools.partial(jax.jit, static_argnames=("chunk", "force_pallas"))
def ssd_scan_op(x, dt, a_decay, bmat, cmat, *, chunk: int = 256,
                force_pallas: bool = False):
    native = jax.default_backend() == "tpu"
    if not native and not force_pallas:
        return ssd_reference(x, dt, a_decay, bmat, cmat)
    s = x.shape[1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a_decay = jnp.pad(a_decay, ((0, 0), (0, pad), (0, 0)),
                          constant_values=1.0)
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    y = ssd_scan_kernel(x, dt, a_decay, bmat, cmat, chunk=q,
                        interpret=not native)
    return y[:, :s] if pad else y
