"""Pure-jnp oracle for the SSD scan: the naive sequential recurrence.

Deliberately a *different algorithm* than the chunked kernel (step-by-step
state recurrence vs chunked matmul duality) so agreement validates the
math, not just the transcription.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_reference"]


def ssd_reference(x, dt, a_decay, bmat, cmat):
    """x: (B,S,H,P); dt/a_decay: (B,S,H); bmat/cmat: (B,S,N) -> (B,S,H,P)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]

    def step(state, xs):
        xt, dtt, at, bt, ct = xs
        xdt = xt.astype(jnp.float32) * dtt.astype(jnp.float32)[..., None]
        outer = jnp.einsum("bhp,bn->bhpn", xdt, bt.astype(jnp.float32))
        state = state * at.astype(jnp.float32)[..., None, None] + outer
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(a_decay, 1, 0), jnp.moveaxis(bmat, 1, 0),
          jnp.moveaxis(cmat, 1, 0))
    _, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
