"""Pure-jnp oracle for the flash-decode kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["decode_attention_reference"]


def decode_attention_reference(q, k_cache, v_cache, cache_len, *,
                               window: int = 0):
    """q: (B, H, dh); k_cache/v_cache: (B, S_max, KV, dh); cache_len: (B,).
    Returns (B, H, dh)."""
    b, h, dh = q.shape
    s_max, kv = k_cache.shape[1], k_cache.shape[2]
    rep = h // kv
    k = jnp.repeat(k_cache, rep, axis=2)            # (B, S, H, dh)
    v = jnp.repeat(v_cache, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh ** -0.5
    idx = jnp.arange(s_max)
    valid = idx[None, :] < cache_len[:, None]
    if window > 0:
        valid = valid | (cache_len[:, None] >= s_max)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
