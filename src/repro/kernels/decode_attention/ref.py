"""Pure-jnp oracle for the flash-decode kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["decode_attention_reference", "decode_attention_paged_reference",
           "decode_attention_paged_lse_reference"]


def decode_attention_reference(q, k_cache, v_cache, cache_len, *,
                               window: int = 0):
    """q: (B, H, dh); k_cache/v_cache: (B, S_max, KV, dh); cache_len: (B,).
    Returns (B, H, dh)."""
    b, h, dh = q.shape
    s_max, kv = k_cache.shape[1], k_cache.shape[2]
    rep = h // kv
    k = jnp.repeat(k_cache, rep, axis=2)            # (B, S, H, dh)
    v = jnp.repeat(v_cache, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh ** -0.5
    idx = jnp.arange(s_max)
    valid = idx[None, :] < cache_len[:, None]
    if window > 0:
        valid = valid | (cache_len[:, None] >= s_max)
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_paged_reference(q, k_pool, v_pool, block_tables,
                                     cache_len, *, window: int = 0):
    """Paged oracle: gather each row's logical cache through its block
    table, then run the dense decode reference.

    q: (B, H, dh); k_pool/v_pool: (n_pages, page, KV, dh);
    block_tables: (B, P) int32; cache_len: (B,).  ``window`` is a logical
    sliding window (no ring wrap — paged caches keep all blocks).
    Returns (B, H, dh)."""
    b, h, dh = q.shape
    n_pages, page, kv, _ = k_pool.shape
    p_max = block_tables.shape[1]
    s_log = p_max * page
    tok = (block_tables.astype(jnp.int32) * page)[:, :, None] \
        + jnp.arange(page, dtype=jnp.int32)[None, None, :]
    tok = tok.reshape(b, s_log)
    k = k_pool.reshape(n_pages * page, kv, dh)[tok]
    v = v_pool.reshape(n_pages * page, kv, dh)[tok]
    rep = h // kv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * dh ** -0.5
    idx = jnp.arange(s_log)
    valid = idx[None, :] < cache_len[:, None]
    if window > 0:
        valid &= idx[None, :] >= cache_len[:, None] - window
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhs,bshd->bhd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_paged_lse_reference(q, k_pool, v_pool, block_tables,
                                         cache_len, *, window: int = 0):
    """(out, lse) oracle: the paged reference plus the f32 log-sum-exp
    of the masked scores, matching the conventions of
    ``models.attention.combine_lse_partials`` (a fully-masked call
    yields lse ≈ -1e30 so its merge weight is exactly 0)."""
    b, h, dh = q.shape
    n_pages, page, kv, _ = k_pool.shape
    p_max = block_tables.shape[1]
    s_log = p_max * page
    tok = (block_tables.astype(jnp.int32) * page)[:, :, None] \
        + jnp.arange(page, dtype=jnp.int32)[None, None, :]
    tok = tok.reshape(b, s_log)
    k = k_pool.reshape(n_pages * page, kv, dh)[tok]
    v = v_pool.reshape(n_pages * page, kv, dh)[tok]
    rep = h // kv
    kk = jnp.repeat(k, rep, axis=2)
    vv = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * dh ** -0.5
    idx = jnp.arange(s_log)
    valid = idx[None, :] < cache_len[:, None]
    if window > 0:
        valid &= idx[None, :] >= cache_len[:, None] - window
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    m = scores.max(-1)                              # (B, H)
    p = jnp.exp(scores - m[..., None])
    l = jnp.maximum(p.sum(-1), 1e-30)
    out = jnp.einsum("bhs,bshd->bhd", p / l[..., None],
                     vv.astype(jnp.float32))
    return out.astype(q.dtype), m + jnp.log(l)
