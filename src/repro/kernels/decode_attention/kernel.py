"""Pallas TPU flash-decode kernel: one-token attention over a KV cache.

Grid: (batch, kv_blocks) — the KV sequence is partitioned and partial
softmax statistics (m, l, acc) are combined across blocks in VMEM scratch
via the log-sum-exp trick.  This is the TPU-idiomatic analogue of
PagedAttention v2's split-KV reduction (DESIGN.md hardware adaptation):
no warp shuffles, just a sequential grid axis with running renormalization.

The per-request valid length arrives as a scalar-prefetch operand in SMEM,
so masking is dynamic per batch row (continuous batching: every request
has its own cache fill level).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_kernel", "decode_attention_paged_kernel",
           "decode_attention_paged_lse_kernel"]

_NEG = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_s: int, n_blocks: int, kv_heads: int,
            rep: int, window: int, s_max: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # (H, dh)
    k = k_ref[0]                                    # (block_s, KV, dh)
    v = v_ref[0]
    h, dh = q.shape
    qg = q.reshape(kv_heads, rep, dh)
    # scores: (KV, rep, block_s)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale

    valid_len = len_ref[0]
    pos = si * block_s + jax.lax.broadcasted_iota(
        jnp.int32, (kv_heads, rep, block_s), 2)
    mask = pos < valid_len
    if window > 0:
        # ring buffer: once wrapped, every slot is within the window
        mask = mask | (valid_len >= s_max)
    s = jnp.where(mask, s, _NEG)

    sf = s.reshape(h, block_s)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, sf.max(axis=1))
    p = jnp.exp(sf - m_new[:, None])                # (H, block_s)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(
        p.reshape(kv_heads, rep, block_s).astype(v.dtype), v,
        (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)         # (KV, rep, dh)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv.reshape(h, dh)
    m_scr[...] = m_new

    @pl.when(si == n_blocks - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, cache_len, *,
                            window: int = 0, block_s: int = 512,
                            interpret: bool = False):
    """q: (B, H, dh); k_cache/v_cache: (B, S_max, KV, dh);
    cache_len: (B,) int32 valid lengths.  Returns (B, H, dh)."""
    b, h, dh = q.shape
    _, s_max, kv, _ = k_cache.shape
    rep = h // kv
    assert s_max % block_s == 0, (s_max, block_s)
    n_blocks = s_max // block_s
    scale = dh ** -0.5

    kernel = functools.partial(
        _kernel, scale=scale, block_s=block_s, n_blocks=n_blocks,
        kv_heads=kv, rep=rep, window=window, s_max=s_max)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b, n_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, si: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
            pl.BlockSpec((1, block_s, kv, dh), lambda bi, si: (bi, si, 0, 0)),
            pl.BlockSpec((1, block_s, kv, dh), lambda bi, si: (bi, si, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh), lambda bi, si: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(cache_len, q, k_cache, v_cache)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page: int,
                  n_blocks: int, kv_heads: int, rep: int, window: int):
    """Paged variant: the grid walks *logical* pages of each sequence; the
    physical page is resolved by the BlockSpec index maps through the
    scalar-prefetched block table, so the kernel body only ever sees one
    (page, KV, dh) tile — PagedAttention's indirection without gather."""
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # (H, dh)
    k = k_ref[0]                                    # (page, KV, dh)
    v = v_ref[0]
    h, dh = q.shape
    qg = q.reshape(kv_heads, rep, dh)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale  # (KV, rep, page)

    # scalar-prefetch operands are whole-array SMEM refs; pick this row
    valid_len = len_ref[pl.program_id(0)]
    pos = si * page + jax.lax.broadcasted_iota(
        jnp.int32, (kv_heads, rep, page), 2)
    mask = pos < valid_len
    if window > 0:
        # logical sliding window: no ring wrap in a paged pool
        mask = mask & (pos >= valid_len - window)
    s = jnp.where(mask, s, _NEG)

    sf = s.reshape(h, page)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, sf.max(axis=1))
    p = jnp.exp(sf - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(
        p.reshape(kv_heads, rep, page).astype(v.dtype), v,
        (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv.reshape(h, dh)
    m_scr[...] = m_new

    @pl.when(si == n_blocks - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _paged_kernel_lse(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                      m_scr, l_scr, acc_scr, *, scale: float, page: int,
                      n_blocks: int, kv_heads: int, rep: int, window: int):
    """``_paged_kernel`` flushing flash-style partials instead of a
    finished output: o = acc / l (normalized over THIS kernel's pages)
    plus lse = m + log(l), so a mesh that stripes the logical page axis
    across shards can run this kernel per stripe and merge the partials
    with ``models.attention.combine_lse_partials`` — PagedAttention
    v2's cross-partition reduction, hoisted out of the kernel and into
    the (GSPMD-collective) merge."""
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # (H, dh)
    k = k_ref[0]                                    # (page, KV, dh)
    v = v_ref[0]
    h, dh = q.shape
    qg = q.reshape(kv_heads, rep, dh)
    s = jax.lax.dot_general(
        qg, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale  # (KV, rep, page)

    valid_len = len_ref[pl.program_id(0)]
    pos = si * page + jax.lax.broadcasted_iota(
        jnp.int32, (kv_heads, rep, page), 2)
    mask = pos < valid_len
    if window > 0:
        mask = mask & (pos >= valid_len - window)
    s = jnp.where(mask, s, _NEG)

    sf = s.reshape(h, page)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, sf.max(axis=1))
    p = jnp.exp(sf - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    pv = jax.lax.dot_general(
        p.reshape(kv_heads, rep, page).astype(v.dtype), v,
        (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv.reshape(h, dh)
    m_scr[...] = m_new

    @pl.when(si == n_blocks - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)
        # lse = m + log(l): exactly -inf-ish (_NEG + log(1e-30)) for a
        # fully-masked stripe, so its merge weight underflows to 0
        lse_ref[0] = (m_scr[...] + jnp.log(l)).astype(lse_ref.dtype)


def decode_attention_paged_kernel(q, k_pool, v_pool, block_tables,
                                  cache_len, *, window: int = 0,
                                  interpret: bool = False):
    """q: (B, H, dh); k_pool/v_pool: (n_pages, page, KV, dh) shared pool;
    block_tables: (B, P) int32 physical-page ids; cache_len: (B,) int32.
    Returns (B, H, dh)."""
    b, h, dh = q.shape
    n_pages, page, kv, _ = k_pool.shape
    p_max = block_tables.shape[1]
    rep = h // kv
    scale = dh ** -0.5

    kernel = functools.partial(
        _paged_kernel, scale=scale, page=page, n_blocks=p_max,
        kv_heads=kv, rep=rep, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # block_tables, cache_len
        grid=(b, p_max),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda bi, si, bt, cl: (bi, 0, 0)),
            pl.BlockSpec((1, page, kv, dh),
                         lambda bi, si, bt, cl: (bt[bi, si], 0, 0, 0)),
            pl.BlockSpec((1, page, kv, dh),
                         lambda bi, si, bt, cl: (bt[bi, si], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, dh),
                               lambda bi, si, bt, cl: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), cache_len.astype(jnp.int32),
      q, k_pool, v_pool)


def decode_attention_paged_lse_kernel(q, k_pool, v_pool, block_tables,
                                      cache_len, *, window: int = 0,
                                      interpret: bool = False):
    """Partial-softmax paged decode: same operands as
    ``decode_attention_paged_kernel`` but returns ``(out, lse)`` with
    out (B, H, dh) normalized over only the pages this call saw and
    lse (B, H) f32 log-sum-exp — the flash-style partial that
    ``models.attention.combine_lse_partials`` merges across KV stripes
    when the page axis is sharded over the mesh."""
    b, h, dh = q.shape
    n_pages, page, kv, _ = k_pool.shape
    p_max = block_tables.shape[1]
    rep = h // kv
    scale = dh ** -0.5

    kernel = functools.partial(
        _paged_kernel_lse, scale=scale, page=page, n_blocks=p_max,
        kv_heads=kv, rep=rep, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # block_tables, cache_len
        grid=(b, p_max),
        in_specs=[
            pl.BlockSpec((1, h, dh), lambda bi, si, bt, cl: (bi, 0, 0)),
            pl.BlockSpec((1, page, kv, dh),
                         lambda bi, si, bt, cl: (bt[bi, si], 0, 0, 0)),
            pl.BlockSpec((1, page, kv, dh),
                         lambda bi, si, bt, cl: (bt[bi, si], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, dh), lambda bi, si, bt, cl: (bi, 0, 0)),
            pl.BlockSpec((1, h), lambda bi, si, bt, cl: (bi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h,), jnp.float32),
            pltpu.VMEM((h, dh), jnp.float32),
        ],
    )

    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, dh), q.dtype),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables.astype(jnp.int32), cache_len.astype(jnp.int32),
      q, k_pool, v_pool)
