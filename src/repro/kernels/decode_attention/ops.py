"""jit'd wrapper for the flash-decode kernel (ref fallback off-TPU)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..bucketing import pow2_bucket
from .kernel import (decode_attention_kernel, decode_attention_paged_kernel,
                     decode_attention_paged_lse_kernel)
from .ref import (decode_attention_paged_lse_reference,
                  decode_attention_paged_reference,
                  decode_attention_reference)

__all__ = ["decode_attention_op", "decode_attention_paged_op",
           "decode_attention_paged_lse_op"]


@functools.partial(jax.jit, static_argnames=("window", "block_s",
                                             "force_pallas"))
def decode_attention_op(q, k_cache, v_cache, cache_len, *, window: int = 0,
                        block_s: int = 512, force_pallas: bool = False):
    """q: (B, H, dh); caches (B, S_max, KV, dh); cache_len (B,)."""
    native = jax.default_backend() == "tpu"
    if not native and not force_pallas:
        return decode_attention_reference(q, k_cache, v_cache, cache_len,
                                          window=window)
    s_max = k_cache.shape[1]
    blk = min(block_s, s_max)
    pad = (-s_max) % blk
    if pad and window > 0:
        raise ValueError("ring-buffer (window) caches must be a multiple of "
                         "block_s — padding would corrupt wrap masking")
    if pad:
        cfg = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, cfg)
        v_cache = jnp.pad(v_cache, cfg)
    return decode_attention_kernel(
        q, k_cache, v_cache, cache_len.astype(jnp.int32),
        window=window, block_s=blk, interpret=not native)


@functools.partial(jax.jit, static_argnames=("window", "force_pallas"))
def decode_attention_paged_op(q, k_pool, v_pool, block_tables, cache_len, *,
                              window: int = 0, force_pallas: bool = False):
    """Paged flash-decode: q (B, H, dh); pools (n_pages, page, KV, dh);
    block_tables (B, P) int32; cache_len (B,).  The kernel's KV grid step
    is the page itself — block tables replace any padding logic.

    The logical-page axis is padded to a pow2 bucket before the kernel
    call: the padded table entries point at physical page 0 (the serving
    engine's scratch page) and sit past every row's ``cache_len``, so
    they are masked out — the kernel's grid/index-map signature stays on
    the bounded bucket ladder no matter how callers size their tables.

    Per-shard invariant (docs/sharded_serving.md): KV is a pure batch
    dim here — the kernel never contracts or reduces over it — so a
    mesh that splits the pool over kv-heads runs this exact kernel on
    per-shard pool slices with an unchanged grid; the block tables it
    indexes with are global and shard-invariant."""
    native = jax.default_backend() == "tpu"
    if not native and not force_pallas:
        return decode_attention_paged_reference(
            q, k_pool, v_pool, block_tables, cache_len, window=window)
    p_max = block_tables.shape[1]
    pb = pow2_bucket(p_max)
    if pb != p_max:
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pb - p_max)))
    return decode_attention_paged_kernel(
        q, k_pool, v_pool, block_tables.astype(jnp.int32),
        cache_len.astype(jnp.int32), window=window, interpret=not native)


@functools.partial(jax.jit, static_argnames=("window", "force_pallas"))
def decode_attention_paged_lse_op(q, k_pool, v_pool, block_tables,
                                  cache_len, *, window: int = 0,
                                  force_pallas: bool = False):
    """Partial paged flash-decode returning ``(out, lse)``.

    Same operands and padding contract as ``decode_attention_paged_op``,
    but ``out`` is normalized over only the pages reachable through THIS
    call's block tables and ``lse`` (B, H) f32 is their log-sum-exp.
    This is the per-stripe building block for LSE-combined sharded
    attention: when kv heads don't divide the mesh, each shard runs this
    op over its stripe of the logical page axis and the partials merge
    exactly via ``models.attention.combine_lse_partials`` — the same
    split-KV reduction the kernel already does across its grid, lifted
    one level up so GSPMD can place the final combine as a collective."""
    native = jax.default_backend() == "tpu"
    if not native and not force_pallas:
        return decode_attention_paged_lse_reference(
            q, k_pool, v_pool, block_tables, cache_len, window=window)
    p_max = block_tables.shape[1]
    pb = pow2_bucket(p_max)
    if pb != p_max:
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pb - p_max)))
    return decode_attention_paged_lse_kernel(
        q, k_pool, v_pool, block_tables.astype(jnp.int32),
        cache_len.astype(jnp.int32), window=window, interpret=not native)
