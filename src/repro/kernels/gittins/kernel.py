"""Pallas TPU kernel: batched Gittins indices over bucketized cost
distributions.

At cluster scale (paper Fig. 12: 64 nodes x 8 RPS with a 1000-deep queue
and ~queue/10 refreshes per arrival) the scheduler evaluates thousands of
Gittins indices per second; this kernel computes a whole batch in one
VMEM-resident pass: two prefix sums + a running min along the bucket axis.

Grid: (n_blocks,) over the request batch; each block holds (block_n, k)
support/prob tiles in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gittins_kernel"]


def _kernel(support_ref, probs_ref, out_ref):
    c = support_ref[...].astype(jnp.float32)       # (bn, k)
    p = probs_ref[...].astype(jnp.float32)
    valid = p > 0.0
    # zero dead columns BEFORE multiplying: padded support may be huge
    # (or even +inf), and inf * 0 would poison the cumsum with NaN
    cz = jnp.where(valid, c, 0.0)
    mass = jnp.cumsum(p, axis=1)                   # P(X <= c_j)
    spent = jnp.cumsum(cz * p, axis=1)             # E[X ; X <= c_j]
    num = spent + cz * (1.0 - mass)                # E[min(X, c_j)]
    ratio = jnp.where(valid & (mass > 1e-12),
                      num / jnp.maximum(mass, 1e-12), jnp.inf)
    out_ref[...] = ratio.min(axis=1)


def gittins_kernel(support, probs, *, block_n: int = 256,
                   interpret: bool = False):
    """support/probs: (n, k) float32 (rows non-decreasing in support;
    padded entries must carry prob 0 — any support value is tolerated
    there, including +inf, but prefer a large finite pad as ops.py
    does).  Returns (n,)."""
    n, k = support.shape
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        support = jnp.pad(support, ((0, pad), (0, 0)),
                          constant_values=1.0)
        probs = jnp.pad(probs, ((0, pad), (0, 0)))
        probs = probs.at[n:, 0].set(1.0)  # harmless rows
    blocks = (n + pad) // bn

    out = pl.pallas_call(
        _kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        interpret=interpret,
    )(support, probs)
    return out[:n]
