"""jit'd wrapper for the batched Gittins kernel."""

from __future__ import annotations

import functools

import jax

from .kernel import gittins_kernel
from .ref import gittins_reference

__all__ = ["gittins_op"]


@functools.partial(jax.jit, static_argnames=("block_n", "force_pallas"))
def gittins_op(support, probs, *, block_n: int = 256,
               force_pallas: bool = False):
    native = jax.default_backend() == "tpu"
    if not native and not force_pallas:
        return gittins_reference(support, probs)
    return gittins_kernel(support, probs, block_n=block_n,
                          interpret=not native)
