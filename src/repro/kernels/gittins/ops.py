"""jit'd wrappers for the batched Gittins kernel.

Two entry points:

  * ``gittins_op``          — plain batched indices over (n, k) rows,
    API-compatible with the numpy oracle ``gittins_index_batch(s, p)``.
  * ``gittins_attained_op`` — the scheduler hot-path op: conditions each
    row on X > attained (the paper's runtime Bayesian refresh) entirely
    in jnp, then runs the Pallas kernel.  Inputs are padded to
    power-of-two batch sizes before entering the jitted function, so a
    scheduler whose queue breathes between, say, 900 and 1000 requests
    compiles exactly once (for n=1024) instead of on every queue-depth
    change — the "persistent padding" that makes jit viable in a
    decision loop.

Ragged rows must be padded with prob 0; this module pads support with a
large *finite* value (``PAD_SUPPORT``) — never +inf, whose product with
a zero probability would poison the kernel's cumsum with NaN (the kernel
also guards against it defensively).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import gittins_kernel
from .ref import gittins_reference

__all__ = ["gittins_op", "gittins_attained_op", "PAD_SUPPORT"]

# large finite pad for ragged support rows: big enough to sit above any
# real cost, small enough that float32 products with ~1 stay finite
PAD_SUPPORT = 1e30


def _next_pow2(n: int) -> int:
    p = 8
    while p < n:
        p *= 2
    return p


@functools.partial(jax.jit, static_argnames=("block_n", "force_pallas"))
def gittins_op(support, probs, *, block_n: int = 256,
               force_pallas: bool = False):
    native = jax.default_backend() == "tpu"
    if not native and not force_pallas:
        return gittins_reference(support, probs)
    return gittins_kernel(support, probs, block_n=block_n,
                          interpret=not native)


@functools.partial(jax.jit, static_argnames=("block_n", "force_pallas"))
def _attained_op(support, probs, attained, *, block_n: int,
                 force_pallas: bool):
    """Condition rows on X > attained, re-origin, and evaluate.  Mirrors
    repro.core.gittins._condition_batch in float32/jnp."""
    c = support.astype(jnp.float32)
    p = probs.astype(jnp.float32)
    att = jnp.maximum(attained.astype(jnp.float32), 0.0)
    valid = p > 0.0
    cond = (att > 0.0)[:, None]
    alive = valid & (~cond | (c > att[:, None]))
    pa = jnp.where(alive, p, 0.0)
    psum = jnp.sum(pa, axis=1)
    exhausted = cond[:, 0] & (psum <= 0.0)
    safe = jnp.where(psum > 0.0, psum, 1.0)
    pn = jnp.where(cond, pa / safe[:, None], pa)
    # dead columns get the finite pad support: keeps the kernel NaN-free
    # and (with prob 0) exactly inert
    cr = jnp.where(alive, c - att[:, None] * cond, PAD_SUPPORT)
    idx = gittins_op(cr, pn, block_n=block_n, force_pallas=force_pallas)
    tail = jnp.maximum(jnp.max(jnp.where(valid, c, -jnp.inf), axis=1), 1.0)
    return jnp.where(exhausted, tail, idx)


def gittins_attained_op(support, probs, attained=None, *, block_n: int = 256,
                        force_pallas: bool = False):
    """Scheduler-facing batched Gittins evaluation.

    support/probs: (n, k) bucketized rows (padded entries prob 0).
    attained: optional (n,) consumed cost per row.
    Accepts numpy or jax arrays; returns a (n,) jax array.  The batch is
    padded to the next power of two with harmless rows before the jitted
    computation, so compilation is persistent across queue-depth jitter.
    """
    support = np.asarray(support, np.float32)
    probs = np.asarray(probs, np.float32)
    n, k = support.shape
    if attained is None:
        attained = np.zeros(n, np.float32)
    attained = np.asarray(attained, np.float32)
    n2 = _next_pow2(n)
    if n2 != n:
        pad = n2 - n
        support = np.pad(support, ((0, pad), (0, 0)),
                         constant_values=PAD_SUPPORT)
        support[n:, 0] = 1.0
        probs = np.pad(probs, ((0, pad), (0, 0)))
        probs[n:, 0] = 1.0          # harmless unit-mass rows
        attained = np.pad(attained, (0, pad))
    out = _attained_op(jnp.asarray(support), jnp.asarray(probs),
                       jnp.asarray(attained), block_n=block_n,
                       force_pallas=force_pallas)
    return out[:n]
