"""Pure-jnp oracle for the batched Gittins kernel (mirrors
repro.core.gittins.gittins_index_batch, the numpy ground truth)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gittins_reference"]


def gittins_reference(support, probs):
    """support/probs: (n, k) -> (n,) Gittins indices."""
    c = support.astype(jnp.float32)
    p = probs.astype(jnp.float32)
    mass = jnp.cumsum(p, axis=1)
    spent = jnp.cumsum(c * p, axis=1)
    num = spent + c * (1.0 - mass)
    ratio = jnp.where(mass > 1e-12, num / jnp.maximum(mass, 1e-12), jnp.inf)
    return ratio.min(axis=1)
