"""Pure-jnp oracle for the batched Gittins kernel (mirrors
repro.core.gittins.gittins_index_batch, the numpy ground truth)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gittins_reference"]


def gittins_reference(support, probs):
    """support/probs: (n, k) -> (n,) Gittins indices.  Padded entries
    (prob 0) are masked out, so any finite-or-inf pad support is safe."""
    c = support.astype(jnp.float32)
    p = probs.astype(jnp.float32)
    valid = p > 0.0
    cz = jnp.where(valid, c, 0.0)
    mass = jnp.cumsum(p, axis=1)
    spent = jnp.cumsum(cz * p, axis=1)
    num = spent + cz * (1.0 - mass)
    ratio = jnp.where(valid & (mass > 1e-12),
                      num / jnp.maximum(mass, 1e-12), jnp.inf)
    return ratio.min(axis=1)
