"""Pallas TPU flash-attention (prefill) kernel.

Grid: (batch*heads, q_blocks, kv_blocks) — kv_blocks innermost, so the
online-softmax running state (m, l, acc) lives in VMEM scratch and
persists across the sequential TPU grid steps.  Block shapes are
MXU-aligned (multiples of 128 on the sequence dims; head_dim is the lane
dim).  GQA is handled in the BlockSpec index maps: the K/V operands keep
their (B*KV, S, dh) layout and each query head reads its group's KV head —
no materialized head repetition in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, n_kv_blocks: int,
            seq_len: int, causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, dh)
    k = k_ref[0]                                   # (bk, dh)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # (bq, bk)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len                         # padding
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _flush():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           seq_len: int | None = None,
                           interpret: bool = False):
    """q: (BH, S, dh); k, v: (BKV, S, dh) with BH = BKV * rep, B-major.

    The caller pads S to a multiple of the block sizes.
    """
    bh, s, dh = q.shape
    bkv = k.shape[0]
    rep = bh // bkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q, n_k = s // block_q, s // block_k
    scale = dh ** -0.5
    seq_len = s if seq_len is None else seq_len  # mask padded keys

    def q_map(h, qi, ki):
        return (h, qi, 0)

    # GQA without materialized repetition: ops.py lays q out as
    # (B*KV*rep, S, dh) grouped by kv head, so operand index = h // rep.
    def kv_map_grouped(h, qi, ki):
        return (h // rep, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=n_k, seq_len=seq_len, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), q_map),
            pl.BlockSpec((1, block_k, dh), kv_map_grouped),
            pl.BlockSpec((1, block_k, dh), kv_map_grouped),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), q_map),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
