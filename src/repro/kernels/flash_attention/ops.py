"""jit'd wrapper for the flash-attention kernel.

Layout contract with kernel.py: q heads are grouped by KV head so the
BlockSpec GQA index map is a plain ``h // rep``.  On non-TPU backends the
kernel runs in interpret mode (or falls back to the reference when
``interpret=False`` is forced off); shapes are padded to block multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import attention_reference

__all__ = ["flash_attention"]


def _use_pallas_native() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "force_pallas"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    force_pallas: bool = False):
    """q: (B, S, H, dh); k, v: (B, S, KV, dh) -> (B, S, H, dh).

    TPU: native Pallas.  CPU: interpret-mode Pallas when force_pallas
    (kernel validation), else the jnp reference.
    """
    native = _use_pallas_native()
    if not native and not force_pallas:
        return attention_reference(q, k, v, causal=causal, window=window)

    b, s, h, dh = q.shape
    kv = k.shape[2]
    rep = h // kv
    blk = max(block_q, block_k)
    pad = (-s) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    # (B, S, H, dh) -> (B, KV, rep, S, dh) -> (B*KV*rep, S, dh)
    qk = q.transpose(0, 2, 1, 3).reshape(b, kv, rep, sp, dh)
    qk = qk.reshape(b * kv * rep, sp, dh)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kv, sp, dh)
    vk = v.transpose(0, 2, 1, 3).reshape(b * kv, sp, dh)
    out = flash_attention_kernel(
        qk, kk, vk, causal=causal, window=window, seq_len=s,
        block_q=block_q, block_k=block_k, interpret=not native)
    out = out.reshape(b, kv, rep, sp, dh).reshape(b, h, sp, dh)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :s] if pad else out
