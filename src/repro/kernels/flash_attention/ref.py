"""Pure-jnp oracle for the flash-attention kernel: naive softmax attention."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["attention_reference"]


def attention_reference(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, S, H, dh); k, v: (B, S, KV, dh). Returns (B, S, H, dh)."""
    b, s, h, dh = q.shape
    kv = k.shape[2]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh ** -0.5
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= i >= j
    if window > 0:
        mask &= (i - j) < window
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - scores.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
