"""Shared pow2 bucket ladder.

One definition used by BOTH the serving engine (fused-step batch/table
buckets, prefill padding, the ``max_fused_compiles`` ladder bound) and
the paged decode kernel op (index-map page padding) — the CI-asserted
compile bound silently assumes the two ladders agree, so they must come
from one function.
"""

from __future__ import annotations

__all__ = ["pow2_bucket", "ladder_size"]


def pow2_bucket(n: int, floor: int = 1, cap: int | None = None) -> int:
    """Smallest power-of-two >= n (at least ``floor``), clamped to
    ``cap``.  Bucketing every dynamic dimension onto this ladder bounds
    the jit compile set to O(log) entries instead of one per distinct
    size."""
    b = max(1, floor)
    while b < n:
        b <<= 1
    return b if cap is None else min(b, cap)


def ladder_size(cap: int, floor: int = 1) -> int:
    """Number of distinct buckets pow2_bucket can emit for n in [1, cap]."""
    return len({pow2_bucket(n, floor, cap) for n in range(1, cap + 1)})
