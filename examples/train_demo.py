"""Training driver: train a reduced llama-family model on synthetic LM
data with AdamW + cosine schedule + checkpointing.

    PYTHONPATH=src python examples/train_demo.py --steps 50
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import lm_batches
from repro.models import build_model
from repro.training import (AdamW, make_lr_schedule, make_train_step,
                            save_checkpoint)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint", default="/tmp/repro_ckpt.npz")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M")

    opt = AdamW(learning_rate=3e-3)
    sched = make_lr_schedule(warmup=10, total=args.steps)
    step_fn = jax.jit(make_train_step(model, opt, sched))
    state = opt.init(params)
    data = lm_batches(cfg.vocab_size, args.batch, args.seq, seed=0)

    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, metrics = step_fn(params, state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"({(time.time()-t0)/(step+1):.2f}s/step)")
    save_checkpoint(args.checkpoint, params, state, step=args.steps)
    print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
