"""Compare every scheduling policy on the paper's mixed workload
(simulator reproduction of Fig. 7 at one RPS point).

    PYTHONPATH=src python examples/scheduler_comparison.py [--rps 8]
"""

import argparse

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import run_policy, seed_records, workload  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rps", type=float, default=8.0)
    ap.add_argument("--n", type=int, default=600)
    args = ap.parse_args()
    reqs = workload(n=args.n, rps=args.rps)
    records = seed_records()
    print(f"{'policy':12s} {'mean TTLT':>10s} {'mean TTFT':>10s} "
          f"{'p99 TTLT':>10s}")
    base = None
    for pol in ("fcfs", "fastserve", "ssjf", "ltr", "trail", "mean",
                "gittins", "sagesched", "sagesched_aged"):
        res = run_policy(pol, reqs, records=records)
        if pol == "fcfs":
            base = res.mean_ttlt()
        gain = (base - res.mean_ttlt()) / base * 100
    # re-run to print (simple two-pass keeps output aligned)
        print(f"{pol:12s} {res.mean_ttlt():9.2f}s {res.mean_ttft():9.2f}s "
              f"{res.p99_ttlt():9.1f}s  ({gain:+.1f}% vs FCFS)")


if __name__ == "__main__":
    main()
