"""Cluster-scale central scheduling demo (paper Sec. 4.4 topology).

One shared-BatchState SageSched scheduler in front of N simulated
serving nodes, with pluggable request routing:

  * jsow — join-shortest-outstanding-work on the fixed admission-time
           token guess (the Llumnix-style baseline);
  * cost — predicted CostDistribution means + per-node KV headroom
           (uncertainty-aware placement);
  * cost with route_quantile=0.9 — routes on the 0.9-quantile of the
           predicted cost instead of its mean (robust to heavy tails).

Also prints the Fig. 12 overhead probe: per-request predict / schedule
wall-clock of the central scheduler at the same node count.

    PYTHONPATH=src python examples/cluster_demo.py [--nodes 4] [--n 400]
"""

import argparse

from repro.core import Scheduler, SemanticHistoryPredictor, make_policy
from repro.simulator import (generate_workload, make_profile,
                             measure_scheduler_overhead, simulate_cluster)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--rps-per-node", type=float, default=8.0)
    ap.add_argument("--policy", default="sagesched")
    args = ap.parse_args()

    profiles = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]
    reqs = generate_workload(profiles, args.n,
                             rps=args.rps_per_node * args.nodes, seed=0)

    print(f"{args.n} requests, {args.nodes} nodes, "
          f"{args.rps_per_node * args.nodes:.0f} RPS aggregate, "
          f"policy={args.policy}\n")
    print(f"{'router':>10s} {'mean TTLT':>10s} {'mean TTFT':>10s} "
          f"{'requests/node':>24s}")
    for router, quantile in (("jsow", None), ("cost", None), ("cost", 0.9)):
        predictor = SemanticHistoryPredictor()
        res = simulate_cluster(
            reqs,
            lambda: Scheduler(policy=make_policy(args.policy),
                              predictor=predictor),
            args.nodes, router=router, route_quantile=quantile)
        print(f"{res.router:>10s} {res.mean_ttlt:9.2f}s "
              f"{res.mean_ttft:9.2f}s "
              f"{str(res.requests_per_node):>24s}")

    print("\ncentral-scheduler overhead (Fig. 12 probe, numpy backend):")
    o = measure_scheduler_overhead(args.nodes, n_probe=50,
                                   history_size=2000)
    print(f"  queue depth {o['queue_depth']}, "
          f"predict {o['predict_ms']:.3f} ms, "
          f"schedule {o['schedule_ms']:.3f} ms per request")


if __name__ == "__main__":
    main()
