"""End-to-end driver (deliverable b): serve a small model with batched
requests through the REAL JAX engine under SageSched scheduling.

    PYTHONPATH=src python examples/serve_demo.py [--policy sagesched]
"""

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core import Scheduler, make_policy
from repro.data import ByteTokenizer
from repro.models import build_model
from repro.serving import ServeRequest, ServingEngine

PROMPTS = [
    "summarize the following meeting notes about quarterly revenue",
    "summarize the following meeting notes about hiring plans",
    "write a long story about a dragon who learns to code",
    "write a long story about an island made of glass",
    "explain in detail how a transformer decoder works",
    "explain in detail how paged attention manages memory",
    "translate this sentence politely",
    "translate this phrase formally",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="sagesched")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--n-requests", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    tok = ByteTokenizer()
    engine = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy(args.policy)),
        n_slots=4, max_seq_len=192, seed=0)

    rng = np.random.default_rng(0)
    reqs = []
    t0 = time.monotonic()
    for i in range(args.n_requests):
        prompt = PROMPTS[i % len(PROMPTS)] + f" (case {i})"
        r = ServeRequest(
            request_id=f"req-{i}", prompt=prompt,
            prompt_tokens=tok.encode(prompt)[:64],
            max_new_tokens=int(rng.integers(8, 48)),
            eos_token=tok.eos_id, arrival=t0 + i * 0.01)
        engine.submit(r)
        reqs.append(r)

    engine.run_until_done()
    print(f"policy={args.policy}  " + str(engine.metrics.summary(reqs)))
    for r in reqs[:3]:
        print(f"  {r.request_id}: {r.generated} tokens, "
              f"ttft={r.ttft:.2f}s ttlt={r.ttlt:.2f}s, "
              f"text={tok.decode(r.output_tokens)[:40]!r}")


if __name__ == "__main__":
    main()
