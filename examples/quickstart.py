"""Quickstart: the SageSched scheduler core in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (ResourceBoundCost, Scheduler, SemanticHistoryPredictor,
                        gittins_index, make_policy)

# 1. A training-free predictor that learns from served requests.
predictor = SemanticHistoryPredictor()
rng = np.random.default_rng(0)
for i in range(200):
    # history: summarization prompts finish short, story prompts run long
    if i % 2 == 0:
        predictor.observe(f"summarize this report {i}", 800,
                          int(rng.lognormal(4.5, 0.4)))
    else:
        predictor.observe(f"write a long fantasy story {i}", 60,
                          int(rng.lognormal(6.8, 0.5)))

# 2. The scheduler: predict -> cost (O^2/2 + I*O) -> Gittins index.
# Ingress is batch-first: a burst of arrivals is ONE batched admission
# (one history search for the burst; scalar .admit() is the B=1 case).
sched = Scheduler(predictor=predictor, cost_model=ResourceBoundCost(),
                  policy=make_policy("sagesched"))
sched.admit_batch(["story", "summ"],
                  ["write a long fantasy story now",
                   "summarize this report please"],
                  [60, 800], arrivals=[0.0, 0.1])

for rid in ("summ", "story"):
    sr = sched.get(rid)
    print(f"{rid:6s} predicted mean O = {sr.length_dist.mean:7.1f}  "
          f"Gittins index = {sr.priority:12.1f}")
print("service order:", sched.order())

# 3. Runtime refresh: after 300 tokens the story request's remaining-cost
# distribution is re-conditioned at the next bucket boundary.
sched.on_progress("story", 300)
print("after 300 tokens, order:", sched.order())
