"""Mesh-parallel serving: sharded-vs-single-device parity.

The tentpole claim of ``repro.serving.sharded``: running the engine on a
mesh (sharded paged KV pool + expert-parallel MoE, everything else
replicated — the exact ``decode_rules`` set) is *bit-identical* to the
single-device engine.  Not close — identical: the rules shard only
batch-like einsum dims, so every per-slice GEMM keeps its unsharded
shape and no float contraction crosses a shard boundary.

The matrix: {dense, moe} x {fused, orchestrated} x {swap, recompute} x
mesh {1x1, 2, 4, 8}, stochastic sampling (temperature 0.7), with the
capacity squeezed so preemption fires mid-decode.  Every sharded run
must emit the same token streams as the no-mesh engine, preserve the KV
accounting invariants, and (fused) stay within the pow2-bucket compile
bound.

tp > 1 requires host devices: CI's mesh job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before importing
jax; on a plain single-device run those cells skip.

Also here: regression coverage for ``launch.mesh.make_local_mesh``
(host-platform fallback + axis-size validation) and the engine's
mesh/tp consistency checks.
"""

import functools

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy)
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.serving import RequestState, ServeRequest, ServingEngine
from repro.testing import assert_engine_quiesced, assert_tokens_close

# Head/expert counts are overridden so every mesh width in the matrix
# divides them — the fallback (non-dividing) path gets its own test.
ARCHS = {
    "dense": ("qwen2-1.5b", dict(n_heads=8, n_kv_heads=8)),
    "moe": ("olmoe-1b-7b", dict(n_heads=8, n_kv_heads=8, n_experts=8)),
}
MESH_WIDTHS = [1, 2, 4, 8]

POOL_SPEC_SHARDED = P(None, None, None, "model", None)


def _need_devices(tp):
    if jax.device_count() < tp:
        pytest.skip(f"needs {tp} devices, jax sees {jax.device_count()} "
                    "(CI mesh job sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _run(fam, *, step_mode, pmode="swap", tp=None, temperature=0.7,
         decode_steps=1, sharing=False, chunk=None, n=3, cap=None,
         overrides=None, parallel="exact"):
    """test_decode_hot_loop's forcing workload (2 slots + a capacity
    squeeze tight enough that both families preempt mid-decode) on an
    optionally-meshed engine.  ``tp=None`` is the plain single-device
    baseline; ``tp=1`` builds a degenerate 1x1 mesh so the plan path
    itself is exercised."""
    arch, ov = ARCHS[fam]
    if cap is None:
        cap = 32  # squeezed so every family x step_mode preempts mid-run
    cfg = get_config(arch, reduced=True).with_overrides(
        **(overrides if overrides is not None else ov))
    o = OraclePredictor()
    for i in range(n):
        o.register(f"p{i}", LengthDistribution(np.array([6 + 2 * i]),
                                               np.array([1.0])))
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("sagesched"), predictor=o),
        n_slots=2, max_seq_len=96, capacity_tokens=cap, block_size=8,
        preemption_mode=pmode, prefill_chunk=chunk, seed=0,
        step_mode=step_mode, decode_steps=decode_steps,
        prefix_sharing=sharing, parallel=parallel,
        mesh=None if tp is None else make_local_mesh(tp=tp))
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(n):
        base = [] if not sharing else _shared_prefix(cfg)
        toks = base + [int(t) for t in rng.integers(
            3, cfg.vocab_size, int(rng.integers(6, 11)))]
        reqs.append(ServeRequest(f"r{i}", f"p{i}", toks,
                                 max_new_tokens=6 + 2 * i,
                                 temperature=temperature, eos_token=1,
                                 arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    eng.run_until_done(max_steps=8000)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    eng.kv.assert_conserved()
    assert_engine_quiesced(eng)
    return eng, [tuple(r.output_tokens) for r in reqs]


@functools.lru_cache(maxsize=None)
def _shared_prefix_cached(vocab):
    rng = np.random.default_rng(11)
    return tuple(int(t) for t in rng.integers(3, vocab, 24))


def _shared_prefix(cfg):
    return list(_shared_prefix_cached(cfg.vocab_size))


@functools.lru_cache(maxsize=None)
def _baseline(fam, step_mode, pmode, decode_steps=1, sharing=False,
              chunk=None, cap=None, temperature=0.7):
    """Single-device reference streams, computed once per cell family."""
    _, want = _run(fam, step_mode=step_mode, pmode=pmode, tp=None,
                   decode_steps=decode_steps, sharing=sharing, chunk=chunk,
                   cap=cap, temperature=temperature)
    return want


# ------------------------------------------------------- parity matrix

@pytest.mark.parametrize("tp", MESH_WIDTHS)
@pytest.mark.parametrize("pmode", ["swap", "recompute"])
@pytest.mark.parametrize("step_mode", ["fused", "orchestrated"])
@pytest.mark.parametrize("fam", ["dense", "moe"])
def test_mesh_parity(fam, step_mode, pmode, tp):
    """The acceptance criterion: sharded token streams are identical to
    the unsharded engine's — stochastic sampling, preemption mid-decode
    and all — while the pool actually lives sharded and the fused
    compile set stays within its bound."""
    _need_devices(tp)
    want = _baseline(fam, step_mode, pmode)
    eng, got = _run(fam, step_mode=step_mode, pmode=pmode, tp=tp)
    assert got == want, f"{fam}/{step_mode}/{pmode}/tp={tp} diverged"
    assert eng.metrics.preemptions > 0

    assert eng.plan is not None and eng.tp == tp
    report = eng.sharding_report()
    assert report["devices"] == tp and report["tp"] == tp
    # the report reflects divisibility, not width: a 1x1 mesh still uses
    # the sharded layout (the 'model' axis just has size 1)
    assert report["attention"] == "sharded"
    if fam == "moe":
        assert report["experts"] == "sharded"
    # physical pages are striped over the kv-head dim (the spec is
    # compared by equivalence: jax normalizes size-1 axes away)
    pool = eng._cache["k"]
    from jax.sharding import NamedSharding
    assert pool.sharding.is_equivalent_to(
        NamedSharding(eng.mesh, POOL_SPEC_SHARDED), pool.ndim)
    n_kv = eng.model.cfg.n_kv_heads
    assert pool.addressable_shards[0].data.shape[3] == n_kv // tp

    if step_mode == "fused":
        assert eng.metrics.fused_steps > 0
        n_compiles = eng.fused_compile_count
        if n_compiles >= 0:       # jax build exposes the jit cache size
            assert 0 < n_compiles <= eng.max_fused_compiles()


@pytest.mark.parametrize("fam", ["dense", "moe"])
def test_mesh_multi_step_fused(fam):
    """decode_steps=4 batches four decode tokens per host round-trip
    inside lax.fori_loop; the donated, shard-pinned pool round-trip must
    not perturb the streams."""
    _need_devices(2)
    want = _baseline(fam, "fused", "swap", decode_steps=4)
    _, got = _run(fam, step_mode="fused", tp=2, decode_steps=4)
    assert got == want


@pytest.mark.parametrize("fam", ["dense", "moe"])
def test_mesh_prefix_sharing_parity(fam):
    """CoW prefix sharing adopts pool pages by refcount; per-shard pages
    make adoption a shard-local no-op, so reuse accounting and streams
    must match the unsharded sharing-on engine."""
    _need_devices(2)
    want = _baseline(fam, "fused", "swap", sharing=True, chunk=16, cap=96)
    eng, got = _run(fam, step_mode="fused", tp=2, sharing=True, chunk=16,
                    cap=96)
    assert got == want
    assert eng.metrics.prefill_tokens_reused > 0


def test_mesh_chunked_prefill_parity():
    """Chunked prefill scatters each chunk's KV into the sharded pool
    through the same per-shard slice path decode uses."""
    _need_devices(2)
    want = _baseline("dense", "fused", "swap", chunk=4)
    _, got = _run("dense", step_mode="fused", tp=2, chunk=4)
    assert got == want


def test_mesh_swap_equals_recompute_sharded():
    """Sampling keys fold (request seed, position) only — preemption
    history is invisible to the stream even when the swap payload is a
    per-shard gather/scatter."""
    _need_devices(2)
    es, a = _run("dense", step_mode="fused", tp=2, pmode="swap")
    er, b = _run("dense", step_mode="fused", tp=2, pmode="recompute")
    assert a == b
    assert es.metrics.preemptions > 0 and er.metrics.preemptions > 0


def test_mesh_fallback_replicates_non_dividing_heads():
    """Heads that don't divide the mesh axis fall back to a replicated
    pool (correct, just not parallel) and the report says so."""
    _need_devices(4)
    eng, got = _run("dense", step_mode="fused", tp=4,
                    overrides=dict(n_heads=6, n_kv_heads=6))
    _, want = _run("dense", step_mode="fused", tp=None,
                   overrides=dict(n_heads=6, n_kv_heads=6))
    assert got == want
    report = eng.sharding_report()
    assert report["attention"] == "replicated"
    pool = eng._cache["k"]
    from jax.sharding import NamedSharding
    assert pool.sharding.is_equivalent_to(
        NamedSharding(eng.mesh, P()), pool.ndim)
    assert pool.addressable_shards[0].data.shape[3] == 6


# --------------------------------------------- make_local_mesh regressions

def test_make_local_mesh_defaults_to_1x1():
    mesh = make_local_mesh()
    assert mesh.axis_names == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_make_local_mesh_uses_host_devices():
    n = jax.device_count()
    mesh = make_local_mesh(tp=n)
    assert int(mesh.shape["model"]) == n
    assert mesh.devices.size == n


def test_make_local_mesh_validates_against_device_count():
    n = jax.device_count()
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_local_mesh(tp=n + 1)
    with pytest.raises(ValueError, match="bad axis sizes"):
        make_local_mesh(tp=0)
    with pytest.raises(ValueError, match="bad axis sizes"):
        make_local_mesh(data=-1)


def test_engine_rejects_tp_mesh_contradiction():
    arch, ov = ARCHS["dense"]
    cfg = get_config(arch, reduced=True).with_overrides(**ov)
    with pytest.raises(ValueError, match="contradicts"):
        ServingEngine(
            model=build_model(cfg),
            scheduler=Scheduler(policy=make_policy("fcfs")),
            n_slots=2, max_seq_len=96, tp=2, mesh=make_local_mesh(tp=1))


def test_decode_rules_reject_data_parallel_mesh():
    """The serving engine manages the batch host-side; a data axis > 1
    on the decode mesh is a configuration error, not a silent no-op."""
    if jax.device_count() < 2:
        pytest.skip("needs 2 devices")
    from repro.sharding.partitioning import decode_rules
    arch, ov = ARCHS["dense"]
    cfg = get_config(arch, reduced=True).with_overrides(**ov)
    mesh = make_local_mesh(tp=1, data=2)
    with pytest.raises(ValueError, match="non-'model' mesh axis"):
        decode_rules(cfg, mesh)


# ------------------------------------------- efficient (Megatron) parallel
#
# parallel="efficient" flips the projection weight axes onto the mesh
# (column-parallel qkv/up/gate, row-parallel wo/down, vocab-sharded
# lm_head) and keeps parity under the *tolerance* contract
# (repro.testing.assert_tokens_close) instead of bit-identity: psum /
# vocab-reduction orders differ per width, so last-ulp drift may flip a
# coin-toss token.  At tp=1 there is nothing to reorder, so efficient
# mode must still be bit-identical.

@pytest.mark.parametrize("tp", MESH_WIDTHS)
@pytest.mark.parametrize("pmode", ["swap", "recompute"])
@pytest.mark.parametrize("step_mode", ["fused", "orchestrated"])
@pytest.mark.parametrize("fam", ["dense", "moe"])
def test_efficient_tolerance_matrix(fam, step_mode, pmode, tp):
    """The PR-8 parity matrix, rerun under parallel='efficient': streams
    match the single-device engine under the tolerance contract, the
    Megatron components actually shard, and the fused compile set stays
    on the same pow2 ladder as exact mode.

    The contract is stated for GREEDY decoding (temperature 0): Megatron
    psum reordering drifts bf16 logits by ~1 ulp, which under stochastic
    sampling shifts the inverse-CDF thresholds by ~the same relative
    mass — a per-step flip chance far above the greedy near-tie rate,
    and more than a short CI stream can absorb at the 0.999 bar.  Greedy
    is what the 0.999 rate is calibrated for; sampled streams get bit
    identity only from parallel='exact' (PR-8 matrix above)."""
    _need_devices(tp)
    want = _baseline(fam, step_mode, pmode, temperature=0.0)
    eng, got = _run(fam, step_mode=step_mode, pmode=pmode, tp=tp,
                    parallel="efficient", temperature=0.0)
    assert_tokens_close(got, want, bit_identical=(tp == 1),
                        label=f"{fam}/{step_mode}/{pmode}/tp={tp}")
    assert eng.metrics.preemptions > 0

    report = eng.sharding_report()
    assert report["parallel"] == "efficient"
    assert report["attention"] == "sharded"
    assert report["vocab"] == "sharded"
    assert report["mlp"] == "sharded"
    if fam == "moe":
        assert report["experts"] == "sharded"
    # the Megatron weights really live sharded: per-device param bytes
    # shrink with width (norm scales are the only replicated leaves)
    if tp > 1:
        assert report["param_bytes_per_device"] < report["param_bytes"]
        assert report["replicated_bytes"] < 0.05 * report["param_bytes"]
    if step_mode == "fused":
        n_compiles = eng.fused_compile_count
        if n_compiles >= 0:
            assert 0 < n_compiles <= eng.max_fused_compiles()


def test_efficient_lse_split_non_dividing_heads():
    """Heads that don't divide the mesh keep the pool replicated but
    still parallelize attention compute: the logical page axis is
    striped over the mesh and per-stripe flash partials merge by LSE
    combine.  Parity stays within tolerance."""
    _need_devices(4)
    ov = dict(n_heads=6, n_kv_heads=6)
    _, want = _run("dense", step_mode="fused", tp=None, overrides=ov,
                   temperature=0.0)
    eng, got = _run("dense", step_mode="fused", tp=4, overrides=ov,
                    parallel="efficient", temperature=0.0)
    assert_tokens_close(got, want, label="lse-split/tp=4")
    report = eng.sharding_report()
    assert report["attention"] == "lse-split"
    assert report["attn_splits"] == 4
    assert set(report["fallbacks"]) == {"heads", "heads_out", "kv"}
    # projections that do divide still shard
    assert report["vocab"] == "sharded" and report["mlp"] == "sharded"


def test_engine_rejects_bad_parallel():
    arch, ov = ARCHS["dense"]
    cfg = get_config(arch, reduced=True).with_overrides(**ov)
    with pytest.raises(ValueError, match="bad parallel"):
        ServingEngine(model=build_model(cfg),
                      scheduler=Scheduler(policy=make_policy("fcfs")),
                      n_slots=2, max_seq_len=96, parallel="megatron")


def test_memory_preflight_refuses_and_diagnoses():
    """An over-budget engine fails *before* allocating anything, with
    the per-component breakdown in the message; a fitting budget stores
    the estimate on ``engine.preflight``."""
    arch, ov = ARCHS["dense"]
    cfg = get_config(arch, reduced=True).with_overrides(**ov)

    def build(budget):
        return ServingEngine(
            model=build_model(cfg),
            scheduler=Scheduler(policy=make_policy("fcfs")),
            n_slots=2, max_seq_len=96, block_size=8,
            device_memory_gb=budget)

    with pytest.raises(ValueError) as ei:
        build(1e-6)
    msg = str(ei.value)
    assert "does not fit" in msg and "weights" in msg \
        and "KV pool" in msg and "workspace" in msg

    eng = build(8.0)
    pf = eng.preflight
    assert pf is not None and pf["total_bytes"] <= 8 * 2**30
    assert pf["total_bytes"] == (pf["weights_bytes"] + pf["kv_pool_bytes"]
                                 + pf["workspace_bytes"])


def test_sharding_report_tensor_rows():
    """describe() itemizes every weight: spec, bytes, per-device bytes,
    and whether a divisibility fallback forced replication — and a
    weight above REPLICATION_WARN_BYTES that fell back warns loudly."""
    _need_devices(2)
    import warnings as _w

    import repro.serving.sharded as sharded
    arch, ov = ARCHS["dense"]
    cfg = get_config(arch, reduced=True).with_overrides(**ov)
    eng = ServingEngine(model=build_model(cfg),
                        scheduler=Scheduler(policy=make_policy("fcfs")),
                        n_slots=2, max_seq_len=96, block_size=8,
                        tp=2, parallel="efficient")
    report = eng.sharding_report()
    rows = report["tensors"]
    assert rows and all({"name", "shape", "spec", "bytes",
                         "bytes_per_device", "sharded", "fallback"}
                        <= set(r) for r in rows)
    by_name = {r["name"]: r for r in rows}
    wq = next(r for n, r in by_name.items() if "wq" in n)
    assert wq["sharded"] and wq["bytes_per_device"] == wq["bytes"] // 2
    assert report["replicated_bytes"] == sum(
        r["bytes"] for r in rows if not r["sharded"])
    assert report["warnings"] == []

    # big non-dividing weights trip the replication warning
    old = sharded.REPLICATION_WARN_BYTES
    sharded.REPLICATION_WARN_BYTES = 0
    try:
        with _w.catch_warnings(record=True) as caught:
            _w.simplefilter("always")
            eng2 = ServingEngine(
                model=build_model(cfg.with_overrides(
                    n_heads=3, n_kv_heads=3)),
                scheduler=Scheduler(policy=make_policy("fcfs")),
                n_slots=2, max_seq_len=96, block_size=8,
                tp=2, parallel="efficient")
        assert any("replicat" in str(w.message) for w in caught)
        assert eng2.sharding_report()["warnings"]
    finally:
        sharded.REPLICATION_WARN_BYTES = old
