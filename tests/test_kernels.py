"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU; on TPU the same code lowers
natively)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gittins_index_batch
from repro.kernels.decode_attention.ops import (decode_attention_op,
                                                decode_attention_paged_op)
from repro.kernels.decode_attention.ref import (
    decode_attention_paged_reference, decode_attention_reference)
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.gittins.ops import (PAD_SUPPORT, gittins_attained_op,
                                       gittins_op)
from repro.kernels.ssd_scan.ops import ssd_scan_op
from repro.kernels.ssd_scan.ref import ssd_reference
from repro.models.ssm import ssd_chunked

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,dh,causal,window", [
    (2, 256, 4, 2, 64, True, 0),      # GQA
    (1, 256, 4, 4, 128, True, 0),     # MHA
    (2, 200, 4, 1, 64, True, 0),      # MQA + ragged seq (padding path)
    (1, 256, 4, 2, 64, False, 0),     # bidirectional (encoder)
    (1, 384, 4, 2, 64, True, 128),    # sliding window
])
def test_flash_attention_vs_oracle(B, S, H, KV, dh, causal, window, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (B, S, H, dh)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, KV, dh)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, KV, dh)), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          force_pallas=True)
    want = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,dh,window,blk", [
    (2, 512, 8, 2, 64, 0, 128),
    (3, 1024, 4, 1, 128, 0, 256),     # MQA (granite-style)
    (2, 512, 8, 8, 64, 512, 128),     # ring buffer (sliding window)
    (1, 640, 4, 4, 64, 0, 128),
])
def test_decode_attention_vs_oracle(B, S, H, KV, dh, window, blk, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (B, H, dh)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, KV, dh)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, KV, dh)), dtype)
    hi = S + 200 if window else S
    cl = jnp.asarray(RNG.integers(1, hi, (B,)), jnp.int32)
    got = decode_attention_op(q, k, v, cl, window=window, block_s=blk,
                              force_pallas=True)
    want = decode_attention_reference(q, k, v, cl, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,dh,page,P,n_pages,window", [
    (2, 8, 2, 64, 16, 8, 32, 0),      # GQA
    (3, 4, 1, 128, 32, 4, 16, 0),     # MQA
    (2, 8, 8, 64, 16, 8, 32, 40),     # logical sliding window
])
def test_paged_decode_attention_vs_oracle(B, H, KV, dh, page, P, n_pages,
                                          window, dtype):
    """Block-table indirection kernel (scalar-prefetch index maps) vs the
    gather-based oracle, non-contiguous physical pages."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (B, H, dh)), dtype)
    kp = jnp.asarray(rng.normal(0, 1, (n_pages, page, KV, dh)), dtype)
    vp = jnp.asarray(rng.normal(0, 1, (n_pages, page, KV, dh)), dtype)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages))[:B * P]
                     .reshape(B, P), jnp.int32)
    cl = jnp.asarray(rng.integers(1, P * page, (B,)), jnp.int32)
    got = decode_attention_paged_op(q, kp, vp, bt, cl, window=window,
                                    force_pallas=True)
    want = decode_attention_paged_reference(q, kp, vp, bt, cl,
                                            window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_splits", [2, 4])
def test_paged_lse_kernel_stripes_merge_to_full(n_splits, dtype):
    """The (out, lse) Pallas variant run per page-stripe, merged by
    ``combine_lse_partials``, equals the full paged kernel AND the
    oracle — the device-side half of the sharded lse-split path."""
    from repro.kernels.decode_attention import decode_attention_paged_lse_op
    from repro.kernels.decode_attention.ref import (
        decode_attention_paged_lse_reference)
    from repro.models.attention import combine_lse_partials
    rng = np.random.default_rng(9)
    B, H, KV, dh, page, P, n_pages = 2, 8, 2, 64, 16, 8, 32
    q = jnp.asarray(rng.normal(0, 1, (B, H, dh)), dtype)
    kp = jnp.asarray(rng.normal(0, 1, (n_pages, page, KV, dh)), dtype)
    vp = jnp.asarray(rng.normal(0, 1, (n_pages, page, KV, dh)), dtype)
    bt = jnp.asarray(rng.permutation(np.arange(1, n_pages))[:B * P]
                     .reshape(B, P), jnp.int32)
    cl = jnp.asarray(rng.integers(1, P * page, (B,)), jnp.int32)

    want = decode_attention_paged_reference(q, kp, vp, bt, cl)
    # full-call (out, lse) pallas vs the lse oracle
    out_full, lse_full = decode_attention_paged_lse_op(
        q, kp, vp, bt, cl, force_pallas=True)
    _, lse_ref = decode_attention_paged_lse_reference(q, kp, vp, bt, cl)
    np.testing.assert_allclose(np.asarray(out_full, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(lse_full), np.asarray(lse_ref),
                               rtol=1e-5, atol=1e-5)

    # striped partials (some stripes fully masked for short rows) merge
    # back to the full result
    sp = P // n_splits
    outs, lses = [], []
    for s in range(n_splits):
        o, l = decode_attention_paged_lse_op(
            q, kp, vp, bt[:, s * sp:(s + 1) * sp],
            jnp.clip(cl - s * sp * page, 0), force_pallas=True)
        outs.append(o.astype(jnp.float32))
        lses.append(l)
    got, _ = combine_lse_partials(jnp.stack(outs), jnp.stack(lses))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_decode_matches_dense_on_gathered_cache():
    """Paged oracle == dense oracle when the pool is gathered through the
    block table — the indirection is a pure relayout."""
    rng = np.random.default_rng(8)
    B, H, KV, dh, page, P, n_pages = 2, 4, 2, 64, 16, 4, 16
    q = jnp.asarray(rng.normal(0, 1, (B, H, dh)), jnp.float32)
    kp = rng.normal(0, 1, (n_pages, page, KV, dh)).astype(np.float32)
    vp = rng.normal(0, 1, (n_pages, page, KV, dh)).astype(np.float32)
    bt = rng.permutation(np.arange(1, n_pages))[:B * P].reshape(B, P)
    cl = jnp.asarray(rng.integers(1, P * page, (B,)), jnp.int32)
    tok = (bt * page)[:, :, None] + np.arange(page)
    kd = kp.reshape(-1, KV, dh)[tok.reshape(B, -1)]
    vd = vp.reshape(-1, KV, dh)[tok.reshape(B, -1)]
    got = decode_attention_paged_reference(
        q, jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(bt, jnp.int32), cl)
    want = decode_attention_reference(q, jnp.asarray(kd), jnp.asarray(vd),
                                      cl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 32, 16, 32),
    (1, 200, 8, 64, 32, 64),          # ragged (padding path)
    (2, 64, 2, 16, 8, 64),            # single chunk
])
def test_ssd_kernel_vs_sequential_oracle(B, S, H, P, N, chunk):
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 1.0, (B, S, H)), jnp.float32)
    a = jnp.asarray(RNG.uniform(0.5, 0.999, (B, S, H)), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 0.5, (B, S, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 0.5, (B, S, N)), jnp.float32)
    got = ssd_scan_op(x, dt, a, bm, cm, chunk=chunk, force_pallas=True)
    want = ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_model_ssd_chunked_matches_oracle():
    """The model-side chunked scan (used by mamba2/zamba2 forward) agrees
    with the sequential recurrence too."""
    B, S, H, P, N = 2, 96, 4, 32, 16
    x = jnp.asarray(RNG.normal(0, 1, (B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 1.0, (B, S, H)), jnp.float32)
    a = jnp.asarray(RNG.uniform(0.5, 0.999, (B, S, H)), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 0.5, (B, S, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 0.5, (B, S, N)), jnp.float32)
    got, _ = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    want = ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,k", [(7, 8), (256, 32), (777, 16)])
def test_gittins_kernel_vs_numpy(n, k):
    sup = np.sort(RNG.uniform(1, 1e6, (n, k)), axis=1).astype(np.float32)
    probs = RNG.dirichlet(np.ones(k), n).astype(np.float32)
    got = gittins_op(jnp.asarray(sup), jnp.asarray(probs), force_pallas=True)
    want = gittins_index_batch(sup, probs)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)


@pytest.mark.parametrize("pad_value", [np.inf, PAD_SUPPORT])
def test_gittins_kernel_ragged_padding_no_nan(pad_value):
    """Regression: padded columns (prob 0) used to poison the cumsum with
    inf * 0 = NaN.  The kernel must stay finite and match the oracle for
    both +inf and large-finite pads."""
    rng = np.random.default_rng(21)   # own rng: order-independent data
    n, k_real, k = 33, 6, 16
    sup = np.sort(rng.uniform(1, 1e5, (n, k_real)), axis=1)
    probs = rng.dirichlet(np.ones(k_real), n)
    sup_p = np.pad(sup, ((0, 0), (0, k - k_real)),
                   constant_values=pad_value).astype(np.float32)
    probs_p = np.pad(probs, ((0, 0), (0, k - k_real))).astype(np.float32)
    got = np.asarray(gittins_op(jnp.asarray(sup_p), jnp.asarray(probs_p),
                                force_pallas=True))
    assert np.isfinite(got).all()
    want = gittins_index_batch(sup, probs)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_gittins_attained_op_matches_numpy():
    """The scheduler-facing op (pow2 persistent padding + conditioning)
    agrees with the float64 oracle, including exhausted rows."""
    rng = np.random.default_rng(22)   # own rng: order-independent data
    n, k = 100, 12
    sup = np.sort(rng.uniform(1, 1e5, (n, k)), axis=1)
    probs = rng.dirichlet(np.ones(k), n)
    att = rng.uniform(0, 2e5, n) * (rng.random(n) > 0.3)  # some exhausted
    got = np.asarray(gittins_attained_op(sup, probs, att,
                                         force_pallas=True))
    want = gittins_index_batch(sup, probs, att)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_flash_kernel_jit_composes():
    """pallas_call must be jittable (interpret mode) inside larger fns."""
    q = jnp.asarray(RNG.normal(0, 1, (1, 128, 2, 64)), jnp.float32)

    @jax.jit
    def f(q):
        return flash_attention(q, q[:, :, :1], q[:, :, :1],
                               force_pallas=True).sum()

    assert np.isfinite(float(f(q)))
