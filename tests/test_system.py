"""End-to-end behaviour tests for the SageSched system (paper claims as
executable assertions, on the calibrated simulator)."""

import numpy as np
import pytest

from repro.core import (Scheduler, SemanticHistoryPredictor, make_cost_model,
                        make_policy)
from repro.simulator import generate_workload, make_profile, simulate

PROFILES = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]


def _seeded_predictor(seed=5, per_cluster=40):
    rng = np.random.default_rng(seed)
    p = SemanticHistoryPredictor()
    prompts, ils, ols = [], [], []
    for prof in PROFILES:
        for c in prof.clusters:
            for _ in range(per_cluster):
                prompts.append(c.sample_prompt(rng))
                ils.append(c.sample_input_len(rng))
                ols.append(c.sample_output_len(rng))
    p.seed(prompts, ils, ols)
    return p


def _run(policy, cost_model="resource_bound", noise=0.0, rps=10.0, n=400,
         seed=11):
    reqs = generate_workload(PROFILES, n, rps=rps, seed=seed)
    sched = Scheduler(policy=make_policy(policy),
                      predictor=_seeded_predictor(),
                      cost_model=make_cost_model(cost_model),
                      noise_weight=noise)
    return simulate(reqs, sched)


def test_sagesched_beats_every_baseline_on_ttlt():
    """The paper's headline: SageSched attains the best mean TTLT."""
    sage = _run("sagesched").mean_ttlt()
    for baseline in ("fcfs", "fastserve", "trail", "mean"):
        assert sage < _run(baseline).mean_ttlt(), baseline


def test_resource_bound_cost_beats_output_length_cost():
    """Paper Sec. 4.3.2 (Fig. 10): hybrid cost model superiority."""
    rb = _run("sagesched", cost_model="resource_bound").mean_ttlt()
    ol = _run("sagesched", cost_model="output_length").mean_ttlt()
    assert rb < ol


def test_gittins_beats_mean_ordering():
    """Paper Sec. 4.3.3 (Fig. 11): Gittins beats expectation ordering."""
    g = _run("gittins").mean_ttlt()
    m = _run("mean").mean_ttlt()
    assert g < m


def test_gittins_robust_to_prediction_noise():
    """Fig. 11's noise experiment: adding 1:4 uniform noise degrades the
    Gittins policy far less (relatively) than point-based SJF."""
    sage_clean = _run("sagesched").mean_ttlt()
    sage_noisy = _run("sagesched", noise=0.2).mean_ttlt()
    sjf_clean = _run("ssjf").mean_ttlt()
    sjf_noisy = _run("ssjf", noise=0.2).mean_ttlt()
    sage_degr = sage_noisy / sage_clean
    sjf_degr = sjf_noisy / sjf_clean
    assert sage_degr < sjf_degr + 0.05


def test_ttft_not_sacrificed():
    """Fig. 7: SageSched also improves TTFT vs FCFS (head-of-line relief)."""
    assert _run("sagesched").mean_ttft() < _run("fcfs").mean_ttft()


def test_improvement_grows_with_load():
    """'improvements are higher with more intensive competition'."""
    gains = []
    for rps in (4.0, 12.0):
        f = _run("fcfs", rps=rps).mean_ttlt()
        s = _run("sagesched", rps=rps).mean_ttlt()
        gains.append((f - s) / f)
    assert gains[1] > gains[0] - 0.02
