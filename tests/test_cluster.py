"""Cluster-scale shared-BatchState scheduler: parity, routing, ordering.

The load-bearing invariant of the PR-2 refactor: one shared BatchState
holding every node's requests must schedule *identically* to one private
scheduler per node, as long as the routing decisions match — the shared
state changes where the arrays live, not what the policies compute.
"""

import numpy as np
import pytest

from repro.core import Scheduler, SemanticHistoryPredictor, make_policy
from repro.simulator import (ClusterScheduler, CostAwareRouter,
                             JoinShortestWorkRouter, NodeSpec,
                             generate_workload, make_profile, make_router,
                             measure_scheduler_overhead, simulate,
                             simulate_cluster)
from repro.simulator.workload import SimRequest

PROFILES = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]


def _metric_key(result):
    """Canonical per-request comparison key (exact float equality)."""
    return sorted((m.request_id, m.node_id, m.ttft, m.ttlt,
                   m.n_preemptions) for m in result.metrics)


def _req(i, arrival, input_len=64, output_len=32, prompt=None):
    c = PROFILES[0].clusters[0]
    return SimRequest(request_id=f"r{i:04d}", arrival=arrival,
                      prompt=prompt or c.sample_prompt(
                          np.random.default_rng(i)),
                      input_len=input_len, true_output_len=output_len,
                      dataset="sharegpt", cluster=c)


# ------------------------------------------------- shared vs fanout parity

@pytest.mark.parametrize("policy", ["fcfs", "fastserve", "sagesched"])
def test_shared_batchstate_matches_per_node_fanout(policy):
    """Acceptance criterion: under identical JSOW routing, the shared-
    BatchState cluster simulation reproduces the per-node-fanout
    baseline's request metrics exactly (not approximately)."""
    reqs = generate_workload(PROFILES, 150, rps=18.0, seed=11)
    # the central scheduler owns ONE history window; for exact parity the
    # fanout baseline's nodes must share the same predictor instance
    pred_a, pred_b = SemanticHistoryPredictor(), SemanticHistoryPredictor()
    shared = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy(policy),
                                predictor=pred_a), 3)
    fanout = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy(policy),
                                predictor=pred_b), 3, shared_state=False)
    assert _metric_key(shared) == _metric_key(fanout)
    assert shared.requests_per_node == fanout.requests_per_node


def test_object_backend_matches_numpy_in_cluster():
    """The per-request object oracle and the batched numpy backend must
    produce the same cluster schedules (node-masked order() included)."""
    reqs = generate_workload(PROFILES, 80, rps=15.0, seed=3)
    runs = {}
    for backend in ("object", "numpy"):
        pred = SemanticHistoryPredictor()
        runs[backend] = simulate_cluster(
            reqs, lambda: Scheduler(policy=make_policy("sagesched"),
                                    predictor=pred,
                                    priority_backend=backend), 2)
    assert _metric_key(runs["object"]) == _metric_key(runs["numpy"])


def test_single_node_cluster_equals_standalone_simulate():
    """n_nodes=1 reduces the event-driven loop to the monolithic
    NodeSimulator.run — metrics must agree exactly."""
    reqs = generate_workload(PROFILES, 90, rps=12.0, seed=5)
    cluster = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy("sagesched")), 1)
    standalone = simulate(reqs, Scheduler(policy=make_policy("sagesched")))
    want = sorted((m.request_id, m.ttft, m.ttlt)
                  for m in standalone.metrics)
    got = sorted((m.request_id, m.ttft, m.ttlt) for m in cluster.metrics)
    assert got == want


def test_cluster_factory_scheduler_is_used():
    """Regression: ClusterScheduler must not swap an *empty* configured
    scheduler (falsy via __len__) for a default one."""
    sched = Scheduler(policy=make_policy("fcfs"))
    cs = ClusterScheduler(sched, n_nodes=2)
    assert cs.scheduler is sched


# ------------------------------------------------------- node-masked order

@pytest.mark.parametrize("backend", ["object", "numpy"])
def test_order_node_masked(backend):
    sched = Scheduler(policy=make_policy("sagesched"),
                      priority_backend=backend)
    rng = np.random.default_rng(0)
    for i in range(30):
        sched.admit(f"r{i}", f"prompt about topic {i % 5}",
                    int(rng.integers(16, 512)), arrival=float(i),
                    node_id=i % 3)
    full = sched.order()
    for nid in range(3):
        masked = sched.order(node_id=nid)
        assert masked == [r for r in full if int(r[1:]) % 3 == nid]
    # reassignment moves a request between node queues
    sched.assign_node("r0", 2)
    assert "r0" in sched.order(node_id=2)
    assert "r0" not in sched.order(node_id=0)


def test_outstanding_by_node_batched_matches_object():
    outs = []
    for backend in ("object", "numpy"):
        sched = Scheduler(policy=make_policy("sagesched"),
                          priority_backend=backend)
        for i in range(20):
            sched.admit(f"r{i}", f"p{i % 4}", 64 + i, arrival=float(i),
                        node_id=i % 4)
        outs.append(sched.outstanding_by_node(4))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-12)
    assert (outs[0] > 0).all()


# ---------------------------------------------------------------- routers

def test_jsow_router_matches_seed_bucketing():
    """The JSOW router reproduces the decayed outstanding-work bucketing
    the seed's simulate_cluster used (Llumnix-style baseline)."""
    reqs = generate_workload(PROFILES, 60, rps=25.0, seed=2)
    router = JoinShortestWorkRouter(3)
    got = [router.route(r) for r in sorted(reqs, key=lambda r: r.arrival)]
    # reference implementation (the seed's inline loop)
    outstanding = np.zeros(3)
    last_t = 0.0
    want = []
    for r in sorted(reqs, key=lambda x: x.arrival):
        outstanding = np.maximum(0.0, outstanding
                                 - (r.arrival - last_t) * 2000.0)
        last_t = r.arrival
        n = int(np.argmin(outstanding))
        want.append(n)
        outstanding[n] += r.input_len + 2.0 * 256
    assert got == want


def test_cost_router_prefers_node_with_headroom():
    """A node whose KV budget cannot take the arriving request is avoided
    even when it has the least outstanding predicted work."""
    pred = SemanticHistoryPredictor()
    spec = NodeSpec()
    router = CostAwareRouter(2, pred, spec=spec)
    cap = spec.kv_capacity_tokens
    # saturate node 0's KV mirror but leave its outstanding work at ~zero
    router.kv[0].allocate("blocker", int(cap * 0.99))
    r = _req(0, arrival=0.0, input_len=2048, output_len=512)
    assert router.route(r) == 1
    router.on_complete(r.request_id, 1)
    assert router.kv[1].used_tokens == 0
    assert router.outstanding[1] == 0.0


def test_cost_router_prefers_less_predicted_work():
    """With headroom everywhere, routing follows the predicted cost-mean
    outstanding counter — high-cost requests repel later arrivals."""
    pred = SemanticHistoryPredictor()
    # teach the predictor: "write a long story" prompts run very long
    for i in range(50):
        pred.observe(f"write a long story {i}", 32, 2000)
        pred.observe(f"short answer {i}", 32, 8)
    router = CostAwareRouter(2, pred)
    long_req = _req(0, 0.0, input_len=32, prompt="write a long story now")
    short_req = _req(1, 0.0, input_len=32, prompt="short answer please")
    n_long = router.route(long_req)
    # the long request's predicted cost parks on its node; the next two
    # short requests must both prefer the other node
    n_s1 = router.route(short_req)
    assert n_s1 == 1 - n_long
    n_s2 = router.route(_req(2, 0.0, input_len=32,
                             prompt="short answer again"))
    assert n_s2 == 1 - n_long
    # completing the long request frees its node again
    router.on_complete(long_req.request_id, n_long)
    assert router.outstanding[n_long] == pytest.approx(0.0)


def test_cost_router_saturated_picks_least_overcommitted():
    pred = SemanticHistoryPredictor()
    router = CostAwareRouter(2, pred)
    cap = router.kv[0].capacity_tokens
    router.kv[0].allocate("b0", cap)
    router.kv[1].allocate("b1", int(cap * 0.98))
    assert router.route(_req(0, 0.0, input_len=4096, output_len=2048)) == 1


def test_cost_router_saturated_spreads_by_outstanding_work():
    """Regression: under full saturation the router must rank by live
    outstanding work, not frozen KV-mirror headroom — a node whose slot
    mirror stopped accruing must not soak up all overload traffic."""
    pred = SemanticHistoryPredictor()
    router = CostAwareRouter(2, pred)
    cap = router.kv[0].capacity_tokens
    router.kv[0].allocate("b0", int(cap * 0.96))
    router.kv[1].allocate("b1", int(cap * 0.99))
    # node 0 has more raw headroom but a mountain of queued work
    router.outstanding[0] = 1e9
    router.outstanding[1] = 1.0
    assert router.route(_req(0, 0.0, input_len=4096, output_len=2048)) == 1


def test_cost_router_survives_deep_backlog():
    """Regression: more than max_batch queued requests per node used to
    exhaust the router's KV-mirror slot pool and crash allocate()."""
    pred = SemanticHistoryPredictor()
    spec = NodeSpec()
    router = CostAwareRouter(2, pred, spec=spec)
    n = 2 * spec.max_batch + 8   # > max_batch slots per node, cluster-wide
    for i in range(n):
        router.route(_req(i, arrival=0.0, input_len=64))
    assert int(router.outstanding.sum()) > 0
    # completions unwind cleanly even for requests that skipped the mirror
    for i in range(n):
        router.on_complete(f"r{i:04d}", i % 2)


def test_cost_router_hands_prediction_to_admit():
    """The route-time prediction is reused by Scheduler.admit (no second
    semantic-history lookup for the same request)."""
    reqs = generate_workload(PROFILES, 40, rps=20.0, seed=13)
    pred = SemanticHistoryPredictor()
    sched_holder = []

    def factory():
        s = Scheduler(policy=make_policy("sagesched"), predictor=pred)
        sched_holder.append(s)
        return s

    simulate_cluster(reqs, factory, 2, router="cost")
    # every request predicted exactly once (by the router); admit reused it
    assert sched_holder[0].stats["predictions"] == 0


def test_cost_router_end_to_end_smoke():
    reqs = generate_workload(PROFILES, 100, rps=20.0, seed=9)
    res = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy("sagesched")), 3,
        router="cost")
    assert len(res.metrics) == 100
    assert res.router == "cost"
    assert sum(res.requests_per_node) == 100
    assert all(np.isfinite(m.ttlt) for m in res.metrics)


def test_make_router_rejects_unknown():
    with pytest.raises(KeyError):
        make_router("nope", 2)


# -------------------------------------------------- event-loop determinism

def test_simultaneous_arrivals_are_routed_in_input_order():
    """Regression: arrivals with identical timestamps must route
    deterministically (input order), and the simulation must be
    reproducible run-to-run."""
    reqs = [_req(i, arrival=1.0) for i in range(6)]  # all at t=1.0
    runs = []
    for _ in range(2):
        res = simulate_cluster(
            reqs, lambda: Scheduler(policy=make_policy("fcfs")), 3)
        runs.append(res)
    # JSOW with equal arrivals: round-robin in input order
    by_node = {m.request_id: m.node_id for m in runs[0].metrics}
    assert [by_node[f"r{i:04d}"] for i in range(6)] == [0, 1, 2, 0, 1, 2]
    assert _metric_key(runs[0]) == _metric_key(runs[1])
    assert len(runs[0].metrics) == 6


def test_event_loop_routes_against_live_state():
    """A request arriving after the cluster drains must still be served
    (idle-node wakeup), and arrival interleaving across nodes must not
    lose or duplicate requests."""
    reqs = [_req(0, 0.0, output_len=8), _req(1, 50.0, output_len=8),
            _req(2, 50.0 + 1e-9, output_len=8)]
    res = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy("sagesched")), 2)
    assert sorted(m.request_id for m in res.metrics) == \
        ["r0000", "r0001", "r0002"]
    for m in res.metrics:
        assert m.ttlt < 10.0  # served promptly at its own arrival


# -------------------------------------------------------- overhead probe

def test_measure_overhead_drives_batched_path():
    o = measure_scheduler_overhead(4, n_probe=8, history_size=1000,
                                   queue_depth=200)
    assert o["backend"] == "numpy"
    assert o["n_nodes"] == 4
    assert o["queue_depth"] >= 8
    assert o["total_ms"] == pytest.approx(
        o["predict_ms"] + o["schedule_ms"])
    assert 0 < o["schedule_ms"] < 1000


def test_measure_overhead_object_backend_still_works():
    o = measure_scheduler_overhead(1, n_probe=4, history_size=500,
                                   queue_depth=100, backend="object")
    assert o["backend"] == "object"
    assert np.isfinite(o["total_ms"])
