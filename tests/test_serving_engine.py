"""Real serving engine + KV cache manager."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core import Scheduler, make_policy
from repro.models import build_model
from repro.serving import (KVCacheManager, RequestState, ServeRequest,
                           ServingEngine)


# ------------------------------------------------------------ KV manager

def test_kv_manager_basic_lifecycle():
    kv = KVCacheManager(n_slots=2, max_seq_len=64, capacity_tokens=100)
    s1 = kv.allocate("a", 30)
    assert kv.used_tokens == 30 and kv.free_slots == 1
    assert kv.grow("a", 5)
    assert kv.tokens_of("a") == 35
    kv.allocate("b", 40)
    assert not kv.can_admit(40)          # over 95% watermark
    assert kv.release("a") == s1
    assert kv.used_tokens == 40


def test_kv_manager_capacity_guard():
    kv = KVCacheManager(n_slots=4, max_seq_len=10, capacity_tokens=20)
    kv.allocate("a", 10)
    assert not kv.grow("a", 1)           # max_seq_len hit
    kv.allocate("b", 10)
    assert not kv.grow("b", 1)           # capacity hit
    with pytest.raises(KeyError):
        kv.allocate("a", 1)


def test_kv_block_tables_and_fragmentation():
    kv = KVCacheManager(n_slots=2, max_seq_len=64, capacity_tokens=64,
                        block_size=8)
    assert kv.n_blocks == 8 and kv.pool_blocks == 9
    kv.allocate("a", 10)                  # 2 blocks, 6 tokens frag
    assert kv.used_blocks == 2 and kv.frag_tokens == 6
    table = kv.block_table("a")
    assert len(table) == 2 and 0 not in table     # scratch never handed out
    # growth inside the last block allocates nothing new
    assert kv.grow("a", 5) and kv.used_blocks == 2 and kv.frag_tokens == 1
    # crossing the boundary appends exactly one block
    assert kv.grow("a", 2) and kv.used_blocks == 3
    assert kv.block_table("a")[:2] == table
    # block-denominated admission budget is the single source of truth
    assert kv.budget_blocks == int(8 * 0.95)
    assert kv.admission_budget_tokens == kv.budget_blocks * 8
    assert not kv.can_admit(48)           # needs 6 blocks; 3 + 6 > budget 7
    assert kv.can_admit(30)               # needs 4 blocks; 3 + 4 <= 7


def test_kv_grow_failure_no_partial_mutation():
    kv = KVCacheManager(n_slots=2, max_seq_len=64, capacity_tokens=16,
                        block_size=8)
    kv.allocate("a", 16)                  # both blocks
    kv2 = KVCacheManager(n_slots=2, max_seq_len=8, capacity_tokens=64,
                         block_size=8)
    kv2.allocate("b", 8)
    for mgr, rid in ((kv, "a"), (kv2, "b")):
        before = (mgr.tokens_of(rid), list(mgr.block_table(rid)),
                  mgr.free_blocks)
        assert not mgr.grow(rid, 1)
        assert (mgr.tokens_of(rid), list(mgr.block_table(rid)),
                mgr.free_blocks) == before


def test_kv_swap_roundtrip():
    kv = KVCacheManager(n_slots=2, max_seq_len=64, capacity_tokens=64,
                        block_size=8)
    slot_a = kv.allocate("a", 20)         # 3 blocks
    kv.allocate("b", 20)
    payload = {"marker": 42}
    assert kv.can_swap_out("a")
    assert kv.swap_out("a", payload) == 20
    assert not kv.holds("a") and kv.is_swapped("a")
    assert kv.swapped_tokens == 20 and kv.swapped_blocks_used == 3
    assert kv.free_slots == 1 and kv.used_blocks == 3   # b's blocks only
    slot_a2, restored = kv.swap_in("a")
    assert restored is payload
    assert kv.holds("a") and not kv.is_swapped("a")
    assert kv.tokens_of("a") == 20 and len(kv.block_table("a")) == 3
    assert slot_a2 in (slot_a, 1 - slot_a)  # any free slot is fine
    kv.swap_out("b")
    kv.drop_swapped("b")
    assert kv.swapped_tokens == 0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from("abcdefgh"),
                          st.integers(1, 30)), max_size=40))
def test_kv_manager_invariants(ops):
    """Property: used_tokens == sum of held; slots never double-allocated;
    free+held == n_slots."""
    kv = KVCacheManager(n_slots=4, max_seq_len=64, capacity_tokens=200)
    held = {}
    for rid, tokens in ops:
        if rid in held:
            kv.release(rid)
            del held[rid]
        elif kv.free_slots > 0 and kv.can_admit(tokens):
            slot = kv.allocate(rid, tokens)
            assert slot not in [s for s, _ in held.values()]
            held[rid] = (slot, tokens)
        assert kv.used_tokens == sum(t for _, t in held.values())
        assert kv.free_slots + len(held) == 4


# --------------------------------------------------------------- engine

def _make_engine(policy="sagesched", n_slots=4):
    cfg = get_config("llama3.2-1b", reduced=True)
    return ServingEngine(model=build_model(cfg),
                         scheduler=Scheduler(policy=make_policy(policy)),
                         n_slots=n_slots, max_seq_len=96, seed=0), cfg


def _submit(eng, cfg, n, max_new=12, rng=None):
    rng = rng or np.random.default_rng(0)
    reqs = []
    for i in range(n):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size,
                                             int(rng.integers(4, 16)))]
        r = ServeRequest(request_id=f"r{i}", prompt=f"prompt {i} topic {i%2}",
                         prompt_tokens=toks, max_new_tokens=max_new,
                         eos_token=0, arrival=float(i) * 1e-3)
        reqs.append(r)
        eng.submit(r)
    return reqs


def test_engine_completes_all_requests():
    eng, cfg = _make_engine()
    reqs = _submit(eng, cfg, 6)
    eng.run_until_done(max_steps=500)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert all(1 <= r.generated <= 12 for r in reqs)
    assert all(np.isfinite(r.ttft) and np.isfinite(r.ttlt) for r in reqs)
    s = eng.metrics.summary(reqs)
    assert s["completed"] == 6


def test_engine_oversubscribed_queues_and_finishes():
    eng, cfg = _make_engine(n_slots=2)
    reqs = _submit(eng, cfg, 7, max_new=8)
    eng.run_until_done(max_steps=2000)
    assert all(r.done for r in reqs)
    assert eng.metrics.prefills >= 7


def test_engine_policy_affects_order():
    """With SJF-ish scheduling, a short request submitted later should
    finish before a long one submitted earlier (single slot)."""
    cfg = get_config("llama3.2-1b", reduced=True)
    from repro.core import OraclePredictor, LengthDistribution
    o = OraclePredictor()
    o.register("long", LengthDistribution(np.array([40]), np.array([1.0])))
    o.register("short", LengthDistribution(np.array([4]), np.array([1.0])))
    eng = ServingEngine(model=build_model(cfg),
                        scheduler=Scheduler(policy=make_policy("ssjf"),
                                            predictor=o),
                        n_slots=1, max_seq_len=96, seed=0)
    rng = np.random.default_rng(1)
    toks = [int(t) for t in rng.integers(3, cfg.vocab_size, 6)]
    r_long = ServeRequest("L", "long", toks, max_new_tokens=40, arrival=0.0)
    r_short = ServeRequest("S", "short", toks, max_new_tokens=4, arrival=0.1)
    eng.submit(r_long)
    eng.submit(r_short)
    order = []
    while eng.has_work:
        eng.step()
        for r in (r_long, r_short):
            if r.done and r.request_id not in order:
                order.append(r.request_id)
    assert order[0] == "S"


def test_engine_moe_model():
    cfg = get_config("olmoe-1b-7b", reduced=True)
    eng = ServingEngine(model=build_model(cfg),
                        scheduler=Scheduler(policy=make_policy("fcfs")),
                        n_slots=2, max_seq_len=64, seed=0)
    reqs = _submit(eng, cfg, 3, max_new=6)
    eng.run_until_done(max_steps=500)
    assert all(r.done for r in reqs)
