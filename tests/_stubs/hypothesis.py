"""Minimal stand-in for the ``hypothesis`` property-testing library.

Loaded by conftest.py ONLY when the real hypothesis is not installed
(this container doesn't ship it), so the property-test modules still
collect and run.  It covers exactly the API surface this repo uses —
``given``, ``settings``, and the ``lists`` / ``integers`` / ``floats`` /
``tuples`` / ``sampled_from`` strategies — by drawing ``max_examples``
pseudo-random samples per test from a seed derived from the test name
(deterministic across runs).  No shrinking, no edge-case bias: a weaker
substitute, not a replacement — installing the real library transparently
takes precedence on machines that have it.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 50


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value, **_ignored):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def _tuples(*elems):
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, lists=_lists, tuples=_tuples,
    sampled_from=_sampled_from)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                vals = [s.draw(rng) for s in strats]
                kvals = {k: s.draw(rng) for k, s in kwstrats.items()}
                fn(*args, *vals, **kwargs, **kvals)
        # hide the drawn parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
