"""Minimal stand-in for the ``hypothesis`` property-testing library.

Loaded by conftest.py ONLY when the real hypothesis is not installed
(this container doesn't ship it), so the property-test modules still
collect and run.  It covers exactly the API surface this repo uses —
``given``, ``settings``, and the ``integers`` / ``floats`` / ``lists`` /
``tuples`` / ``sampled_from`` / ``booleans`` / ``just`` / ``composite``
strategies — by drawing ``max_examples`` pseudo-random samples per test.
No shrinking, no edge-case bias: a weaker substitute, not a replacement —
installing the real library transparently takes precedence on machines
that have it.

Reproduction: each example draws from its own seed (derived from the
test's qualname + example index).  When an example fails, the stub prints
``REPRO_HYPOTHESIS_SEED=<seed>`` to stderr before re-raising; exporting
that variable re-runs ONLY the failing seed, turning a 200-example fuzz
run into a single deterministic replay::

    REPRO_HYPOTHESIS_SEED=123456789 pytest tests/test_kv_fuzz.py -x
"""

from __future__ import annotations

import functools
import inspect
import os
import sys
import types
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies"]

_DEFAULT_EXAMPLES = 50


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _floats(min_value, max_value, **_ignored):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def _booleans():
    return _Strategy(lambda rng: bool(rng.integers(2)))


def _just(value):
    return _Strategy(lambda rng: value)


def _lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def _tuples(*elems):
    return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


def _composite(fn):
    """``@st.composite`` — ``fn(draw, *args, **kwargs)`` builds a value
    from other strategies.  The returned callable produces a _Strategy
    whose draw hands ``fn`` a ``draw(strategy)`` function, mirroring the
    real hypothesis API closely enough for tests written against it."""
    @functools.wraps(fn)
    def build(*args, **kwargs):
        def draw(rng):
            return fn(lambda strat: strat.draw(rng), *args, **kwargs)
        return _Strategy(draw)
    return build


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, lists=_lists, tuples=_tuples,
    sampled_from=_sampled_from, booleans=_booleans, just=_just,
    composite=_composite)


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strats, **kwstrats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_EXAMPLES)
            base = zlib.crc32(fn.__qualname__.encode())
            pinned = os.environ.get("REPRO_HYPOTHESIS_SEED")
            if pinned is not None:
                seeds = [int(pinned)]
            else:
                seeds = [(base + i) & 0xFFFFFFFF for i in range(n)]
            for seed in seeds:
                rng = np.random.default_rng(seed)
                try:
                    vals = [s.draw(rng) for s in strats]
                    kvals = {k: s.draw(rng) for k, s in kwstrats.items()}
                    fn(*args, *vals, **kwargs, **kvals)
                except Exception:
                    print(f"\nREPRO_HYPOTHESIS_SEED={seed}  "
                          f"(re-run with this env var to replay only "
                          f"the failing example of {fn.__qualname__})",
                          file=sys.stderr)
                    raise
        # hide the drawn parameters from pytest's fixture resolution
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
