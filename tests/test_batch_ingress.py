"""Batch-first ingress (PR 3): admission parity, batched prediction,
routing bursts.

The load-bearing invariant of the redesign: the batch call is the
primitive and the scalar call is its B = 1 case, and *both produce
bit-identical state*.  ``admit_batch`` of N requests must yield exactly
the BatchState (every column) and exactly the ``order()`` that N scalar
``admit`` calls produce — for every predictor class and for both the
numpy and pallas refresh backends.  That holds because

  * the history search thresholds through a deterministic exact-recheck
    band (``HistoryStore.threshold_matches``), so BLAS batch-shape
    reduction differences can never flip a match;
  * the proxy head uses non-optimized einsum (B-independent reduction
    order) instead of a gemv/gemm pair;
  * admission priorities always run on the float64 numpy evaluators,
    which are bit-identical to the scalar oracle (PR 1).
"""

import numpy as np
import pytest

from repro.core import (CostDistribution, LengthDistribution,
                        LengthHistoryPredictor, OraclePredictor,
                        PointPredictor, ProxyModelPredictor,
                        ResourceBoundCost, Scheduler,
                        SemanticHistoryPredictor, make_policy)

RNG = np.random.default_rng(7)
WORDS = ("alpha beta gamma delta epsilon zeta eta theta iota kappa "
         "lambda mu nu xi omicron pi rho sigma tau upsilon").split()
POOL = [" ".join(RNG.choice(WORDS, size=12)) for _ in range(48)]


def _seeded_semantic():
    pred = SemanticHistoryPredictor()
    rng = np.random.default_rng(1)
    for _ in range(25):
        for p in POOL:
            pred.observe(p, 64, int(rng.integers(20, 1200)))
    return pred


def _seeded_length_history():
    pred = LengthHistoryPredictor()
    rng = np.random.default_rng(2)
    for i in range(600):
        pred.observe("", int(rng.integers(8, 800)),
                     int(rng.integers(20, 1200)))
    return pred


def _seeded_proxy():
    pred = ProxyModelPredictor(refit_every=64)
    rng = np.random.default_rng(3)
    for i in range(200):
        topic = POOL[i % 8]
        pred.observe(topic, 32, int(rng.integers(20, 1900)))
    assert pred._W is not None
    return pred


def _seeded_oracle():
    pred = OraclePredictor()
    rng = np.random.default_rng(4)
    for p in POOL:
        k = int(rng.integers(1, 12))
        lens = np.sort(rng.choice(np.arange(1, 2000), k, replace=False))
        pred.register(p, LengthDistribution(lens, rng.dirichlet(np.ones(k))))
    return pred


PREDICTORS = {
    "semantic": _seeded_semantic,
    "length_history": _seeded_length_history,
    "proxy": _seeded_proxy,
    "oracle": _seeded_oracle,
    "point": lambda: PointPredictor(_seeded_semantic()),
}

STATE_COLUMNS = ("cost_sup", "cost_probs", "len_sup", "len_probs",
                 "generated", "attained", "arrival", "input_len",
                 "next_refresh", "priority", "base_priority", "node_id",
                 "cost_mean", "dirty")


def _burst(n, seed=11):
    rng = np.random.default_rng(seed)
    prompts = [POOL[int(rng.integers(len(POOL)))] for _ in range(n)]
    input_lens = [int(x) for x in rng.integers(8, 700, n)]
    arrivals = [float(i) for i in range(n)]
    return prompts, input_lens, arrivals


def _state_cols(sched):
    st = sched._state
    return {c: getattr(st, c)[:st.n].copy() for c in STATE_COLUMNS}


# ------------------------------------------------------ predict_batch parity

@pytest.mark.parametrize("pred_name", sorted(PREDICTORS))
def test_predict_batch_bit_identical_to_scalar(pred_name):
    pred = PREDICTORS[pred_name]()
    prompts, input_lens, _ = _burst(40)
    batched = pred.predict_batch(prompts, input_lens)
    for p, il, d in zip(prompts, input_lens, batched):
        want = pred.predict(p, il)
        np.testing.assert_array_equal(d.lengths, want.lengths)
        np.testing.assert_array_equal(d.probs, want.probs)


def test_predict_batch_empty_and_singleton():
    pred = _seeded_semantic()
    assert pred.predict_batch([], []) == []
    (d,) = pred.predict_batch([POOL[0]], [64])
    want = pred.predict(POOL[0], 64)
    np.testing.assert_array_equal(d.lengths, want.lengths)
    np.testing.assert_array_equal(d.probs, want.probs)


def test_subclass_scalar_predict_override_beats_inherited_batch():
    """A subclass of a built-in predictor that overrides only the scalar
    ``predict`` must NOT have it bypassed by the inherited batch path:
    ``has_batch`` goes False and batched callers loop the scalar
    (mirrors Policy.has_batch)."""
    marker = LengthDistribution(np.array([777]), np.array([1.0]))

    class Tweaked(SemanticHistoryPredictor):
        def predict(self, prompt, input_len):
            return marker

    pred = Tweaked()
    assert SemanticHistoryPredictor().has_batch
    assert not pred.has_batch
    dists = pred.predict_many(POOL[:3], [8, 16, 32])
    assert all(d is marker for d in dists)
    # the scheduler's batched admission honors the override too
    sched = Scheduler(predictor=pred)
    srs = sched.admit_batch(["a", "b"], POOL[:2], [8, 16],
                            arrivals=[0.0, 0.0])
    assert all(sr.length_dist is marker for sr in srs)


def test_predict_batch_empty_history_falls_back():
    pred = SemanticHistoryPredictor()
    dists = pred.predict_batch(POOL[:3], [8, 16, 32])
    for d in dists:
        assert list(d.lengths) == [pred.default_length]
        assert d.probs[0] == 1.0


# ------------------------------------------------------- admit_batch parity

@pytest.mark.parametrize("backend", ["numpy", "pallas"])
@pytest.mark.parametrize("pred_name", sorted(PREDICTORS))
def test_admit_batch_bit_identical_to_scalar_admits(pred_name, backend):
    """The acceptance criterion: every BatchState column, the live
    ScheduledRequests, and order() agree exactly between one admit_batch
    call and the equivalent scalar admit loop."""
    pred = PREDICTORS[pred_name]()   # shared: predict() does not mutate
    n = 40
    prompts, input_lens, arrivals = _burst(n)
    mk = lambda: Scheduler(predictor=pred, cost_model=ResourceBoundCost(),
                           policy=make_policy("sagesched"),
                           priority_backend=backend)
    a, b = mk(), mk()
    for i in range(n):
        a.admit(f"r{i}", prompts[i], input_lens[i], arrival=arrivals[i],
                node_id=i % 3)
    b.admit_batch([f"r{i}" for i in range(n)], prompts, input_lens,
                  arrivals=arrivals, node_ids=[i % 3 for i in range(n)])
    ca, cb = _state_cols(a), _state_cols(b)
    for col in STATE_COLUMNS:
        np.testing.assert_array_equal(ca[col], cb[col], err_msg=col)
    assert a._state.ids == b._state.ids
    assert a._state.index == b._state.index
    assert a._state.k == b._state.k
    assert a.order() == b.order()
    assert a.order(node_id=1) == b.order(node_id=1)
    for i in range(n):
        sa, sb = a.get(f"r{i}"), b.get(f"r{i}")
        assert (sa.priority, sa.arrival, sa.next_refresh, sa.node_id) \
            == (sb.priority, sb.arrival, sb.next_refresh, sb.node_id)
        np.testing.assert_array_equal(sa.length_dist.lengths,
                                      sb.length_dist.lengths)
        np.testing.assert_array_equal(sa.cost_dist.support,
                                      sb.cost_dist.support)
        np.testing.assert_array_equal(sa.cost_dist.probs,
                                      sb.cost_dist.probs)


@pytest.mark.parametrize("policy", ["fcfs", "fastserve", "ssjf", "ltr",
                                    "trail", "mean", "gittins",
                                    "sagesched", "sagesched_aged"])
def test_admit_batch_parity_across_policies(policy):
    pred = _seeded_semantic()
    n = 24
    prompts, input_lens, arrivals = _burst(n, seed=23)
    mk = lambda: Scheduler(predictor=pred, policy=make_policy(policy),
                           priority_backend="numpy")
    a, b = mk(), mk()
    for i in range(n):
        a.admit(f"r{i}", prompts[i], input_lens[i], arrival=arrivals[i])
    b.admit_batch([f"r{i}" for i in range(n)], prompts, input_lens,
                  arrivals=arrivals)
    ca, cb = _state_cols(a), _state_cols(b)
    for col in STATE_COLUMNS:
        np.testing.assert_array_equal(ca[col], cb[col], err_msg=col)
    assert a.order() == b.order()


def test_admit_batch_empty_is_a_noop():
    sched = Scheduler(predictor=_seeded_semantic())
    assert sched.admit_batch([], [], []) == []
    assert len(sched) == 0
    assert sched.order() == []
    assert sched.stats["predictions"] == 0


def test_admit_batch_single_element_equals_scalar():
    pred = _seeded_semantic()
    a = Scheduler(predictor=pred)
    b = Scheduler(predictor=pred)
    sa = a.admit("x", POOL[0], 64, arrival=1.0)
    (sb,) = b.admit_batch(["x"], [POOL[0]], [64], arrivals=[1.0])
    assert sa.priority == sb.priority
    assert sa.arrival == sb.arrival
    assert sa.next_refresh == sb.next_refresh
    for col in STATE_COLUMNS:
        np.testing.assert_array_equal(getattr(a._state, col)[:1],
                                      getattr(b._state, col)[:1],
                                      err_msg=col)


def test_admit_batch_duplicate_ids_reject_before_mutation():
    sched = Scheduler(predictor=_seeded_semantic())
    sched.admit("a", POOL[0], 32, arrival=0.0)
    # duplicate against live state
    with pytest.raises(KeyError):
        sched.admit_batch(["b", "a"], POOL[:2], [8, 8], arrivals=[1.0, 1.0])
    # duplicate within the burst
    with pytest.raises(KeyError):
        sched.admit_batch(["c", "c"], POOL[:2], [8, 8], arrivals=[1.0, 1.0])
    assert len(sched) == 1          # nothing from the rejected bursts
    assert sched._state.n == 1


def test_admit_batch_mixed_provided_predictions():
    """None entries in length_dists are predicted (batched); provided
    entries are used verbatim and not counted as predictions."""
    pred = _seeded_semantic()
    sched = Scheduler(predictor=pred)
    given = LengthDistribution(np.array([123]), np.array([1.0]))
    srs = sched.admit_batch(["a", "b", "c"], POOL[:3], [32, 48, 64],
                            arrivals=[0.0, 0.0, 0.0],
                            length_dists=[None, given, None])
    assert sched.stats["predictions"] == 2
    assert srs[1].length_dist is given
    assert list(srs[0].length_dist.lengths) != [123]


def test_admit_batch_object_backend_matches_scalar():
    pred = _seeded_semantic()
    n = 16
    prompts, input_lens, arrivals = _burst(n, seed=5)
    a = Scheduler(predictor=pred, priority_backend="object")
    b = Scheduler(predictor=pred, priority_backend="object")
    for i in range(n):
        a.admit(f"r{i}", prompts[i], input_lens[i], arrival=arrivals[i])
    b.admit_batch([f"r{i}" for i in range(n)], prompts, input_lens,
                  arrivals=arrivals)
    assert a.order() == b.order()
    for i in range(n):
        assert a.get(f"r{i}").priority == b.get(f"r{i}").priority


# ----------------------------------------------------------- cost quantile

def test_cost_distribution_quantile():
    cd = CostDistribution(np.array([10.0, 100.0, 1000.0]),
                          np.array([0.5, 0.4, 0.1]))
    assert cd.quantile(0.5) == 10.0
    assert cd.quantile(0.9) == 100.0
    assert cd.quantile(0.95) == 1000.0
    assert cd.quantile(1.0) == 1000.0  # rounding-shortfall clip


def test_distribution_batch_matches_scalar():
    cm = ResourceBoundCost()
    rng = np.random.default_rng(9)
    dists, ils = [], []
    for _ in range(20):
        k = int(rng.integers(1, 16))
        lens = np.sort(rng.choice(np.arange(1, 3000), k, replace=False))
        dists.append(LengthDistribution(lens, rng.dirichlet(np.ones(k))))
        ils.append(int(rng.integers(1, 900)))
    batched = cm.distribution_batch(ils, dists)
    for il, ld, cd in zip(ils, dists, batched):
        want = cm.distribution(il, ld.lengths, ld.probs)
        np.testing.assert_array_equal(cd.support, want.support)
        np.testing.assert_array_equal(cd.probs, want.probs)


# ------------------------------------------------------------ router bursts

from repro.simulator import (CostAwareRouter, JoinShortestWorkRouter,  # noqa: E402
                             generate_workload, make_profile, make_router,
                             simulate_cluster)
from repro.simulator.workload import SimRequest  # noqa: E402

PROFILES = [make_profile(n) for n in ("sharegpt", "alpaca")]


def _sim_req(i, arrival, input_len=64, output_len=24, prompt=None):
    c = PROFILES[0].clusters[0]
    return SimRequest(request_id=f"r{i:04d}", arrival=arrival,
                      prompt=prompt or c.sample_prompt(
                          np.random.default_rng(i)),
                      input_len=input_len, true_output_len=output_len,
                      dataset="sharegpt", cluster=c)


def test_jsow_route_batch_matches_sequential():
    reqs = [_sim_req(i, arrival=0.25 * (i // 3), input_len=16 + 7 * i)
            for i in range(12)]           # mixed same-tick / spaced
    a, b = JoinShortestWorkRouter(3), JoinShortestWorkRouter(3)
    assert a.route_batch(reqs) == [b.route(r) for r in reqs]
    np.testing.assert_array_equal(a.outstanding, b.outstanding)


def test_cost_route_batch_matches_sequential():
    pred = _seeded_semantic()
    a, b = CostAwareRouter(3, pred), CostAwareRouter(3, pred)
    reqs = [_sim_req(i, arrival=0.0, input_len=32 + 5 * i,
                     prompt=POOL[i % len(POOL)]) for i in range(10)]
    got = a.route_batch(reqs)
    want = [b.route(r) for r in reqs]
    assert got == want
    np.testing.assert_array_equal(a.outstanding, b.outstanding)
    # route-time predictions are staged for admit on both paths
    for r in reqs:
        assert a.take_prediction(r.request_id) is not None


def test_route_quantile_charges_the_quantile():
    o = _seeded_oracle()
    heavy = LengthDistribution(np.array([10, 1000]), np.array([0.9, 0.1]))
    o.register("tail prompt", heavy)
    cm = ResourceBoundCost()
    cd = cm.distribution(50, heavy.lengths, heavy.probs)
    r_mean = CostAwareRouter(2, o, cost_model=cm)
    r_q = CostAwareRouter(2, o, cost_model=cm, route_quantile=0.95)
    assert r_q.name == "cost@q0.95"
    req = _sim_req(0, 0.0, input_len=50, prompt="tail prompt")
    n1 = r_mean.route(req)
    n2 = r_q.route(_sim_req(1, 0.0, input_len=50, prompt="tail prompt"))
    assert r_mean.outstanding[n1] == pytest.approx(cd.mean)
    assert r_q.outstanding[n2] == pytest.approx(cd.quantile(0.95))
    assert cd.quantile(0.95) > 5 * cd.mean   # the tail dominates


def test_make_router_route_quantile_validation():
    pred = _seeded_semantic()
    r = make_router("cost", 2, predictor=pred, route_quantile=0.9)
    assert isinstance(r, CostAwareRouter) and r.route_quantile == 0.9
    with pytest.raises(ValueError):
        make_router("jsow", 2, route_quantile=0.9)
    with pytest.raises(ValueError):
        CostAwareRouter(2, pred, route_quantile=1.5)
    # a pre-built instance must not silently swallow the knob
    with pytest.raises(ValueError):
        make_router(CostAwareRouter(2, pred), 2, route_quantile=0.9)


def test_simulate_cluster_route_quantile_end_to_end():
    reqs = generate_workload(PROFILES, 60, rps=20.0, seed=17)
    res = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy("sagesched")), 2,
        router="cost", route_quantile=0.9)
    assert res.router == "cost@q0.9"
    assert len(res.metrics) == 60
    assert all(np.isfinite(m.ttlt) for m in res.metrics)


def test_same_tick_bursts_shared_equals_fanout():
    """Coalesced same-tick bursts (route_batch + admit_batch) keep the
    shared-BatchState and per-node-fanout modes metric-identical."""
    rng = np.random.default_rng(31)
    reqs = [_sim_req(i, arrival=float(i // 4),   # bursts of 4 per tick
                     input_len=int(rng.integers(16, 256)),
                     output_len=int(rng.integers(8, 64)))
            for i in range(48)]
    pred_a, pred_b = _seeded_semantic(), _seeded_semantic()
    shared = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy("sagesched"),
                                predictor=pred_a), 3, router="cost")
    fanout = simulate_cluster(
        reqs, lambda: Scheduler(policy=make_policy("sagesched"),
                                predictor=pred_b), 3, router="cost",
        shared_state=False)
    key = lambda res: sorted((m.request_id, m.node_id, m.ttft, m.ttlt)
                             for m in res.metrics)
    assert key(shared) == key(fanout)
    assert shared.requests_per_node == fanout.requests_per_node


# ------------------------------------------------------- engine submit_batch

def test_engine_submit_batch_completes():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving import RequestState, ServeRequest, ServingEngine

    cfg = get_config("llama3.2-1b", reduced=True)
    eng = ServingEngine(model=build_model(cfg),
                        scheduler=Scheduler(policy=make_policy("sagesched")),
                        n_slots=4, max_seq_len=96, seed=0)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(5):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size,
                                             int(rng.integers(4, 12)))]
        reqs.append(ServeRequest(request_id=f"r{i}",
                                 prompt=f"prompt {i} topic {i % 2}",
                                 prompt_tokens=toks, max_new_tokens=8,
                                 eos_token=0, arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    assert all(f"r{i}" in eng.scheduler for i in range(5))
    assert eng.scheduler.stats["predictions"] == 5
    # a rejected burst (duplicate id) must leave no ghost registrations
    dup = ServeRequest(request_id="r0", prompt="dup",
                       prompt_tokens=[3, 4], max_new_tokens=4)
    fresh = ServeRequest(request_id="fresh", prompt="fresh",
                         prompt_tokens=[3, 4], max_new_tokens=4)
    with pytest.raises(KeyError):
        eng.submit_batch([fresh, dup])
    assert "fresh" not in eng._requests
    assert "fresh" not in eng.scheduler
    eng.run_until_done(max_steps=500)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert eng.metrics.completed == 5
