"""Gateway admission control: verdicts, bounded queues, uncertainty-aware
shedding, deadlines, retries, degraded fallback (ISSUE 6 tentpole)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        SemanticHistoryPredictor, make_policy)
from repro.models import build_model
from repro.serving import (Gateway, GatewayConfig, RequestState, ServeRequest,
                           ServingEngine, Verdict)
from repro.testing import FlakyPredictor, VirtualClock, assert_engine_quiesced

CFG = get_config("llama3.2-1b", reduced=True)


def _engine(n_slots=2, predictor=None, policy="fcfs", **kw):
    sched = (Scheduler(policy=make_policy(policy), predictor=predictor)
             if predictor is not None
             else Scheduler(policy=make_policy(policy)))
    return ServingEngine(model=build_model(CFG), scheduler=sched,
                         n_slots=n_slots, max_seq_len=96, seed=0,
                         clock=VirtualClock(), **kw)


def _req(i, prompt="p", max_new=6, n_prompt=6, **kw):
    rng = np.random.default_rng(i)
    toks = [int(t) for t in rng.integers(3, CFG.vocab_size, n_prompt)]
    return ServeRequest(request_id=f"g{i}", prompt=prompt,
                        prompt_tokens=toks, max_new_tokens=max_new,
                        eos_token=0, **kw)


def test_gateway_verdicts_and_bounded_queues():
    eng = _engine(n_slots=1)
    gw = Gateway(eng, GatewayConfig(max_inflight=2, max_queue_per_tenant=2,
                                    max_total_queue=2, max_retries=0,
                                    shed_policy="tail"))
    verdicts = gw.offer_batch([_req(i) for i in range(6)])
    assert verdicts == [Verdict.ACCEPT, Verdict.ACCEPT, Verdict.QUEUE,
                        Verdict.QUEUE, Verdict.SHED, Verdict.SHED]
    assert eng.metrics.shed == 2
    gw.run_until_drained(max_steps=2000)
    gw.assert_all_terminal()
    kinds = sorted(k for k, _ in gw.dispositions.values())
    assert kinds == ["FINISHED"] * 4 + ["SHED"] * 2
    assert all(reason == "queue_full" for k, reason in
               gw.dispositions.values() if k == "SHED")
    assert_engine_quiesced(eng)


def test_gateway_round_robin_protects_tenants():
    """One tenant's flood cannot consume another tenant's queue space,
    and the round-robin pump serves the minority tenant early."""
    eng = _engine(n_slots=1)
    gw = Gateway(eng, GatewayConfig(max_inflight=1, max_queue_per_tenant=4,
                                    max_total_queue=16, max_retries=0,
                                    shed_policy="tail"))
    flood = [_req(i, tenant="a") for i in range(6)]
    va = gw.offer_batch(flood)
    assert va == [Verdict.ACCEPT] + [Verdict.QUEUE] * 4 + [Verdict.SHED]
    vb = gw.offer(_req(10, tenant="b"))
    assert vb == Verdict.QUEUE        # per-tenant bound, not global, applies
    finish_order = []
    while not gw.drained:
        gw.step()
        for r in flood + [gw._offered["g10"]]:
            if r.state == RequestState.FINISHED \
                    and r.request_id not in finish_order:
                finish_order.append(r.request_id)
    # the minority tenant's request is pumped in the first round-robin
    # turn after the flood's head — not behind the whole flood
    assert finish_order.index("g10") <= 2
    gw.assert_all_terminal()


def test_gateway_cost_shedding_drops_widest_tail():
    """Under pressure the cost policy sheds the request whose predicted
    cost upper quantile is worst — a queued heavy-tail request is
    displaced by a cheaper incoming one."""
    o = OraclePredictor()
    o.register("cheap", LengthDistribution(np.array([4]), np.array([1.0])))
    o.register("wide", LengthDistribution(np.array([4, 400]),
                                          np.array([0.5, 0.5])))
    eng = _engine(n_slots=1, predictor=o, policy="ssjf")
    gw = Gateway(eng, GatewayConfig(max_inflight=1, max_queue_per_tenant=1,
                                    max_total_queue=1, max_retries=0,
                                    shed_policy="cost", shed_quantile=0.9))
    v0 = gw.offer(_req(0, prompt="cheap"))
    v1 = gw.offer(_req(1, prompt="wide", max_new=8))
    v2 = gw.offer(_req(2, prompt="cheap"))
    assert (v0, v1, v2) == (Verdict.ACCEPT, Verdict.QUEUE, Verdict.QUEUE)
    assert gw.dispositions["g1"] == ("SHED", "displaced_by_cheaper")
    assert eng.metrics.shed == 1
    gw.run_until_drained(max_steps=2000)
    gw.assert_all_terminal()
    assert gw.dispositions["g0"][0] == "FINISHED"
    assert gw.dispositions["g2"][0] == "FINISHED"


def test_gateway_degraded_mode_falls_back_to_static_limits():
    """Predictor outage: scheduler flips to the flat prediction-free
    prior, the gateway stops ranking on costs (FCFS tail-drop) and caps
    in-flight at the conservative static limit — nothing crashes and
    every request still terminates with a reason."""
    flaky = FlakyPredictor(SemanticHistoryPredictor(), mode="outage")
    eng = _engine(n_slots=2, predictor=flaky, policy="sagesched")
    gw = Gateway(eng, GatewayConfig(max_inflight=8, degraded_max_inflight=2,
                                    max_queue_per_tenant=4,
                                    max_total_queue=4, max_retries=0,
                                    shed_policy="cost"))
    verdicts = gw.offer_batch([_req(i) for i in range(8)])
    assert gw.degraded and eng.scheduler.degraded
    assert eng.scheduler.stats["prediction_failures"] > 0
    # static degraded limit (2), then bounded queue (4), then tail-drop
    assert verdicts.count(Verdict.ACCEPT) == 2
    assert verdicts.count(Verdict.QUEUE) == 4
    assert verdicts.count(Verdict.SHED) == 2
    gw.run_until_drained(max_steps=4000)
    gw.assert_all_terminal()
    assert_engine_quiesced(eng)


def test_gateway_deadline_aborts_release_every_block():
    clock = VirtualClock()
    eng = ServingEngine(model=build_model(CFG),
                        scheduler=Scheduler(policy=make_policy("fcfs")),
                        n_slots=2, max_seq_len=96, seed=0, clock=clock)
    gw = Gateway(eng, GatewayConfig(max_inflight=2))
    r0 = _req(0, max_new=64, ttlt_deadline_s=0.5)   # will miss TTLT
    r1 = _req(1, max_new=64, ttft_deadline_s=0.25)  # aborted before decode
    assert gw.offer_batch([r0, r1]) == [Verdict.ACCEPT, Verdict.ACCEPT]
    clock.advance(0.3)             # past r1's TTFT budget, within r0's TTLT
    gw.tick()
    assert r1.state == RequestState.ABORTED
    assert r1.finish_reason == "ttft_deadline"
    gw.step()                      # r0 starts decoding; tokens stream
    clock.advance(0.7)
    gw.tick()
    assert r0.state == RequestState.ABORTED
    assert r0.finish_reason == "ttlt_deadline"
    assert eng.metrics.timeout_aborts == 2
    assert eng.metrics.wasted_tokens == r0.generated + r1.generated
    eng.kv.assert_conserved()
    assert eng.kv.free_slots == 2 and eng.kv.used_tokens == 0
    gw.assert_all_terminal()
    s = eng.metrics.summary([r0, r1])
    assert s["timeout_aborts"] == 2
    assert s["goodput_tokens"] == eng.metrics.decode_tokens \
        - s["wasted_tokens"]


def test_gateway_queued_deadline_shed_without_engine_work():
    clock = VirtualClock(start=5.0)
    eng = ServingEngine(model=build_model(CFG),
                        scheduler=Scheduler(policy=make_policy("fcfs")),
                        n_slots=1, max_seq_len=96, seed=0, clock=clock)
    gw = Gateway(eng, GatewayConfig(max_inflight=1))
    r0 = _req(0, max_new=32)
    r1 = _req(1, max_new=8, arrival=clock(), ttlt_deadline_s=0.2)
    assert gw.offer_batch([r0, r1]) == [Verdict.ACCEPT, Verdict.QUEUE]
    clock.advance(1.0)
    gw.tick()
    assert gw.dispositions["g1"] == ("SHED", "deadline")
    assert r1.state == RequestState.SHED
    gw.run_until_drained(max_steps=2000)
    gw.assert_all_terminal()


def test_gateway_retry_backoff_eventually_admits():
    """A shed request retries with exponential backoff and is admitted
    once pressure clears (no queue space at all -> pure retry path)."""
    eng = _engine(n_slots=1)
    gw = Gateway(eng, GatewayConfig(max_inflight=1, max_queue_per_tenant=0,
                                    max_total_queue=0, max_retries=3,
                                    retry_backoff_s=0.1, shed_policy="tail"))
    r0, r1 = _req(0, max_new=4), _req(1, max_new=4)
    assert gw.offer_batch([r0, r1]) == [Verdict.ACCEPT, Verdict.SHED]
    assert not gw.dispositions.get("g1")      # retryable, not terminal yet
    gw.run_until_drained(max_steps=2000)
    gw.assert_all_terminal()
    assert gw.dispositions["g1"][0] == "FINISHED"
    assert eng.metrics.retries >= 1
    assert eng.metrics.shed == 0


def test_gateway_retry_exhaustion_is_terminal_shed():
    eng = _engine(n_slots=1)
    gw = Gateway(eng, GatewayConfig(max_inflight=1, max_queue_per_tenant=0,
                                    max_total_queue=0, max_retries=2,
                                    retry_backoff_s=0.05, shed_policy="tail"))
    r0 = _req(0, max_new=64)                  # hogs the engine
    r1 = _req(1, max_new=4)
    gw.offer_batch([r0, r1])
    # drive retries while r0 still occupies the single in-flight slot:
    # tick (not step) so the engine makes no progress
    clock = gw.clock
    for _ in range(8):
        clock.advance(0.5)
        gw.tick()
        if gw.dispositions.get("g1"):
            break
    assert gw.dispositions["g1"] == ("SHED", "queue_full")
    assert r1.state == RequestState.SHED
    assert eng.metrics.retries == 2 and eng.metrics.shed == 1
    gw.run_until_drained(max_steps=2000)
    gw.assert_all_terminal()


def test_gateway_duplicate_offer_rejected():
    eng = _engine(n_slots=1)
    gw = Gateway(eng)
    r = _req(0)
    gw.offer(r)
    with pytest.raises(KeyError):
        gw.offer(r)
