"""Discrete-event simulator: conservation laws + scheduling sanity."""

import numpy as np
import pytest

from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy)
from repro.simulator import (NodeSpec, ServiceModel, generate_workload,
                             make_profile, simulate, simulate_cluster,
                             measure_scheduler_overhead)

PROFILES = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]


def _perfect_oracle(reqs):
    o = OraclePredictor()
    for r in reqs:
        o.register(r.prompt, LengthDistribution(
            np.array([r.true_output_len]), np.array([1.0])))
    return o


def test_service_model_regimes():
    sm = ServiceModel(NodeSpec())
    # small batch short ctx: weight-read bound; huge KV: memory grows
    t1 = sm.decode_iteration_time(1, 100)
    t2 = sm.decode_iteration_time(1, 100_000)
    assert t2 > t1
    # closed form == sum of single steps
    steps = sum(sm.decode_iteration_time(4, 1000 + 4 * i) for i in range(10))
    closed = sm.decode_run_time(4, 1000, 10)
    assert closed == pytest.approx(steps, rel=1e-9)


def test_workload_generation_poisson_and_profiles():
    reqs = generate_workload(PROFILES, 200, rps=10.0, seed=0)
    assert len(reqs) == 200
    arr = np.diff([r.arrival for r in reqs])
    assert np.mean(arr) == pytest.approx(0.1, rel=0.3)
    alp = [r for r in reqs if r.dataset == "alpaca"]
    wri = [r for r in reqs if r.dataset == "write"]
    assert np.median([r.input_len for r in alp]) > \
        np.median([r.input_len for r in wri])


def test_simulation_conservation():
    reqs = generate_workload(PROFILES, 100, rps=6.0, seed=2)
    res = simulate(reqs, Scheduler(policy=make_policy("fcfs")))
    assert len(res.metrics) == 100
    for m in res.metrics:
        assert np.isfinite(m.ttlt) and m.ttlt > 0
        assert np.isfinite(m.ttft) and 0 < m.ttft <= m.ttlt + 1e-9
    assert res.makespan >= max(m.arrival + m.ttlt for m in res.metrics) - 1e-6


def test_sjf_oracle_beats_fcfs_under_load():
    reqs = generate_workload(PROFILES, 300, rps=10.0, seed=3)
    fcfs = simulate(reqs, Scheduler(policy=make_policy("fcfs")))
    sjf = simulate(reqs, Scheduler(policy=make_policy("ssjf"),
                                   predictor=_perfect_oracle(reqs)))
    assert sjf.mean_ttlt() < fcfs.mean_ttlt()


def test_sagesched_beats_fcfs_under_load():
    # long enough run for the queue to build — scheduling leverage appears
    # near saturation (paper: "improvements are higher with more intensive
    # competitions")
    reqs = generate_workload(PROFILES, 550, rps=10.0, seed=4)
    rng = np.random.default_rng(0)
    sched = Scheduler(policy=make_policy("sagesched"))
    # warm the history window (paper footnote 3: public-dataset seeding)
    prompts, ils, ols = [], [], []
    for prof in PROFILES:
        for c in prof.clusters:
            for _ in range(30):
                prompts.append(c.sample_prompt(rng))
                ils.append(c.sample_input_len(rng))
                ols.append(c.sample_output_len(rng))
    sched.predictor.seed(prompts, ils, ols)
    sage = simulate(reqs, sched)
    fcfs = simulate(reqs, Scheduler(policy=make_policy("fcfs")))
    assert sage.mean_ttlt() < fcfs.mean_ttlt() * 0.98


def test_fastserve_improves_ttft():
    reqs = generate_workload(PROFILES, 200, rps=10.0, seed=5)
    fcfs = simulate(reqs, Scheduler(policy=make_policy("fcfs")))
    fs = simulate(reqs, Scheduler(policy=make_policy("fastserve")))
    assert fs.mean_ttft() < fcfs.mean_ttft()


def test_capacity_forces_eviction():
    spec = NodeSpec(hbm_bytes=70e9, weight_bytes=64e9)  # tiny KV budget
    reqs = generate_workload(PROFILES, 60, rps=20.0, seed=6)
    res = simulate(reqs, Scheduler(policy=make_policy("sagesched")), spec)
    assert len(res.metrics) == 60          # still all complete
    assert res.n_evictions > 0             # under memory pressure


def test_cluster_routing_and_overhead():
    reqs = generate_workload(PROFILES, 120, rps=20.0, seed=7)
    cr = simulate_cluster(reqs, lambda: Scheduler(policy=make_policy("fcfs")),
                          n_nodes=2)
    total = sum(len(r.metrics) for r in cr.node_results)
    assert total == 120
    o1 = measure_scheduler_overhead(1, n_probe=10, history_size=2000)
    o64 = measure_scheduler_overhead(64, n_probe=10, history_size=2000)
    assert o64["total_ms"] > o1["total_ms"] * 0.5  # grows (roughly) with scale
    assert o64["total_ms"] < 1000                  # and stays sub-second
