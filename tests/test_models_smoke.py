"""Per-architecture smoke tests (deliverable f): reduced variants of every
assigned config run one forward/train step on CPU, asserting output shapes
and the absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPE_IDS, get_config, get_shape
from repro.models import build_model
from repro.training import AdamW, make_train_step


def _batch(cfg, B=2, S=32, rng=None):
    rng = rng or np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    b["labels"] = b["tokens"]
    if cfg.family == "vlm":
        b["patches"] = jnp.asarray(rng.normal(0, 0.02, (B, 8, cfg.d_model)),
                                   jnp.bfloat16)
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(rng.normal(0, 0.02, (B, 16, cfg.d_model)),
                                  jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_constraints(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.family == "moe":
        assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    logits, _, aux = m.forward(params, batch, remat=False)
    exp_s = S + (8 if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    opt = AdamW(learning_rate=1e-3)
    state = opt.init(params)
    step = make_train_step(m, opt)
    params2, state2, metrics = step(params, state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.count) == 1
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_roundtrip(arch):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    batch.pop("labels")
    logits, cache = m.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    # pad seq-dim of KV caches to accept one more token
    cache = {k: (jnp.pad(v, [(0, 0)] * 2 + [(0, 4)] + [(0, 0)] * 2)
                 if k in ("k", "v") else v) for k, v in cache.items()}
    extra = 8 if cfg.family == "vlm" else 0
    cl = jnp.full((B,), S + extra, jnp.int32)
    logits2, cache2 = m.decode_step(params, tok, cache, cl)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_shape_registry():
    assert set(SHAPE_IDS) == {"train_4k", "prefill_32k", "decode_32k",
                              "long_500k"}
    s = get_shape("train_4k")
    assert (s.seq_len, s.global_batch, s.kind) == (4096, 256, "train")
    assert get_shape("long_500k").global_batch == 1


def test_param_counts_in_expected_range():
    """Full configs approximate their nameplate sizes."""
    expected = {
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "llama3.2-1b": (1.0e9, 1.7e9),
        "nemotron-4-340b": (3.0e11, 3.8e11),
        "granite-34b": (3.0e10, 4.0e10),
        "olmoe-1b-7b": (6.0e9, 8.0e9),
        "deepseek-moe-16b": (1.4e10, 2.0e10),
        "mamba2-2.7b": (2.2e9, 3.3e9),
        "internvl2-76b": (6.5e10, 8.5e10),
        "zamba2-1.2b": (1.0e9, 1.7e9),
        "seamless-m4t-medium": (0.7e9, 1.8e9),  # text backbone only
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3g} not in [{lo:.3g},{hi:.3g}]"


def test_active_params_moe():
    cfg = get_config("olmoe-1b-7b")
    assert cfg.active_param_count() < cfg.param_count() / 3
