"""Sharding rules resolve to valid PartitionSpecs; a 1x1 local mesh runs a
sharded train step end-to-end (the real SPMD path at degenerate size)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.sharding import (batch_axes, kv_cache_spec, resolve_specs,
                            rules_for, ssm_state_spec)
from repro.training import AdamW, make_train_step


def test_rules_cover_all_logical_axes():
    mesh = make_local_mesh()
    for arch in ("qwen2-1.5b", "olmoe-1b-7b", "mamba2-2.7b",
                 "seamless-m4t-medium"):
        cfg = get_config(arch)
        m = build_model(cfg)
        for mode in ("train", "serve", "serve_big"):
            rules = rules_for(cfg, mode, mesh)
            specs = resolve_specs(m.param_specs(), rules)
            for leaf in jax.tree.leaves(
                    specs, is_leaf=lambda x: isinstance(x, P)):
                assert isinstance(leaf, P)


def test_fsdp_rules_shard_embed_dim():
    mesh = make_local_mesh()
    cfg = get_config("nemotron-4-340b")
    rules = rules_for(cfg, "train", mesh)
    assert rules["embed"] == "data"
    rules_s = rules_for(cfg, "serve", mesh)
    assert rules_s["embed"] is None


def test_kv_spec_mqa_shards_sequence():
    mesh = make_local_mesh()
    granite = get_config("granite-34b")      # kv=1 < model_parallel
    spec = kv_cache_spec(granite, "serve", mesh, 128)
    assert spec[2] == "model" and spec[3] is None
    qwen = get_config("olmoe-1b-7b")         # kv=16 >= model_parallel
    spec = kv_cache_spec(qwen, "serve", mesh, 128)
    assert spec[3] == "model" and spec[2] is None


def test_batch_axes_divisibility_fallback():
    mesh = make_local_mesh()                 # data=1
    assert batch_axes(mesh, 1) == ("data",)
    spec = ssm_state_spec(get_config("mamba2-2.7b"), "serve", mesh, 1)
    assert spec["ssd"][2] == "model"


def test_sharded_train_step_runs_on_local_mesh():
    mesh = make_local_mesh()
    cfg = get_config("qwen2-1.5b", reduced=True)
    m = build_model(cfg)
    rules = rules_for(cfg, "train", mesh)
    pspecs = resolve_specs(m.param_specs(), rules)
    ns = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                      is_leaf=lambda x: isinstance(x, P))
    params = m.init(jax.random.PRNGKey(0))
    params = jax.device_put(params, ns)
    opt = AdamW()
    state = opt.init(params)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    with mesh:
        step = jax.jit(make_train_step(m, opt))
        params2, state2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
