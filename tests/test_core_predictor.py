"""Predictor stack: embeddings, history store, semantic retrieval."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (HistoryStore, LengthHistoryPredictor, PointPredictor,
                        PromptEmbedder, ProxyModelPredictor,
                        SemanticHistoryPredictor, empirical_distribution)
from repro.simulator import make_profile


def test_embedding_deterministic_unit_norm():
    e = PromptEmbedder()
    a = e.embed("hello world this is a test")
    b = e.embed("hello world this is a test")
    np.testing.assert_array_equal(a, b)
    assert np.linalg.norm(a) == pytest.approx(1.0, abs=1e-5)


def test_embedding_similarity_orders_topics():
    e = PromptEmbedder()
    a = e.embed("summarize this medical paper on cardiology outcomes")
    b = e.embed("summarize this medical paper on oncology trials")
    c = e.embed("write a python quicksort function with tests")
    assert a @ b > a @ c + 0.2


def test_history_fifo_eviction():
    h = HistoryStore(dim=4, capacity=3)
    e = np.ones(4, np.float32) / 2
    for i in range(5):
        h.add(e, 10 + i, 100 + i)
    assert len(h) == 3
    assert set(h.global_output_lengths()) == {102, 103, 104}


def test_history_search_threshold():
    h = HistoryStore(dim=2)
    h.add(np.array([1.0, 0.0], np.float32), 1, 10)
    h.add(np.array([0.0, 1.0], np.float32), 1, 20)
    idx = h.search_similar(np.array([1.0, 0.0], np.float32), 0.9)
    assert list(h.output_lengths(idx)) == [10]


def test_semantic_predictor_recovers_cluster_distribution():
    prof = make_profile("write", seed=7)
    rng = np.random.default_rng(0)
    pred = SemanticHistoryPredictor()
    # seed with history from two very different clusters
    c_long, c_other = prof.clusters[0], prof.clusters[1]
    for _ in range(80):
        pred.observe(c_long.sample_prompt(rng), 64,
                     c_long.sample_output_len(rng))
        pred.observe(c_other.sample_prompt(rng), 64,
                     c_other.sample_output_len(rng))
    truth = c_long.true_length_samples(rng, 400).mean()
    d = pred.predict(c_long.sample_prompt(rng), 64)
    # prediction mean within 50% of cluster ground truth
    assert abs(d.mean - truth) / truth < 0.5


def test_semantic_beats_length_based_on_clustered_data():
    """The paper's Fig. 9 premise as a unit test."""
    prof = make_profile("sharegpt", seed=3)
    rng = np.random.default_rng(1)
    sem = SemanticHistoryPredictor()
    lb = LengthHistoryPredictor()
    clusters = prof.clusters[:6]
    for _ in range(60):
        for c in clusters:
            p, il, ol = (c.sample_prompt(rng), c.sample_input_len(rng),
                         c.sample_output_len(rng))
            sem.observe(p, il, ol)
            lb.observe(p, il, ol)
    errs_s, errs_l = [], []
    for _ in range(40):
        c = clusters[int(rng.integers(len(clusters)))]
        p, il = c.sample_prompt(rng), c.sample_input_len(rng)
        truth = float(np.mean([c.sample_output_len(rng) for _ in range(64)]))
        errs_s.append(abs(sem.predict(p, il).mean - truth))
        errs_l.append(abs(lb.predict(p, il).mean - truth))
    assert np.mean(errs_s) < np.mean(errs_l)


def test_proxy_predictor_fits_and_predicts():
    pred = ProxyModelPredictor(refit_every=64)
    rng = np.random.default_rng(0)
    for i in range(200):
        topic = "alpha beta" if i % 2 == 0 else "gamma delta"
        pred.observe(f"{topic} prompt {i}", 32, 50 if i % 2 == 0 else 900)
    d = pred.predict("alpha beta prompt x", 32)
    d2 = pred.predict("gamma delta prompt y", 32)
    assert d.mean < d2.mean


def test_point_predictor_collapses():
    inner = SemanticHistoryPredictor()
    for i in range(20):
        inner.observe("same prompt every time", 8, 100 + i * 10)
    pp = PointPredictor(inner)
    d = pp.predict("same prompt every time", 8)
    assert len(d.lengths) == 1
    assert d.probs[0] == 1.0


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(1, 4096), min_size=1, max_size=300),
       st.integers(4, 64))
def test_empirical_distribution_properties(samples, max_support):
    d = empirical_distribution(np.array(samples), max_support)
    assert d.probs.sum() == pytest.approx(1.0)
    assert len(d.lengths) <= max_support
    assert np.all(np.diff(d.lengths) > 0)
    assert min(samples) <= d.mean <= max(samples)


def test_noise_mixing():
    d = empirical_distribution(np.array([100, 200, 300]))
    noisy = d.mix_uniform(0.2, max_len=1000)
    assert noisy.probs.sum() == pytest.approx(1.0)
    assert len(noisy.lengths) > len(d.lengths)
