"""Memory-hybrid execution layer: paged KV + swap preemption + chunked
prefill, unified across the real engine and the simulator.

The load-bearing assertions:

  * paged decode (block-table indirection) is BIT-identical to the dense
    per-slot decode path, and chunked prefill is bit-identical to atomic
    prefill for dense models;
  * swap-mode preemption produces token-identical greedy outputs to
    recompute mode while performing ZERO re-prefills on readmission;
  * decode growth past capacity (grow() -> False) is surfaced and forces
    eviction instead of silently unaccounted growth;
  * engine and simulator charge preemption through the SAME
    ServiceModel.swap_time / block-table accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy)
from repro.models import build_model
from repro.serving import RequestState, ServeRequest, ServingEngine
from repro.simulator import NodeSpec, ServiceModel, generate_workload, \
    make_profile, simulate
from repro.simulator.simulator import NodeSimulator


# --------------------------------------------------------- model parity

def _dense_setup(arch="llama3.2-1b", S=23, seed=1):
    cfg = get_config(arch, reduced=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(0)
    toks = rng.integers(3, cfg.vocab_size, (1, S)).astype(np.int32)
    return cfg, m, params, toks


def test_chunked_prefill_matches_atomic_dense():
    """Chunk boundaries must not change the computed KV (dense model:
    bit-identical; MoE capacity routing legitimately regroups tokens, so
    only dense is held to equality)."""
    cfg, m, params, toks = _dense_setup()
    S = toks.shape[1]
    _, cache = m.prefill(params, {"tokens": jnp.asarray(toks)})
    want_k = np.asarray(cache["k"], np.float32)[:, 0]
    L, _, KV, dh = want_k.shape
    empty = jnp.zeros((L, 1, 0, KV, dh), jnp.bfloat16)
    # one-shot chunk
    k1, _ = m.prefill_chunk(params, jnp.asarray(toks), empty, empty,
                            jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(k1, np.float32)[:, 0], want_k)
    # two chunks, second fed the first's (padded) KV as its prefix
    c1 = 12
    ka, va = m.prefill_chunk(params, jnp.asarray(toks[:, :c1]), empty,
                             empty, jnp.int32(0))
    pk = np.zeros((L, 1, 16, KV, dh), np.float32)
    pv = np.zeros_like(pk)
    pk[:, :, :c1] = np.asarray(ka, np.float32)
    pv[:, :, :c1] = np.asarray(va, np.float32)
    kb, _ = m.prefill_chunk(params, jnp.asarray(toks[:, c1:]),
                            jnp.asarray(pk, jnp.bfloat16),
                            jnp.asarray(pv, jnp.bfloat16), jnp.int32(c1))
    got = np.concatenate([np.asarray(ka, np.float32),
                          np.asarray(kb, np.float32)], axis=2)[:, 0]
    np.testing.assert_array_equal(got, want_k)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "olmoe-1b-7b"])
def test_paged_decode_matches_dense_decode(arch):
    """Block-table indirection must be a pure relayout: logits from the
    paged decode step equal the dense decode step bit-for-bit."""
    cfg, m, params, toks = _dense_setup(arch)
    S = toks.shape[1]
    _, cache = m.prefill(params, {"tokens": jnp.asarray(toks)})
    kd = np.asarray(cache["k"], np.float32)
    vd = np.asarray(cache["v"], np.float32)
    L, _, _, KV, dh = kd.shape
    page, P, n_pages = 8, 8, 16
    blocks = [3, 1, 4, 2]                       # deliberately non-contiguous
    bt = np.zeros((2, P), np.int32)
    bt[0, :4] = blocks
    phys = np.array([blocks[p // page] * page + p % page for p in range(S)])
    flatk = np.zeros((L, n_pages * page, KV, dh), np.float32)
    flatv = np.zeros_like(flatk)
    flatk[:, phys] = kd[:, 0, :S]
    flatv[:, phys] = vd[:, 0, :S]
    pcache = {
        "k": jnp.asarray(flatk.reshape(L, n_pages, page, KV, dh),
                         jnp.bfloat16),
        "v": jnp.asarray(flatv.reshape(L, n_pages, page, KV, dh),
                         jnp.bfloat16),
    }
    dk = np.zeros((L, 2, 64, KV, dh), np.float32)
    dv = np.zeros_like(dk)
    dk[:, 0, :S] = kd[:, 0, :S]
    dv[:, 0, :S] = vd[:, 0, :S]
    dcache = {"k": jnp.asarray(dk, jnp.bfloat16),
              "v": jnp.asarray(dv, jnp.bfloat16)}
    cl = jnp.asarray(np.array([S - 1, 0]), jnp.int32)
    tok = jnp.asarray(np.array([[toks[0, -1]], [0]]), jnp.int32)
    btj = jnp.asarray(bt)
    for _ in range(4):
        ld, dcache = m.decode_step(params, tok, dcache, cl)
        lp, pcache = m.decode_step_paged(params, tok, pcache, cl, btj,
                                         page_size=page)
        np.testing.assert_array_equal(np.asarray(ld[0], np.float32),
                                      np.asarray(lp[0], np.float32))
        nxt = int(np.argmax(np.asarray(ld[0], np.float32)))
        tok = jnp.asarray(np.array([[nxt], [0]]), jnp.int32)
        cl = cl + jnp.asarray(np.array([1, 0]), jnp.int32)


# ------------------------------------------------- preemption equivalence

def _engine(mode, *, policy="sagesched", cap=56, chunk=None, n=6,
            block=8, n_slots=2, temperature=0.0):
    cfg = get_config("llama3.2-1b", reduced=True)
    o = OraclePredictor()
    for i in range(n):
        o.register(f"p{i}", LengthDistribution(np.array([8 + 3 * i]),
                                               np.array([1.0])))
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy(policy), predictor=o),
        n_slots=n_slots, max_seq_len=96, capacity_tokens=cap,
        block_size=block, preemption_mode=mode, prefill_chunk=chunk,
        seed=0)
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(n):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size,
                                             int(rng.integers(6, 14)))]
        reqs.append(ServeRequest(
            request_id=f"r{i}", prompt=f"p{i}", prompt_tokens=toks,
            max_new_tokens=8 + 3 * i, temperature=temperature, eos_token=1,
            arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    eng.run_until_done(max_steps=5000)
    return eng, reqs


def test_swap_equals_recompute_and_skips_reprefill():
    """The acceptance criterion: greedy generations are token-identical
    under recompute vs swap preemption with forced eviction, and swap
    restores skip re-prefill (metrics.prefills stays at one per
    request)."""
    es, rs_s = _engine("swap")
    er, rs_r = _engine("recompute")
    assert es.metrics.preemptions > 0, "scenario must force preemption"
    assert er.metrics.preemptions > 0
    for a, b in zip(rs_s, rs_r):
        assert a.output_tokens == b.output_tokens, a.request_id
        assert a.state == RequestState.FINISHED
    # swap mode: one prefill per request, restores via swap-in
    assert es.metrics.prefills == len(rs_s)
    assert es.metrics.swap_ins > 0
    assert sum(r.n_swap_restores for r in rs_s) == es.metrics.swap_ins
    # recompute mode: every readmission re-prefills
    assert er.metrics.prefills == len(rs_r) + er.metrics.preemptions
    assert er.metrics.swap_ins == 0


def test_chunked_engine_matches_atomic_engine():
    """Chunked prefill is an execution-plan change, not a semantic one:
    greedy outputs equal the atomic engine's (dense model)."""
    ea, rs_a = _engine("swap", cap=96, chunk=None)
    ec, rs_c = _engine("swap", cap=96, chunk=4)
    for a, b in zip(rs_a, rs_c):
        assert a.output_tokens == b.output_tokens, a.request_id
    assert ec.metrics.prefill_chunks > ea.metrics.prefill_chunks
    assert ec.metrics.prefills == len(rs_c)


def test_selection_budget_prevents_organic_grow_failure():
    """The unified block budget (selection reserves blocks_for(ctx+1)
    against the SAME accessor grow() draws from) makes over-capacity
    growth impossible in normal operation — the seed engine's silently
    ignored grow()==False can no longer even occur organically."""
    eng, reqs = _engine("swap", policy="fcfs", cap=48, block=8, n=4)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert eng.metrics.preemptions > 0          # capacity was tight
    assert eng.metrics.grow_failures == 0


def test_grow_failure_surfaces_and_forces_eviction():
    """When blocks vanish out from under the engine anyway (here: an
    external allocation hogging the pool), grow()'s False return is
    surfaced as grow_failures and relieved by memory-aware forced
    eviction — not silently dropped like the seed engine did."""
    cfg = get_config("llama3.2-1b", reduced=True)
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("fcfs")),
        n_slots=3, max_seq_len=96, capacity_tokens=96, block_size=8,
        preemption_mode="swap", seed=0)
    rng = np.random.default_rng(4)
    reqs = []
    for i in range(2):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size, 9)]
        reqs.append(ServeRequest(f"g{i}", f"prompt {i}", toks,
                                 max_new_tokens=30, temperature=0.0,
                                 eos_token=1, arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    for _ in range(3):
        eng.step()                      # both prefilled and decoding
    # hog every remaining block behind the manager's back
    hog = eng.kv.free_blocks * eng.kv.block_size
    eng.kv.allocate("__hog__", hog)
    eng.run_until_done(max_steps=3000)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert eng.metrics.grow_failures > 0
    assert eng.metrics.forced_evictions > 0
    assert eng.metrics.completed == len(reqs)


def test_mixed_prefill_decode_token_budget():
    """max_tokens_per_step bounds chunk tokens + decode tokens per
    iteration: the engine still completes and runs chunked."""
    cfg = get_config("llama3.2-1b", reduced=True)
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("fcfs")),
        n_slots=4, max_seq_len=96, block_size=8,
        prefill_chunk=8, max_tokens_per_step=12, seed=0)
    rng = np.random.default_rng(5)
    reqs = []
    for i in range(5):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size, 20)]
        reqs.append(ServeRequest(f"q{i}", f"prompt {i}", toks,
                                 max_new_tokens=6, temperature=0.0,
                                 eos_token=1, arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    eng.run_until_done(max_steps=4000)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    # 20-token prompts through 8-token chunks: >= 3 chunks each
    assert eng.metrics.prefill_chunks >= 3 * len(reqs)


# -------------------------------------------------- shared cost model

def test_swap_cost_shared_between_engine_and_simulator():
    """Engine and simulator charge preemption from ONE model:
    ServiceModel.swap_time with block-table (block-aligned) token
    accounting."""
    sm = ServiceModel()
    # block alignment: 100 tokens in 16-token blocks transfer 112 tokens
    assert sm.swap_time(100, block_size=16) == sm.swap_time(112)
    assert sm.swap_time(112, block_size=16) == sm.swap_time(112)
    # the engine's modeled swap seconds are exactly that function applied
    # to its swap events (block size from its own KVCacheManager)
    eng, _ = _engine("swap")
    assert eng.metrics.swap_outs == eng.metrics.swap_ins == 1
    expect = sm.swap_time(eng.metrics.swapped_out_tokens,
                          eng.kv.block_size) \
        + sm.swap_time(eng.metrics.swapped_in_tokens, eng.kv.block_size)
    assert eng.metrics.modeled_swap_s == pytest.approx(expect)
    # the simulator charges through the same call: a NodeSimulator with
    # the same block size prices one swap-in identically
    node = NodeSimulator(Scheduler(policy=make_policy("fcfs")),
                         block_size=eng.kv.block_size)
    t = int(eng.metrics.swapped_in_tokens)
    assert node.model.swap_time(t, node.block_size) \
        == sm.swap_time(t, eng.kv.block_size)


def test_simulator_chunked_prefill_and_memory_eviction():
    profiles = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]
    reqs = generate_workload(profiles, 80, rps=12.0, seed=2)
    atomic = simulate(reqs, Scheduler(policy=make_policy("sagesched")))
    chunked = simulate(reqs, Scheduler(policy=make_policy("sagesched")),
                       prefill_chunk=256)
    assert len(chunked.metrics) == 80
    for m in chunked.metrics:
        assert np.isfinite(m.ttft) and np.isfinite(m.ttlt)
        assert m.ttft <= m.ttlt + 1e-9
    # chunking splits prefills into more iterations
    assert chunked.n_iterations > atomic.n_iterations
    # memory-aware eviction under a tiny KV budget still completes all
    spec = NodeSpec(hbm_bytes=70e9, weight_bytes=64e9)
    res = simulate(reqs[:50], Scheduler(policy=make_policy("sagesched")),
                   spec, memory_weight=0.5, block_size=16)
    assert len(res.metrics) == 50
    assert res.n_evictions > 0


def test_scheduler_eviction_order_memory_term():
    """memory_weight=0 reverses order(); a positive weight prefers the
    cheap-to-restore victim among equally-ranked tails."""
    sched = Scheduler(policy=make_policy("fcfs"))
    for i, rid in enumerate(("a", "b", "c")):
        sched.admit(rid, f"p {rid}", 10, arrival=float(i))
    base = sched.eviction_order(["a", "b", "c"],
                                held_tokens={"a": 10, "b": 10, "c": 10})
    assert base == sched.order(["a", "b", "c"])[::-1]
    # c is least urgent (FCFS, latest arrival) but holds a huge KV; with
    # a strong memory term the small holder b gets evicted first
    held = {"a": 5000, "b": 1, "c": 5000}
    sm = ServiceModel()
    out = sched.eviction_order(
        ["a", "b", "c"], held_tokens=held,
        swap_cost=lambda t: sm.swap_time(t, 16), memory_weight=2.0)
    assert out[0] == "b"


# ------------------------------------------- recurrent families + edges

@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-1.2b"])
def test_recurrent_families_swap_equals_recompute(arch):
    """SSM/hybrid engine paths (atomic prefill with slot-state write,
    ssm payload swap round-trip, hybrid paged group decode): swap mode
    is token-identical to recompute mode and restores without
    re-prefill."""
    cfg = get_config(arch, reduced=True)

    def run(mode):
        o = OraclePredictor()
        for i in range(3):
            o.register(f"p{i}", LengthDistribution(
                np.array([6 + 3 * i]), np.array([1.0])))
        eng = ServingEngine(
            model=build_model(cfg),
            scheduler=Scheduler(policy=make_policy("sagesched"),
                                predictor=o),
            n_slots=1, max_seq_len=64, capacity_tokens=32, block_size=8,
            preemption_mode=mode, seed=0)
        rng = np.random.default_rng(9)
        reqs = []
        for i in range(3):
            toks = [int(t) for t in rng.integers(3, cfg.vocab_size, 7)]
            reqs.append(ServeRequest(f"s{i}", f"p{i}", toks,
                                     max_new_tokens=6 + 3 * i,
                                     temperature=0.0, eos_token=1,
                                     arrival=float(i) * 1e-3))
        eng.submit_batch(reqs)
        eng.run_until_done(max_steps=3000)
        return eng, reqs

    es, rs_s = run("swap")
    er, rs_r = run("recompute")
    assert all(r.state == RequestState.FINISHED for r in rs_s + rs_r)
    for a, b in zip(rs_s, rs_r):
        assert a.output_tokens == b.output_tokens, (arch, a.request_id)
    if es.metrics.preemptions:
        assert es.metrics.prefills == len(rs_s)
        assert er.metrics.prefills \
            == len(rs_r) + er.metrics.preemptions


def test_infeasible_prompt_rejected_not_livelocked():
    """A prompt larger than the whole physical pool is aborted (with the
    scheduler notified) instead of spinning in WAITING forever."""
    cfg = get_config("llama3.2-1b", reduced=True)
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("fcfs")),
        n_slots=2, max_seq_len=96, capacity_tokens=32, block_size=8,
        seed=0)
    rng = np.random.default_rng(6)
    giant = ServeRequest("giant", "giant prompt",
                         [int(t) for t in rng.integers(3, cfg.vocab_size,
                                                       60)],
                         max_new_tokens=4, temperature=0.0, eos_token=1)
    small = ServeRequest("small", "small prompt",
                         [int(t) for t in rng.integers(3, cfg.vocab_size,
                                                       8)],
                         max_new_tokens=4, temperature=0.0, eos_token=1)
    eng.submit_batch([giant, small])
    eng.run_until_done(max_steps=2000)
    assert giant.state == RequestState.ABORTED
    assert small.state == RequestState.FINISHED
    assert "giant" not in eng.scheduler


def test_prefill_time_chunked_consistent():
    """The closed-form chunked prefill total equals the sum of the
    per-chunk charges the simulator actually applies, and collapses to
    the atomic prefill_time without chunking."""
    sm = ServiceModel()
    assert sm.prefill_time_chunked(700, None) == sm.prefill_time(700)
    assert sm.prefill_time_chunked(700, 1000) == sm.prefill_time(700)
    total, done = 0.0, 0
    while done < 700:
        take = min(256, 700 - done)
        total += sm.prefill_chunk_time(take, done)
        done += take
    assert sm.prefill_time_chunked(700, 256) == pytest.approx(total)
    # chunking trades fixed overhead for a smaller attention term
    assert sm.prefill_time_chunked(700, 256) != sm.prefill_time(700)
