import os
import signal
import sys

import pytest

# Tests run on the single real CPU device (the 512-device fleet is ONLY for
# the dry-run process). Keep compilation light.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The property tests use hypothesis when available; this container doesn't
# ship it, so fall back to the minimal random-sampling stub in _stubs/
# (real hypothesis, when installed, wins — it is found first).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))

# Per-test watchdog: a stall bug (engine drain loop, gateway retry spin)
# must fail its own test with a diagnostic, not hang the whole suite.
# Override per test with @pytest.mark.timeout(seconds); 0 disables.
DEFAULT_TEST_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT_S", "300"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "timeout(seconds): per-test watchdog limit "
        f"(default {DEFAULT_TEST_TIMEOUT_S}s via REPRO_TEST_TIMEOUT_S)")


@pytest.fixture(autouse=True)
def _test_watchdog(request):
    marker = request.node.get_closest_marker("timeout")
    limit = int(marker.args[0]) if marker and marker.args \
        else DEFAULT_TEST_TIMEOUT_S
    if limit <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def _expired(signum, frame):
        raise TimeoutError(
            f"{request.node.nodeid} exceeded the {limit}s per-test "
            "watchdog (likely a drain/retry stall)")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(limit)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)
