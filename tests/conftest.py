import os
import sys

# Tests run on the single real CPU device (the 512-device fleet is ONLY for
# the dry-run process). Keep compilation light.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# The property tests use hypothesis when available; this container doesn't
# ship it, so fall back to the minimal random-sampling stub in _stubs/
# (real hypothesis, when installed, wins — it is found first).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))
