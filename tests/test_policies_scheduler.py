"""Scheduler facade + policy behaviour."""

import numpy as np
import pytest

from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy, POLICY_NAMES)


def oracle_with(dists):
    o = OraclePredictor()
    for prompt, d in dists.items():
        o.register(prompt, d)
    return o


def det(n):
    return LengthDistribution(np.array([n]), np.array([1.0]))


def test_all_policies_constructible():
    for name in POLICY_NAMES:
        assert make_policy(name).name == name


def test_fcfs_orders_by_arrival():
    s = Scheduler(policy=make_policy("fcfs"),
                  predictor=oracle_with({"a": det(10), "b": det(5)}))
    s.admit("r1", "a", 10, arrival=1.0)
    s.admit("r2", "b", 10, arrival=0.5)
    assert s.order() == ["r2", "r1"]


def test_ssjf_orders_by_predicted_length():
    s = Scheduler(policy=make_policy("ssjf"),
                  predictor=oracle_with({"long": det(500), "short": det(20)}))
    s.admit("r1", "long", 10, arrival=0.0)
    s.admit("r2", "short", 10, arrival=1.0)
    assert s.order() == ["r2", "r1"]


def test_sagesched_orders_by_gittins_not_mean():
    lottery = LengthDistribution(np.array([5, 1000]), np.array([0.5, 0.5]))
    steady = LengthDistribution(np.array([300]), np.array([1.0]))
    s = Scheduler(policy=make_policy("sagesched"),
                  predictor=oracle_with({"lot": lottery, "st": steady}))
    s.admit("r1", "st", 10, arrival=0.0)
    s.admit("r2", "lot", 10, arrival=1.0)
    assert s.order() == ["r2", "r1"]  # lottery first despite higher mean
    # mean policy picks the other order
    s2 = Scheduler(policy=make_policy("mean"),
                   predictor=oracle_with({"lot": lottery, "st": steady}))
    s2.admit("r1", "st", 10, arrival=0.0)
    s2.admit("r2", "lot", 10, arrival=1.0)
    assert s2.order() == ["r1", "r2"]


def test_bucket_refresh_deprioritizes_lost_lottery():
    lottery = LengthDistribution(np.array([5, 1000]), np.array([0.5, 0.5]))
    steady = LengthDistribution(np.array([300]), np.array([1.0]))
    s = Scheduler(policy=make_policy("sagesched"), bucket_size=50,
                  predictor=oracle_with({"lot": lottery, "st": steady}))
    s.admit("r1", "st", 10, arrival=0.0)
    s.admit("r2", "lot", 10, arrival=1.0)
    assert s.order()[0] == "r2"
    s.on_progress("r2", 60)  # crossed bucket boundary past the short mode
    assert s.order()[0] == "r1"
    assert s.stats["refreshes"] >= 1


def test_gittins_no_refresh_keeps_priority():
    lottery = LengthDistribution(np.array([5, 1000]), np.array([0.5, 0.5]))
    s = Scheduler(policy=make_policy("gittins"), bucket_size=50,
                  predictor=oracle_with({"lot": lottery}))
    s.admit("r2", "lot", 10, arrival=0.0)
    p0 = s.get("r2").priority
    s.on_progress("r2", 60)
    assert s.get("r2").priority == p0
    assert s.stats["refreshes"] == 0


def test_fastserve_demotes_at_quantum_boundaries():
    pol = make_policy("fastserve", base_quantum=16)
    s = Scheduler(policy=pol, predictor=oracle_with({"p": det(100)}))
    s.admit("r", "p", 10, arrival=0.0)
    lvl0 = pol.level_of(0)
    s.on_progress("r", 20)  # past first quantum (16)
    assert pol.level_of(20) > lvl0
    assert s.get("r").priority > pol.LEVEL_SPAN - 1


def test_trail_conditional_remaining():
    d = LengthDistribution(np.array([10, 100]), np.array([0.5, 0.5]))
    s = Scheduler(policy=make_policy("trail"), bucket_size=10,
                  predictor=oracle_with({"p": d}))
    s.admit("r", "p", 10, arrival=0.0)
    p0 = s.get("r").priority  # E[remaining] = 55
    s.on_progress("r", 20)    # only the 100 mode remains -> remaining 80
    assert s.get("r").priority != p0


def test_completion_feeds_history():
    s = Scheduler()  # default: semantic history predictor + sagesched
    s.admit("r", "some prompt text here", 12, arrival=0.0)
    s.on_complete("r", 77)
    assert len(s.predictor.history) == 1
    assert "r" not in s


def test_double_admit_raises():
    s = Scheduler(predictor=oracle_with({"p": det(5)}))
    s.admit("r", "p", 1, arrival=0.0)
    with pytest.raises(KeyError):
        s.admit("r", "p", 1, arrival=0.0)


def test_aged_sagesched_time_varying():
    """Beyond-paper aging: an old request's priority improves with time."""
    lottery = LengthDistribution(np.array([5, 1000]), np.array([0.5, 0.5]))
    s = Scheduler(policy=make_policy("sagesched_aged", tau_age=10.0),
                  predictor=oracle_with({"p": lottery}))
    s.admit("r", "p", 10, arrival=0.0)
    s.set_now(0.0)
    p0 = s.get("r").priority
    s.set_now(100.0)  # 10x tau of queueing age
    assert s.get("r").priority < p0 / 5
