"""Fault-injection matrix (ISSUE 6): abort in every lifecycle state leaks
nothing, injected KV/predictor faults are survived with conserved block
accounting, node kill/slow events re-route cleanly, stall diagnostics."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        SemanticHistoryPredictor, make_policy)
from repro.models import build_model
from repro.serving import (EngineStallError, RequestState, ServeRequest,
                           ServingEngine)
from repro.simulator import (NodeKill, NodeSlow, generate_workload,
                             make_profile, simulate_cluster)
from repro.testing import (FlakyPredictor, PredictorUnavailable, VirtualClock,
                           assert_engine_quiesced, inject_kv_fault)

CFG = get_config("llama3.2-1b", reduced=True)


def _engine(n_slots=2, predictor=None, policy="fcfs", **kw):
    sched = (Scheduler(policy=make_policy(policy), predictor=predictor)
             if predictor is not None
             else Scheduler(policy=make_policy(policy)))
    return ServingEngine(model=build_model(CFG), scheduler=sched,
                         n_slots=n_slots, max_seq_len=96, seed=0,
                         clock=VirtualClock(), **kw)


def _req(i, prompt="p", max_new=6, n_prompt=6, **kw):
    rng = np.random.default_rng(i)
    toks = [int(t) for t in rng.integers(3, CFG.vocab_size, n_prompt)]
    return ServeRequest(request_id=f"f{i}", prompt=prompt,
                        prompt_tokens=toks, max_new_tokens=max_new,
                        eos_token=0, **kw)


def _swap_engine():
    """Tight-capacity swap-mode engine stepped until some request is
    observably parked in SWAPPED state (capacity-forced preemption)."""
    o = OraclePredictor()
    for i in range(6):
        o.register(f"p{i}", LengthDistribution(np.array([8 + 3 * i]),
                                               np.array([1.0])))
    eng = ServingEngine(
        model=build_model(CFG),
        scheduler=Scheduler(policy=make_policy("sagesched"), predictor=o),
        n_slots=2, max_seq_len=96, capacity_tokens=56, block_size=8,
        preemption_mode="swap", seed=0, clock=VirtualClock())
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(6):
        toks = [int(t) for t in rng.integers(3, CFG.vocab_size,
                                             int(rng.integers(6, 14)))]
        reqs.append(ServeRequest(
            request_id=f"f{i}", prompt=f"p{i}", prompt_tokens=toks,
            max_new_tokens=8 + 3 * i, eos_token=1, arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    swapped = None
    for _ in range(200):
        eng.step()
        swapped = next((r for r in reqs
                        if r.state == RequestState.SWAPPED), None)
        if swapped is not None:
            break
    assert swapped is not None, "scenario must park a request in SWAPPED"
    return eng, swapped, reqs


# --------------------------------------- satellite 1: abort leaks nothing

def test_abort_waiting_request_releases_everything():
    eng = _engine(n_slots=1)
    reqs = [_req(i, max_new=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    assert all(r.state == RequestState.WAITING for r in reqs)
    eng.abort("f2", reason="client_cancel")
    assert reqs[2].state == RequestState.ABORTED
    assert reqs[2].finish_reason == "client_cancel"
    eng.kv.assert_conserved()
    eng.run_until_done(max_steps=500)
    assert_engine_quiesced(eng)
    assert eng.kv.free_slots == 1 and eng.kv.used_tokens == 0


def test_abort_running_request_releases_everything():
    eng = _engine(n_slots=2)
    reqs = [_req(i, max_new=32) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert any(r.state == RequestState.RUNNING for r in reqs)
    running = next(r for r in reqs if r.state == RequestState.RUNNING)
    eng.abort(running.request_id)
    eng.kv.assert_conserved()
    assert not eng.kv.holds(running.request_id)
    eng.run_until_done(max_steps=2000)
    assert_engine_quiesced(eng)
    assert eng.kv.used_tokens == 0 and eng.kv.swapped_tokens == 0
    assert eng.metrics.aborted == 1


def test_abort_swapped_request_releases_host_payload():
    eng, swapped, reqs = _swap_engine()
    rid = swapped.request_id
    assert eng.kv.is_swapped(rid) and eng.kv.swapped_tokens > 0
    eng.abort(rid, reason="client_cancel")
    assert not eng.kv.is_swapped(rid)
    assert eng.metrics.wasted_tokens >= swapped.generated > 0
    eng.kv.assert_conserved()
    eng.run_until_done(max_steps=2000)
    assert all(r.state == RequestState.FINISHED
               for r in reqs if r is not swapped)
    assert_engine_quiesced(eng)
    assert eng.kv.used_tokens == 0 and eng.kv.swapped_tokens == 0
    assert eng.kv.free_slots == 2


def test_abort_mid_chunked_prefill_releases_everything():
    eng = _engine(n_slots=1, prefill_chunk=4)
    r = _req(0, max_new=8, n_prompt=14)
    eng.submit(r)
    eng.step()
    assert 0 < r.prefill_pos < len(r.prompt_tokens)   # mid-prefill
    eng.abort("f0")
    eng.kv.assert_conserved()
    assert eng.kv.used_tokens == 0 and eng.kv.free_slots == 1
    assert r.prefill_pos == 0
    assert_engine_quiesced(eng)


def test_abort_terminal_states_is_idempotent():
    eng = _engine(n_slots=1)
    r = _req(0, max_new=4)
    eng.submit(r)
    eng.run_until_done(max_steps=500)
    assert r.state == RequestState.FINISHED
    before = (eng.metrics.aborted, r.finish_reason)
    eng.abort("f0")                    # FINISHED: no-op
    eng.abort("f0")                    # double-abort: no-op
    assert (eng.metrics.aborted, r.finish_reason) == before
    eng.kv.assert_conserved()


# ------------------------------- satellite 2: stall raises with diagnosis

def test_run_until_done_exhaustion_raises_diagnostic():
    eng = _engine(n_slots=1)
    eng.submit(_req(0, max_new=40))
    with pytest.raises(EngineStallError) as ei:
        eng.run_until_done(max_steps=1)
    msg = str(ei.value)
    assert "step budget (1)" in msg
    assert "request_states" in msg and "queue_depth" in msg
    assert "conservation" in msg or "free_blocks" in msg
    # the engine is still coherent and can finish afterwards
    eng.run_until_done(max_steps=2000)
    assert_engine_quiesced(eng)


# ------------------------------------------ injected KV-plane faults

def test_swap_in_fault_falls_back_to_recompute():
    eng, swapped, reqs = _swap_engine()
    with inject_kv_fault(eng.kv, "swap_in", at_call=0, n_calls=1) as stats:
        eng.run_until_done(max_steps=2000)
    assert stats["faults"] == 1
    assert eng.metrics.swap_in_faults == 1
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert_engine_quiesced(eng)


def test_grow_fault_is_absorbed_by_pressure_relief():
    eng = _engine(n_slots=2)
    reqs = [_req(i, max_new=24, n_prompt=10) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    with inject_kv_fault(eng.kv, "grow", at_call=2, n_calls=3) as stats:
        eng.run_until_done(max_steps=4000)
    assert stats["faults"] >= 1
    assert_engine_quiesced(eng)
    assert eng.kv.used_tokens == 0


def test_inject_kv_fault_restores_method():
    eng = _engine(n_slots=1)
    orig = eng.kv.swap_in
    with pytest.raises(RuntimeError):
        with inject_kv_fault(eng.kv, "swap_in"):
            eng.kv.swap_in("nope")
    assert eng.kv.swap_in == orig      # bound method re-exposed


# ------------------------------------------ predictor faults / degraded

def test_flaky_predictor_modes():
    inner = OraclePredictor()
    inner.register("a", LengthDistribution(np.array([10]), np.array([1.0])))
    inner.register("b", LengthDistribution(np.array([100]), np.array([1.0])))
    out = FlakyPredictor(inner, mode="outage", fail_after=1)
    assert out.predict("a", 8).mean == 10.0
    with pytest.raises(PredictorUnavailable):
        out.predict("a", 8)
    corrupt = FlakyPredictor(inner, mode="corrupt", corrupt_scale=16.0)
    d = corrupt.predict("a", 8)
    assert d.lengths.tolist() == [160] and corrupt.faults == 1
    stale = FlakyPredictor(inner, mode="stale", fail_after=1)
    assert stale.predict("a", 8).mean == 10.0
    assert stale.predict("b", 8).mean == 10.0   # replays the first answer


def test_scheduler_degrades_and_recovers_on_predictor_outage():
    flaky = FlakyPredictor(SemanticHistoryPredictor(), mode="outage",
                           fail_after=0, n_failures=1)
    sched = Scheduler(policy=make_policy("sagesched"), predictor=flaky)
    # the single outage raises out of the whole batched predict: BOTH
    # admissions fall back to the prediction-free prior
    sched.admit_batch(["d0", "d1"], ["p0", "p1"], [8, 8],
                      arrivals=[0.0, 0.0])
    assert sched.degraded
    assert sched.stats["prediction_failures"] == 2
    assert sched.order(["d0", "d1"])           # still schedulable
    # exit hysteresis (default degraded_exit_successes=4): a single
    # healthy prediction must NOT flap the flag back...
    sched.admit("d2", "p2", 8, arrival=0.1)    # window over: healthy again
    assert sched.degraded
    # ...but a streak of clean calls does (a batch of m counts m)
    sched.admit_batch(["d3", "d4", "d5"], ["p3", "p4", "p5"], [8, 8, 8],
                      arrivals=[0.2, 0.2, 0.2])
    assert not sched.degraded
    # a fresh failure resets the streak
    flaky2 = FlakyPredictor(SemanticHistoryPredictor(), mode="outage",
                            fail_after=0, n_failures=1)
    sched2 = Scheduler(policy=make_policy("sagesched"), predictor=flaky2,
                       degraded_exit_successes=2)
    sched2.admit("e0", "p0", 8, arrival=0.0)
    assert sched2.degraded
    sched2.admit("e1", "p1", 8, arrival=0.1)
    assert sched2.degraded                     # streak 1 < 2
    sched2.admit("e2", "p2", 8, arrival=0.2)
    assert not sched2.degraded                 # streak 2 >= 2


# --------------------------------------------- cluster node kill / slow

PROFILES = [make_profile("sharegpt", n_clusters=4, seed=1)]


def _workload(n=40, rps=10.0, seed=3):
    return generate_workload(PROFILES, n, rps=rps, seed=seed)


def test_cluster_without_faults_is_bit_identical():
    reqs = _workload()
    a = simulate_cluster(reqs, lambda: Scheduler(), 3)
    b = simulate_cluster(reqs, lambda: Scheduler(), 3, faults=[])
    ka = sorted((m.request_id, m.ttft, m.ttlt, m.node_id)
                for m in a.metrics)
    kb = sorted((m.request_id, m.ttft, m.ttlt, m.node_id)
                for m in b.metrics)
    assert ka == kb and b.migrated == 0 and b.aborted == []


def test_cluster_node_kill_reroutes_without_dangling_rows():
    reqs = _workload()
    created = []

    def factory():
        created.append(Scheduler())
        return created[-1]

    res = simulate_cluster(reqs, factory, 3, faults=[NodeKill(1, at=1.0)])
    accounted = {m.request_id for m in res.metrics} | set(res.aborted)
    assert accounted == {r.request_id for r in reqs}
    assert res.migrated > 0 and res.aborted == []
    # shared BatchState fully drained: no node_id row dangles post-kill
    assert len(created) == 1 and len(created[0]) == 0
    # the dead node completed nothing after the kill instant
    for m in res.node_results[1].metrics:
        assert m.arrival + m.ttlt <= 1.0 + 1e-9
    # migrated requests landed on surviving nodes
    assert all(m.node_id != 1 for m in res.metrics
               if m.arrival + m.ttlt > 1.0 + 1e-9)


def test_cluster_node_kill_cost_router_parity_of_accounting():
    reqs = _workload()
    for shared in (True, False):
        res = simulate_cluster(reqs, lambda: Scheduler(), 3, router="cost",
                               shared_state=shared,
                               faults=[NodeKill(2, at=1.2)])
        accounted = {m.request_id for m in res.metrics} | set(res.aborted)
        assert accounted == {r.request_id for r in reqs}


def test_cluster_slow_node_degrades_latency():
    reqs = _workload()
    base = simulate_cluster(reqs, lambda: Scheduler(), 2)
    slow = simulate_cluster(reqs, lambda: Scheduler(), 2,
                            faults=[NodeSlow(0, at=0.5, factor=8.0)])
    assert len(slow.metrics) == len(base.metrics)
    assert slow.mean_ttlt > base.mean_ttlt


def test_cluster_total_outage_aborts_everything():
    reqs = _workload(n=20)
    res = simulate_cluster(reqs, lambda: Scheduler(), 2,
                           faults=[NodeKill(0, at=0.4), NodeKill(1, at=0.5)])
    assert set(res.aborted) | {m.request_id for m in res.metrics} \
        == {r.request_id for r in reqs}
    assert len(res.aborted) > 0


# ----------------------------------------------- workload burst overload

def test_workload_burst_factor_one_is_seed_identical():
    a = generate_workload(PROFILES, 50, rps=5.0, seed=7)
    b = generate_workload(PROFILES, 50, rps=5.0, seed=7, burst_factor=1.0)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert [r.prompt for r in a] == [r.prompt for r in b]


def test_workload_bursts_compress_arrivals():
    base = generate_workload(PROFILES, 200, rps=5.0, seed=7)
    burst = generate_workload(PROFILES, 200, rps=5.0, seed=7,
                              burst_factor=10.0, burst_period_s=10.0,
                              burst_duty=0.5)
    assert burst[-1].arrival < base[-1].arrival  # same n arrives sooner


# ------------------------------------------------------------- clock

def test_virtual_clock_is_monotonic():
    clk = VirtualClock(start=2.0)
    assert clk() == 2.0
    assert clk.advance(0.5) == 2.5
    with pytest.raises(ValueError):
        clk.advance(-0.1)
