"""Property-based allocator fuzz for the copy-on-write paged KV layer.

A random interleaving of every externally reachable ``KVCacheManager``
mutation — allocate / allocate_shared (via ``match_prefix``, mirroring
the engine's admission path) / grow / fork_block / swap_out / swap_in /
release / drop_swapped — runs against a deliberately tiny pool, and
after EVERY operation the full invariant bundle is asserted:

  * block conservation — every physical block is in exactly one of
    {free, cached, referenced}, and the three partitions sum to the
    pool (``assert_conserved``);
  * refcounts equal live readers — the per-block refcount map is
    recomputed from the allocations' block tables and must match;
  * the scratch block (physical 0) never enters any partition;
  * the prefix index equals a from-scratch rebuild over per-block
    content tags (``check_prefix_index``) — no stale or missing
    entries after any eviction / fork / swap interleaving;
  * owned (refcount-weighted) blocks sum exactly to distinct used
    blocks, and each allocation's block table length matches
    ``blocks_for`` of its token count.

Prompts are drawn from a handful of shared base pools so random
sequences collide on prefixes constantly — the interesting regime.

Scaling & reproduction
----------------------
``REPRO_FUZZ_EXAMPLES`` sets the example count (default 200 — the CI
floor; the nightly workflow runs 2000).  On failure the harness raises
with the exact operation list embedded, and the hypothesis stub prints
``REPRO_HYPOTHESIS_SEED=<seed>`` — export it to replay only the failing
example:

    REPRO_HYPOTHESIS_SEED=123456789 pytest tests/test_kv_fuzz.py -x
"""

from __future__ import annotations

import itertools
import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.kv_cache import SCRATCH_BLOCK, KVCacheManager

MAX_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "200"))

BLOCK = 8
KV_PARAMS = dict(n_slots=4, max_seq_len=96, capacity_tokens=20 * BLOCK,
                 block_size=BLOCK, swap_capacity_tokens=24 * BLOCK)

# three shared base prompts: random cuts of these collide on block
# boundaries, exercising the prefix index far more than fresh prompts
BASES = [[1000 * k + j for j in range(96)] for k in range(3)]

OPS = ("alloc", "grow", "fork", "swap_out", "swap_in", "free",
       "drop_swapped")


@st.composite
def op_sequences(draw):
    n = draw(st.integers(min_value=4, max_value=40))
    ops = []
    for _ in range(n):
        ops.append((draw(st.sampled_from(OPS)),
                    draw(st.integers(min_value=0, max_value=2)),
                    draw(st.integers(min_value=0, max_value=7)),
                    draw(st.integers(min_value=1, max_value=18))))
    return ops


def _check(kv: KVCacheManager) -> None:
    """The per-operation invariant bundle."""
    kv.assert_conserved()            # conservation + refcounts + scratch
    kv.check_prefix_index()          # rebuilt index == incremental index
    assert abs(kv.owned_blocks - kv.used_blocks) < 1e-9, \
        "refcount-weighted ownership does not sum to used blocks"
    for rid in list(kv._held):
        a = kv._held[rid]
        assert len(a.blocks) == kv.blocks_for(a.tokens)
        assert SCRATCH_BLOCK not in a.blocks


def run_ops(ops) -> None:
    """Interpret one drawn operation sequence against a fresh manager,
    checking invariants after every step.  Operations whose
    preconditions don't hold (pool exhausted, nothing to act on) are
    no-ops — the manager must refuse them without partial mutation."""
    kv = KVCacheManager(**KV_PARAMS)
    live: list[str] = []
    swapped: list[str] = []
    rid_seq = itertools.count()
    fresh = itertools.count(10**6)   # never collides with base tokens

    for kind, base_idx, sel, amount in ops:
        if kind == "alloc" and kv.free_slots:
            cut = (sel % 8) * BLOCK
            prompt = BASES[base_idx][:cut] + [next(fresh)
                                              for _ in range(amount)]
            prompt = prompt[:KV_PARAMS["max_seq_len"] - BLOCK]
            rid = f"r{next(rid_seq)}"
            matched, blocks, hashes = kv.match_prefix(prompt)
            # engine-style cap: the block holding the final prompt
            # position stays private (decode re-writes that position)
            k = min(len(blocks), max(0, (len(prompt) - 1) // BLOCK))
            try:
                kv.allocate_shared(rid, len(prompt), blocks[:k],
                                   hashes[:k])
            except RuntimeError:     # pool exhausted: refused atomically
                _check(kv)
                continue
            kv.register_prefix(rid, prompt)
            live.append(rid)
        elif kind == "grow" and live:
            kv.grow(live[sel % len(live)], amount)
        elif kind == "fork" and live:
            rid = live[sel % len(live)]
            idx = sel % len(kv._held[rid].blocks)
            try:
                kv.fork_block(rid, idx)
            except RuntimeError:
                pass                 # no reclaimable block for the copy
        elif kind == "swap_out" and live:
            rid = live[sel % len(live)]
            if kv.can_swap_out(rid):
                kv.swap_out(rid, payload={"rid": rid})
                live.remove(rid)
                swapped.append(rid)
        elif kind == "swap_in" and swapped:
            rid = swapped[sel % len(swapped)]
            try:
                slot, payload = kv.swap_in(rid)
            except RuntimeError:     # no slot / no blocks: refused
                _check(kv)
                continue
            assert payload == {"rid": rid}
            swapped.remove(rid)
            live.append(rid)
        elif kind == "free" and live:
            kv.release(live.pop(sel % len(live)))
        elif kind == "drop_swapped" and swapped:
            kv.drop_swapped(swapped.pop(sel % len(swapped)))
        _check(kv)

    # drain: every path back to an empty manager must conserve too
    for rid in list(live):
        kv.release(rid)
        _check(kv)
    for rid in list(swapped):
        kv.drop_swapped(rid)
        _check(kv)
    assert kv.used_blocks == 0 and not kv.live_refcounts()
    assert kv.free_blocks == kv.n_blocks   # cached blocks still count


@settings(max_examples=MAX_EXAMPLES, deadline=None)
@given(ops=op_sequences())
def test_allocator_fuzz(ops):
    try:
        run_ops(ops)
    except Exception as e:  # embed the program for replay anywhere
        raise AssertionError(
            f"allocator fuzz violated an invariant: {e}\n"
            f"failing op sequence (feed to run_ops to replay):\n"
            f"{ops!r}") from e


# ------------------------------------------------------ deterministic
# Pinned scenarios for the properties the fuzz asserts statistically.


def _mgr(**over):
    return KVCacheManager(**{**KV_PARAMS, **over})


def _admit(kv, rid, prompt):
    """Engine-style admission: match, adopt, register."""
    matched, blocks, hashes = kv.match_prefix(prompt)
    k = min(len(blocks), max(0, (len(prompt) - 1) // kv.block_size))
    kv.allocate_shared(rid, len(prompt), blocks[:k], hashes[:k])
    kv.register_prefix(rid, prompt)
    return k


def test_refcounts_equal_readers():
    kv = _mgr()
    prompt = BASES[0][:3 * BLOCK]            # 3 full blocks
    assert _admit(kv, "a", prompt) == 0      # first writer: nothing shared
    for rid in ("b", "c"):
        assert _admit(kv, rid, prompt) == 2  # last block stays private
    shared = kv._held["a"].blocks[:2]
    assert all(kv.refcount_of(b) == 3 for b in shared)
    # 3 private tails + 2 shared blocks distinct; ownership sums exactly
    assert kv.used_blocks == 5
    assert abs(kv.owned_blocks - 5.0) < 1e-9
    assert kv.shared_excess_blocks("b") == pytest.approx(2 * (1 - 1 / 3))
    _check(kv)


def test_fork_block_gives_private_copy():
    kv = _mgr()
    prompt = BASES[0][:3 * BLOCK]
    _admit(kv, "a", prompt)
    _admit(kv, "b", prompt)
    old = kv._held["b"].blocks[0]
    assert kv.refcount_of(old) == 2
    pair = kv.fork_block("b", 0)
    assert pair is not None and pair[0] == old
    assert kv.refcount_of(old) == 1 and kv.refcount_of(pair[1]) == 1
    assert kv._held["b"].hashes == []        # published chain truncated
    _check(kv)
    assert kv.fork_block("b", 0) is None     # already private


def test_cached_tier_survives_release():
    kv = _mgr()
    prompt = BASES[1][:4 * BLOCK]
    _admit(kv, "a", prompt)
    kv.release("a")
    # indexed blocks park in the cached tier, still reclaimable
    assert kv.cached_blocks == 3
    assert kv.free_blocks == kv.n_blocks
    used_before = kv.used_blocks
    assert _admit(kv, "b", prompt) == 3      # re-adopted, not re-filled
    assert kv.adopted_blocks_of("b") == 3
    assert kv.used_blocks == used_before + 4
    _check(kv)


def test_swap_preserves_share_structure():
    kv = _mgr()
    prompt = BASES[2][:4 * BLOCK]
    _admit(kv, "a", prompt)
    _admit(kv, "b", prompt)
    kv.swap_out("b", payload={"k": "payload-b"})
    _check(kv)
    slot, payload = kv.swap_in("b")
    assert payload == {"k": "payload-b"}
    # the shared prefix was still resident (held by "a"): re-adopted
    assert kv.adopted_blocks_of("b") == 3
    assert kv._held["b"].blocks[:3] == kv._held["a"].blocks[:3]
    _check(kv)


def test_corrupted_refcount_trips_conservation():
    kv = _mgr()
    _admit(kv, "a", BASES[0][:2 * BLOCK])
    kv._ref[kv._held["a"].blocks[0]] += 1    # simulate a leaked reference
    with pytest.raises(RuntimeError, match="refcounts"):
        kv.assert_conserved()


def test_allocate_shared_validates_inputs():
    kv = _mgr()
    with pytest.raises(ValueError, match="length mismatch"):
        kv.allocate_shared("a", 16, [1], [])
    with pytest.raises(ValueError, match="longer than the context"):
        kv.allocate_shared("a", 8, [1, 2], [11, 22])
    with pytest.raises(ValueError, match="block_size"):
        KVCacheManager(n_slots=1, max_seq_len=8, block_size=0)


def test_register_prefix_rejects_divergent_chain():
    kv = _mgr()
    prompt = BASES[0][:3 * BLOCK]
    _admit(kv, "a", prompt)
    _admit(kv, "b", prompt)          # b records a's chain at adoption
    divergent = BASES[1][:3 * BLOCK]
    with pytest.raises(RuntimeError, match="diverged"):
        kv.register_prefix("b", divergent)


def test_corrupted_index_trips_rebuild_check():
    kv = _mgr()
    _admit(kv, "a", BASES[0][:3 * BLOCK])
    kv._index[999999] = kv._held["a"].blocks[0]   # stale phantom entry
    with pytest.raises(RuntimeError, match="drifted"):
        kv.check_prefix_index()


def test_corrupted_ledgers_trip_conservation():
    kv = _mgr()
    _admit(kv, "a", BASES[0][:2 * BLOCK])
    b = kv._held["a"].blocks[0]
    kv._free_blocks.append(b)                     # free AND referenced
    with pytest.raises(RuntimeError, match="free and referenced"):
        kv.assert_conserved()
    kv._free_blocks.pop()
    kv._free_blocks.append(SCRATCH_BLOCK)         # scratch leaked in
    with pytest.raises(RuntimeError, match="scratch"):
        kv.assert_conserved()
    kv._free_blocks.pop()
    kv._free_slots.append(kv._held["a"].slot)     # slot double-booked
    with pytest.raises(RuntimeError, match="slot ledger"):
        kv.assert_conserved()


def test_pool_exhaustion_is_refused_atomically():
    kv = _mgr(n_slots=8, capacity_tokens=4 * BLOCK)
    prompt = BASES[0][:3 * BLOCK]
    _admit(kv, "a", prompt)
    _admit(kv, "b", prompt)          # 2 shared + 2 private tails: full
    assert kv.free_blocks == 0
    with pytest.raises(RuntimeError, match="no free blocks"):
        kv.allocate("c", BLOCK)
    with pytest.raises(RuntimeError, match="no free blocks"):
        kv.fork_block("b", 0)        # CoW copy needs a reclaimable block
    assert not kv.grow("a", BLOCK)   # refused, no partial mutation
    _check(kv)
    assert not kv.can_admit(BLOCK)
    # duplicate-id and missing-slot guards
    with pytest.raises(KeyError):
        kv.allocate("a", BLOCK)
    kv.swap_out("a")
    assert kv.can_swap_in("a") or not kv.can_swap_in("a")  # well-defined
    _check(kv)


def test_swap_pool_capacity_enforced():
    kv = _mgr(swap_capacity_tokens=2 * BLOCK)
    _admit(kv, "a", BASES[0][:2 * BLOCK])
    _admit(kv, "b", BASES[1][:2 * BLOCK])
    kv.swap_out("a")                 # fills the 2-block host pool
    assert not kv.can_swap_out("b")
    with pytest.raises(RuntimeError, match="host swap pool full"):
        kv.swap_out("b")
    _check(kv)
    # swap_in with every slot taken is refused atomically
    kv2 = _mgr(n_slots=1)
    _admit(kv2, "x", BASES[0][:2 * BLOCK])
    kv2.swap_out("x")
    _admit(kv2, "y", BASES[1][:2 * BLOCK])
    with pytest.raises(RuntimeError, match="no free slots"):
        kv2.swap_in("x")
    _check(kv2)


def test_grow_upto_grants_partial():
    kv = _mgr(n_slots=2, capacity_tokens=4 * BLOCK, max_seq_len=96)
    kv.allocate("a", 2 * BLOCK)
    # 2 blocks left: a 3-block ask is granted up to the pool edge
    granted = kv.grow_upto("a", 3 * BLOCK)
    assert granted == 2 * BLOCK
    assert kv.free_blocks == 0
    _check(kv)


def test_no_free_slots_refused():
    kv = _mgr(n_slots=1)
    kv.allocate("a", BLOCK)
    with pytest.raises(RuntimeError, match="no free slots"):
        kv.allocate("b", BLOCK)
    with pytest.raises(RuntimeError, match="no free slots"):
        kv.allocate_shared("b", BLOCK, [], [])
    with pytest.raises(KeyError):
        kv.allocate_shared("a", BLOCK, [], [])   # duplicate id
    assert not kv.can_admit(BLOCK)


def test_accounting_accessors():
    kv = _mgr()
    _admit(kv, "a", BASES[0][:2 * BLOCK + 3])    # partial last block
    assert kv.slot_of("a") == kv._held["a"].slot
    assert kv.block_table("a") == kv._held["a"].blocks
    assert kv.used_tokens == 2 * BLOCK + 3
    assert kv.frag_tokens == BLOCK - 3
    assert kv.tokens_of("a") == 2 * BLOCK + 3
    assert kv.admission_budget_tokens == kv.budget_blocks * BLOCK
    assert kv.pool_blocks == kv.n_blocks + 1
    assert kv.blocks_for(0) == 1                 # a request pins >= 1
    kv.swap_out("a")
    assert kv.swapped_tokens == 2 * BLOCK + 3
    assert kv.swapped_tokens_of("a") == 2 * BLOCK + 3
    assert kv.is_swapped("a") and not kv.holds("a")
    snap = kv.conservation()
    assert snap["swapped_blocks"] == 3 and snap["held_blocks"] == 0
