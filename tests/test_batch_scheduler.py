"""Array-native scheduler hot path: batch/scalar parity + BatchState.

Covers the PR-1 acceptance criteria:
  * every policy's ``priority_batch`` matches its scalar ``priority`` to
    1e-6 over random distributions and attained costs (numpy backend is
    in fact bit-identical; pallas is float32-close),
  * a full NodeSimulator run produces identical SimResult metrics under
    the object oracle and the batched numpy backend,
  * BatchState bookkeeping (swap-remove, column growth, bucketize),
  * the LengthDistribution.quantile clip fix.
"""

import zlib

import numpy as np
import pytest

from repro.core import (BatchView, LengthDistribution, NumpyPriorityBackend,
                        POLICY_NAMES, Predictor, ResourceBoundCost,
                        Scheduler, bucketize_support, gittins_index,
                        gittins_index_batch, make_policy)
from repro.core.cost_model import CostDistribution
from repro.simulator import generate_workload, make_profile, simulate

RNG = np.random.default_rng(0)


def random_length_dist(rng, max_k=24, max_len=4000) -> LengthDistribution:
    k = int(rng.integers(1, max_k + 1))
    lens = np.sort(rng.choice(np.arange(1, max_len), k, replace=False))
    return LengthDistribution(lens, rng.dirichlet(np.ones(k)))


class PooledPredictor(Predictor):
    """Deterministic, embedding-free predictor: prompt -> pooled dist."""

    def __init__(self, pool=64, seed=0):
        rng = np.random.default_rng(seed)
        self.dists = [random_length_dist(rng) for _ in range(pool)]

    def predict(self, prompt, input_len):
        return self.dists[zlib.crc32(prompt.encode()) % len(self.dists)]


def build_pair(policy_name, n=60, bucket_size=50, seed=3):
    """Two schedulers (object oracle, numpy batch) fed identical
    admissions and progress."""
    rng = np.random.default_rng(seed)
    scheds = [Scheduler(policy=make_policy(policy_name),
                        predictor=PooledPredictor(seed=seed),
                        cost_model=ResourceBoundCost(),
                        bucket_size=bucket_size, priority_backend=b)
              for b in ("object", "numpy")]
    for i in range(n):
        il = int(rng.integers(1, 2000))
        for s in scheds:
            s.admit(f"r{i}", f"prompt-{i % 17}", il, arrival=float(i))
    for i in range(n):
        g = int(rng.integers(0, 600))
        for s in scheds:
            s.on_progress(f"r{i}", g)
    for s in scheds:
        s.set_now(float(n))
    return scheds


@pytest.mark.parametrize("policy_name", POLICY_NAMES)
def test_priority_batch_matches_scalar(policy_name):
    """Property: priority_batch == scalar priority to 1e-6 (bit-identical
    in practice for the numpy backend) for random dists/attained costs."""
    if getattr(make_policy(policy_name), "rank_based", False):
        pytest.skip("rank-based policies have no scalar oracle "
                    "(object backend is rejected); covered by "
                    "tests/test_robust.py order oracles")
    obj, bat = build_pair(policy_name)
    ids = [f"r{i}" for i in range(len(obj))]
    p_obj = np.array([obj.get(r).priority for r in ids])
    p_bat = np.array([bat.get(r).priority for r in ids])
    np.testing.assert_allclose(p_bat, p_obj, rtol=1e-6, atol=1e-9)
    assert obj.order() == bat.order()


@pytest.mark.parametrize("policy_name", POLICY_NAMES)
def test_priority_batch_direct_view(policy_name):
    """priority_batch evaluated straight on a BatchView equals the scalar
    oracle on matching ScheduledRequest state."""
    from repro.core.scheduler import ScheduledRequest
    pol = make_policy(policy_name)
    if getattr(pol, "rank_based", False):
        pytest.skip("rank-based policies have no scalar priority; "
                    "covered by tests/test_robust.py order oracles")
    if hasattr(pol, "now"):
        pol.now = 500.0
    rng = np.random.default_rng(11)
    cm = ResourceBoundCost()
    n, k = 40, 32
    rows, srs = [], []
    for i in range(n):
        ld = random_length_dist(rng)
        cd = cm.distribution(int(rng.integers(1, 1000)),
                             ld.lengths, ld.probs)
        g = int(rng.integers(0, 800))
        il = int(rng.integers(1, 1000))
        att = cm.attained(il, g) if rng.random() < 0.7 else 0.0
        sr = ScheduledRequest(request_id=f"r{i}", prompt="p", input_len=il,
                              arrival=float(i), length_dist=ld, cost_dist=cd,
                              generated=g, attained_cost=att)
        srs.append(sr)
        rows.append((cd, ld, g, att, il))
    cost_sup = np.stack([bucketize_support(cd.support, cd.probs, k)[0]
                         for cd, *_ in rows])
    cost_probs = np.stack([bucketize_support(cd.support, cd.probs, k)[1]
                           for cd, *_ in rows])
    len_sup = np.stack([bucketize_support(
        ld.lengths.astype(np.float64), ld.probs, k)[0]
        for _, ld, *_ in rows])
    len_probs = np.stack([bucketize_support(
        ld.lengths.astype(np.float64), ld.probs, k)[1]
        for _, ld, *_ in rows])
    view = BatchView(
        cost_sup=cost_sup, cost_probs=cost_probs,
        len_sup=len_sup, len_probs=len_probs,
        generated=np.array([r[2] for r in rows], np.int64),
        attained=np.array([r[3] for r in rows]),
        arrival=np.arange(n, dtype=np.float64),
        input_len=np.array([r[4] for r in rows], np.int64))
    if not pol.has_batch:
        pytest.skip("policy has no batch path")
    got = pol.priority_batch(view, NumpyPriorityBackend())
    want = np.array([pol.priority(sr) for sr in srs])
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-9)


@pytest.mark.parametrize("policy_name",
                         ["sagesched", "sagesched_aged", "mean", "trail",
                          "fastserve"])
def test_simulator_end_to_end_identical(policy_name):
    """Full NodeSimulator runs are *identical* (not just close) between
    the object oracle and the batched numpy backend."""
    profiles = [make_profile(n) for n in ("sharegpt", "alpaca")]
    reqs = generate_workload(profiles, 300, rps=10.0, seed=5)

    def run(backend):
        sched = Scheduler(policy=make_policy(policy_name),
                          predictor=PooledPredictor(seed=1),
                          cost_model=ResourceBoundCost(),
                          priority_backend=backend)
        return simulate(reqs, sched)

    a, b = run("object"), run("numpy")
    assert a.makespan == b.makespan
    assert a.n_iterations == b.n_iterations
    assert a.n_preemptions == b.n_preemptions
    assert a.n_evictions == b.n_evictions
    assert a.scheduler_stats == b.scheduler_stats
    for m1, m2 in zip(a.metrics, b.metrics):
        assert m1.request_id == m2.request_id
        assert m1.ttft == m2.ttft and m1.ttlt == m2.ttlt
        assert m1.n_preemptions == m2.n_preemptions


def test_simulator_1k_seeded_bit_identical():
    """The acceptance-criterion workload: 1k seeded requests, sagesched,
    object vs numpy — bit-identical mean TTLT/TTFT and preemptions."""
    profiles = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]
    reqs = generate_workload(profiles, 1000, rps=8.0, seed=7)

    def run(backend):
        sched = Scheduler(policy=make_policy("sagesched"),
                          predictor=PooledPredictor(seed=2),
                          cost_model=ResourceBoundCost(),
                          priority_backend=backend)
        return simulate(reqs, sched)

    a, b = run("object"), run("numpy")
    assert a.mean_ttlt() == b.mean_ttlt()
    assert a.mean_ttft() == b.mean_ttft()
    assert a.n_preemptions == b.n_preemptions
    assert a.scheduler_stats == b.scheduler_stats


# ------------------------------------------------------------- BatchState

def test_batchstate_swap_remove_and_growth():
    sched = Scheduler(predictor=PooledPredictor(), policy=make_policy(
        "sagesched"), priority_backend="numpy", batch_k=4)
    st = sched._state
    for i in range(100):  # forces row growth past cap=64 and col growth
        sched.admit(f"r{i}", f"prompt-{i}", 10 + i, arrival=float(i))
    assert st.n == 100
    assert st.k >= 4
    # removal swaps the last row in and keeps the index map consistent
    sched.on_complete("r3", 17)
    assert "r3" not in sched
    assert st.n == 99
    for rid, i in st.index.items():
        assert st.ids[i] == rid
        assert st.input_len[i] == sched._live[rid].input_len
    # ordering still matches the object oracle's semantics after churn
    ids = sched.order()
    pr = [sched.get(r).priority for r in ids]
    assert pr == sorted(pr)


def test_bucketize_pad_and_compress():
    sup = np.array([1.0, 5.0, 9.0])
    p = np.array([0.2, 0.5, 0.3])
    s2, p2 = bucketize_support(sup, p, 6)
    assert s2.shape == (6,)
    np.testing.assert_allclose(s2[:3], sup)
    np.testing.assert_allclose(s2[3:], 9.0)  # repeat-last pad
    np.testing.assert_allclose(p2[:3], p)
    assert (p2[3:] == 0).all()
    # padded and raw rows produce the same Gittins index
    g_raw = gittins_index(CostDistribution(sup, p), 3.0)
    g_pad = gittins_index_batch(s2[None], p2[None], np.array([3.0]))[0]
    assert g_raw == g_pad
    # compression: mass and mean are preserved, support non-decreasing
    rng = np.random.default_rng(4)
    sup_big = np.sort(rng.uniform(1, 1e4, 50))
    p_big = rng.dirichlet(np.ones(50))
    s3, p3 = bucketize_support(sup_big, p_big, 8)
    assert s3.shape == (8,) and (np.diff(s3) >= 0).all()
    assert p3.sum() == pytest.approx(1.0)
    assert (s3 * p3).sum() == pytest.approx((sup_big * p_big).sum(),
                                            rel=1e-9)


def test_on_progress_many_matches_scalar_calls():
    a, b = [Scheduler(predictor=PooledPredictor(), policy=make_policy(
        "sagesched"), priority_backend="numpy", bucket_size=50)
        for _ in range(2)]
    for i in range(30):
        for s in (a, b):
            s.admit(f"r{i}", f"p{i}", 5, arrival=float(i))
    gens = [int(g) for g in np.random.default_rng(1).integers(0, 300, 30)]
    for i, g in enumerate(gens):
        a.on_progress(f"r{i}", g)
    b.on_progress_many([f"r{i}" for i in range(30)], gens)
    assert a.order() == b.order()
    assert a.stats["refreshes"] == b.stats["refreshes"]


def test_gittins_index_batch_attained_matches_scalar():
    rng = np.random.default_rng(9)
    n, k = 64, 16
    sup = np.sort(rng.uniform(1, 1e5, (n, k)), axis=1)
    probs = rng.dirichlet(np.ones(k), n)
    att = rng.uniform(0, 1.2e5, n) * (rng.random(n) > 0.25)
    got = gittins_index_batch(sup, probs, att)
    for i in range(n):
        want = gittins_index(CostDistribution(sup[i], probs[i]),
                             float(att[i]))
        assert got[i] == want  # bit-identical by construction


def test_custom_policy_scalar_fallbacks():
    """A user policy with only scalar methods (no priority_batch, a
    custom next_boundary) must behave identically under the batched
    backend: the scheduler loops the scalar oracle with synced state."""
    from repro.core import Policy

    class HalfBucket(Policy):
        name = "halfbucket"
        preemptive = True
        refreshing = True

        def priority(self, sr):
            return float(sr.attained_cost + sr.generated)

        def next_boundary(self, sr, bucket_size):
            half = bucket_size // 2
            return (sr.generated // half + 1) * half

    results = []
    for backend in ("object", "numpy"):
        s = Scheduler(policy=HalfBucket(), predictor=PooledPredictor(seed=3),
                      bucket_size=100, priority_backend=backend)
        for i in range(12):
            s.admit(f"r{i}", f"p{i}", 10, arrival=float(i))
        for g in (60, 120):          # crosses the custom 50-boundaries
            for i in range(12):
                s.on_progress(f"r{i}", g)
            s.order()
        results.append((s.stats["refreshes"], s.order(),
                        [s.get(f"r{i}").next_refresh for i in range(12)],
                        [s.get(f"r{i}").priority for i in range(12)]))
    assert results[0] == results[1]


def test_subclass_scalar_override_beats_inherited_batch():
    """A subclass of a built-in policy that overrides only the scalar
    ``priority`` must NOT inherit the parent's priority_batch (it would
    silently disagree); the scheduler falls back to the scalar oracle."""
    from repro.core.policies import SageSchedPolicy

    class Tweaked(SageSchedPolicy):
        def priority(self, sr):
            return 2.0 * super().priority(sr) + sr.arrival

    assert not Tweaked().has_batch
    results = []
    for backend in ("object", "numpy"):
        s = Scheduler(policy=Tweaked(), predictor=PooledPredictor(seed=4),
                      bucket_size=50, priority_backend=backend)
        for i in range(10):
            s.admit(f"r{i}", f"p{i}", 20, arrival=float(i))
        for i in range(10):
            s.on_progress(f"r{i}", 120)
        results.append((s.order(),
                        [s.get(f"r{i}").priority for i in range(10)]))
    assert results[0] == results[1]


def test_scalar_only_time_varying_policy_ages_correctly():
    """A time-varying policy with only scalar methods must not have a
    stale admit-time base discounted by set_now: the scheduler loops the
    scalar oracle with synced attained/generated."""
    from repro.core import Policy

    class ScalarAged(Policy):
        name = "scalar_aged"
        preemptive = True
        refreshing = True
        time_varying = True

        def __init__(self):
            self.now = 0.0

        def priority(self, sr):
            return (sr.attained_cost + 1.0) / (1.0 + (self.now - sr.arrival))

        def apply_age(self, base, arrival, now):  # scalar-shaped helper
            return base / (1.0 + (now - arrival))

        def base_priority(self, sr):
            return sr.attained_cost + 1.0

    results = []
    for backend in ("object", "numpy"):
        s = Scheduler(policy=ScalarAged(), predictor=PooledPredictor(seed=4),
                      bucket_size=50, priority_backend=backend)
        for i in range(8):
            s.admit(f"r{i}", f"p{i}", 30, arrival=float(i))
        for i in range(8):
            s.on_progress(f"r{i}", 60 + 10 * i)
        s.order()            # drain dirtiness (updates attained)
        s.set_now(100.0)     # must re-age from FRESH attained costs
        results.append([s.get(f"r{i}").priority for i in range(8)])
    assert results[0] == results[1]


def test_pallas_backend_close_to_oracle():
    """The jitted Pallas backend (interpret mode on CPU) slots into the
    same protocol and lands within float32 tolerance of the oracle."""
    obj = Scheduler(policy=make_policy("sagesched"),
                    predictor=PooledPredictor(seed=6),
                    priority_backend="object", bucket_size=50)
    pal = Scheduler(policy=make_policy("sagesched"),
                    predictor=PooledPredictor(seed=6),
                    priority_backend="pallas", bucket_size=50)
    rng = np.random.default_rng(6)
    for i in range(40):
        il = int(rng.integers(1, 1500))
        obj.admit(f"r{i}", f"p{i % 9}", il, arrival=float(i))
        pal.admit(f"r{i}", f"p{i % 9}", il, arrival=float(i))
    for i in range(40):
        g = int(rng.integers(0, 400))
        obj.on_progress(f"r{i}", g)
        pal.on_progress(f"r{i}", g)
    pal.refresh()
    p_obj = np.array([obj.get(f"r{i}").priority for i in range(40)])
    p_pal = np.array([pal.get(f"r{i}").priority for i in range(40)])
    np.testing.assert_allclose(p_pal, p_obj, rtol=1e-4)


# --------------------------------------------------------- quantile clip

def test_quantile_clips_rounding_overflow():
    """cdf[-1] can round below q (e.g. seven 1/7 buckets); searchsorted
    then returns k — the index must clip instead of raising."""
    k = 7
    d = LengthDistribution(np.arange(1, k + 1),
                           np.full(k, 1.0 / k))
    assert float(np.cumsum(d.probs)[-1]) < 1.0  # the failure precondition
    assert d.quantile(1.0) == k                 # was: IndexError
    assert d.quantile(0.5) == 4
