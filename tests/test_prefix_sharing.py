"""Copy-on-write prefix sharing: differential parity + reuse accounting.

The load-bearing claim: ``prefix_sharing=True`` is a pure *cost*
optimization — for every servable model family, both preemption modes
and both step modes, the emitted token streams are bit-identical to the
sharing-off engine, while ``EngineMetrics.prefill_tokens_reused`` proves
real work was skipped.  Parity is constructive, not accidental: matches
are capped to the lcm(prefill_chunk, block_size) grid, so the resumed
chunked prefill lands on the exact absolute chunk boundaries a
from-scratch prefill would use (same per-chunk shapes -> same float
rounding -> same KV bits).  SSM/hybrid families cannot resume a prefill
mid-context, so sharing is inert for them — parity still holds with
zero reuse.

Also covered: session traffic through the Gateway front door, and the
simulator's node-level mirror of the same mechanism driven by
``generate_session_workload`` through ``simulate_cluster``.
"""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (LengthDistribution, OraclePredictor, Scheduler,
                        make_policy)
from repro.models import build_model
from repro.serving import (Gateway, GatewayConfig, RequestState,
                           ServeRequest, ServingEngine)
from repro.simulator import NodeSimulator, make_profile, simulate_cluster
from repro.simulator.workload import generate_session_workload
from repro.testing import VirtualClock, assert_engine_quiesced

FAMILIES = ["llama3.2-1b", "internvl2-76b", "olmoe-1b-7b", "mamba2-2.7b",
            "zamba2-1.2b"]
# families whose attention KV supports resuming a prefill mid-context —
# the only ones where sharing can actually skip work
KV_CHUNKED = {"llama3.2-1b", "internvl2-76b", "olmoe-1b-7b"}

PROFILES = [make_profile(n) for n in ("sharegpt", "alpaca", "write")]


def _run(arch, *, sharing, pmode="swap", step_mode="fused",
         temperature=0.0, n=4, n_slots=2, cap=96):
    """Run ``n`` requests sharing a 24-token base prefix to completion;
    returns (engine, per-request output token lists)."""
    cfg = get_config(arch, reduced=True)
    o = OraclePredictor()
    for i in range(n):
        o.register(f"p{i}", LengthDistribution(np.array([6 + 2 * i]),
                                               np.array([1.0])))
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("sagesched"), predictor=o),
        n_slots=n_slots, max_seq_len=96, capacity_tokens=cap,
        block_size=8, preemption_mode=pmode, prefill_chunk=16,
        seed=0, step_mode=step_mode, prefix_sharing=sharing)
    rng = np.random.default_rng(11)
    base = [int(t) for t in rng.integers(3, cfg.vocab_size, 24)]
    reqs = []
    for i in range(n):
        toks = base + [int(t) for t in rng.integers(3, cfg.vocab_size,
                                                    4 + i)]
        reqs.append(ServeRequest(f"r{i}", f"p{i}", toks,
                                 max_new_tokens=6 + 2 * i,
                                 temperature=temperature, eos_token=1,
                                 arrival=float(i) * 1e-3))
    eng.submit_batch(reqs)
    eng.run_until_done(max_steps=8000)
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert_engine_quiesced(eng)
    return eng, [r.output_tokens for r in reqs]


# ------------------------------------------------- differential parity

@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("pmode", ["swap", "recompute"])
def test_sharing_is_token_identical(arch, pmode):
    """The acceptance criterion: sharing ON == sharing OFF, bit for bit,
    for every family x preemption mode x step mode — while the reuse
    counter proves KV-chunked families actually skipped prefill work."""
    for step_mode in ("fused", "orchestrated"):
        off, want = _run(arch, sharing=False, pmode=pmode,
                         step_mode=step_mode)
        on, got = _run(arch, sharing=True, pmode=pmode,
                       step_mode=step_mode)
        assert got == want, f"{arch}/{pmode}/{step_mode} streams diverged"
        assert off.metrics.prefill_tokens_reused == 0
        if arch in KV_CHUNKED:
            assert on.metrics.prefill_tokens_reused > 0
            # reused tokens were not re-computed
            assert (on.metrics.prefill_tokens
                    + on.metrics.prefill_tokens_reused
                    == off.metrics.prefill_tokens)
        else:
            # recurrent state can't resume mid-context: sharing is inert
            assert on.metrics.prefill_tokens_reused == 0
            assert on.metrics.prefill_tokens == off.metrics.prefill_tokens


def test_sharing_parity_survives_stochastic_sampling():
    """Fused sampling is keyed by (request, position), never the slot or
    schedule, so parity holds even at temperature > 0 — where the two
    engines take different prefill paths."""
    on, got = _run("llama3.2-1b", sharing=True, temperature=0.7)
    _, want = _run("llama3.2-1b", sharing=False, temperature=0.7)
    assert got == want
    assert on.metrics.prefill_tokens_reused > 0


def test_sharing_parity_multi_tenant_prefixes():
    """Two distinct system prompts: matches never cross prefix chains
    (a wrong-chain adoption would corrupt tokens, so parity is the
    detector)."""
    cfg = get_config("llama3.2-1b", reduced=True)
    rng = np.random.default_rng(5)
    bases = [[int(t) for t in rng.integers(3, cfg.vocab_size, 24)]
             for _ in range(2)]

    def build(sharing):
        o = OraclePredictor()
        for i in range(6):
            o.register(f"p{i}", LengthDistribution(np.array([6]),
                                                   np.array([1.0])))
        eng = ServingEngine(
            model=build_model(cfg),
            scheduler=Scheduler(policy=make_policy("sagesched"),
                                predictor=o),
            n_slots=2, max_seq_len=96, capacity_tokens=128, block_size=8,
            prefill_chunk=16, seed=0, prefix_sharing=sharing)
        srng = np.random.default_rng(9)
        reqs = [ServeRequest(
            f"r{i}", f"p{i}",
            bases[i % 2] + [int(t) for t in srng.integers(
                3, cfg.vocab_size, 3 + i)],
            max_new_tokens=6, temperature=0.0, eos_token=1,
            arrival=float(i) * 1e-3) for i in range(6)]
        eng.submit_batch(reqs)
        eng.run_until_done(max_steps=8000)
        assert_engine_quiesced(eng)
        return eng, [r.output_tokens for r in reqs]

    _, want = build(False)
    on, got = build(True)
    assert got == want
    assert on.metrics.prefill_tokens_reused > 0


# ------------------------------------------------------- gateway path

def test_gateway_session_traffic_reuses_prefixes():
    """Shared-system-prompt tenants through the bounded front door: the
    engine under the Gateway adopts prefixes, every request terminates,
    and the quiesced-engine invariants (including the prefix-index
    rebuild) hold."""
    cfg = get_config("llama3.2-1b", reduced=True)
    o = OraclePredictor()
    o.register("p", LengthDistribution(np.array([6]), np.array([1.0])))
    eng = ServingEngine(
        model=build_model(cfg),
        scheduler=Scheduler(policy=make_policy("fcfs"), predictor=o),
        n_slots=2, max_seq_len=96, capacity_tokens=128, block_size=8,
        prefill_chunk=16, seed=0, clock=VirtualClock(),
        prefix_sharing=True)
    gw = Gateway(eng, GatewayConfig(max_inflight=4))
    rng = np.random.default_rng(3)
    system = [int(t) for t in rng.integers(3, cfg.vocab_size, 24)]
    reqs = [ServeRequest(f"s{i}", "p",
                         system + [int(t) for t in rng.integers(
                             3, cfg.vocab_size, 4)],
                         max_new_tokens=6, eos_token=1, tenant="acme",
                         session_id=f"sess-{i}")
            for i in range(5)]
    gw.offer_batch(reqs)
    gw.run_until_drained(max_steps=5000)
    gw.assert_all_terminal()
    gw.check_invariants()
    assert all(r.state == RequestState.FINISHED for r in reqs)
    assert eng.metrics.prefill_tokens_reused > 0
    assert_engine_quiesced(eng)


# ------------------------------------------------- simulator mirror

def test_session_workload_generator_is_consistent():
    """Deterministic per seed; session chains are well-formed: turn j+1
    shares exactly what turn j published, tenants share only the system
    prompt, and arrivals are sorted."""
    a = generate_session_workload(PROFILES, 40, rps=10.0, seed=7)
    b = generate_session_workload(PROFILES, 40, rps=10.0, seed=7)
    assert [(r.request_id, r.arrival, r.input_len, r.prefix_group,
             r.shared_prefix_len, r.sharable_prefix_len)
            for r in a] == \
           [(r.request_id, r.arrival, r.input_len, r.prefix_group,
             r.shared_prefix_len, r.sharable_prefix_len) for r in b]
    assert a != generate_session_workload(PROFILES, 40, rps=10.0, seed=8)
    assert all(r.arrival <= s.arrival for r, s in zip(a, a[1:]))
    chains: dict[str, list] = {}
    for r in a:
        assert 0 <= r.shared_prefix_len <= r.input_len
        assert 0 <= r.sharable_prefix_len <= r.input_len
        if r.prefix_group.startswith("sess-"):
            chains.setdefault(r.prefix_group, []).append(r)
    assert chains, "no multi-turn sessions generated"
    for turns in chains.values():
        turns.sort(key=lambda r: r.arrival)
        assert turns[0].shared_prefix_len == 0
        for prev, cur in zip(turns, turns[1:]):
            # each turn's prompt extends the accumulated conversation:
            # it shares the predecessor's full context (prompt + answer)
            # and publishes its whole own prompt for the next turn
            assert cur.shared_prefix_len == (prev.input_len
                                             + prev.true_output_len)
            assert cur.sharable_prefix_len == cur.input_len
            assert cur.input_len > prev.input_len


def test_cluster_session_sharing_reuses_and_speeds_up_ttft():
    """The simulator's node-level mirror: with sharing on, session
    turns skip their cached prefix — reuse is counted and mean TTFT
    can only improve (prefill work strictly shrinks)."""
    reqs = generate_session_workload(PROFILES, 60, rps=14.0, seed=2)

    def run(sharing):
        return simulate_cluster(
            reqs, lambda: Scheduler(policy=make_policy("sagesched")), 2,
            node_kwargs=dict(prefill_chunk=64, block_size=16,
                             prefix_sharing=sharing))

    off = run(False)
    on = run(True)
    assert sum(len(r.metrics) for r in off.node_results) == len(reqs)
    assert sum(len(r.metrics) for r in on.node_results) == len(reqs)
    assert on.mean_ttft <= off.mean_ttft
    # the NodeSimulator instances aren't kept on the cluster result; run
    # one node standalone to read the reuse counter on the same traffic
    sim = NodeSimulator(Scheduler(policy=make_policy("sagesched")),
                        prefill_chunk=64, block_size=16,
                        prefix_sharing=True)
    sim.run(list(reqs))
    assert sim.prefill_tokens_reused > 0
