"""Cost-model + Gittins-index math: unit and property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CostDistribution, EncDecCost, HybridCost, LinearCost,
                        OutputLengthCost, OverallLengthCost,
                        ResourceBoundCost, gittins_index, gittins_index_batch,
                        make_cost_model, mean_index)


def test_resource_bound_formula():
    cm = ResourceBoundCost()
    # C = O^2/2 + I*O  (paper Sec. 3.2)
    assert cm.total(100, 10) == pytest.approx(10 * 10 / 2 + 100 * 10)
    # attained cost is the same cumulative sum truncated
    assert cm.attained(100, 10) == pytest.approx(cm.total(100, 10))
    assert cm.attained(100, 0) == 0.0


def test_cost_model_rank_difference():
    """The paper's Fig. 2(b) point: output-length order != true cost order
    when inputs differ."""
    rb, ol = ResourceBoundCost(), OutputLengthCost()
    # A: long input short output; B: short input longer output
    a = (2000, 100)
    b = (10, 150)
    assert ol.total(*a) < ol.total(*b)            # O-based: A first
    assert rb.total(*a) > rb.total(*b)            # true cost: B first


def test_all_models_monotone_in_output():
    for name in ("resource_bound", "output_length", "overall_length",
                 "linear", "hybrid", "enc_dec"):
        cm = make_cost_model(name)
        c1, c2 = cm.total(64, 10), cm.total(64, 500)
        assert c2 > c1, name


def test_distribution_pushforward():
    cm = ResourceBoundCost()
    d = cm.distribution(100, np.array([10, 20]), np.array([0.5, 0.5]))
    assert d.support[0] == pytest.approx(10 * 10 / 2 + 1000)
    assert d.probs.sum() == pytest.approx(1.0)
    assert d.mean == pytest.approx(0.5 * (50 + 1000) + 0.5 * (200 + 2000))


def test_gittins_deterministic_equals_value():
    d = CostDistribution(np.array([42.0]), np.array([1.0]))
    assert gittins_index(d) == pytest.approx(42.0)


def test_gittins_bimodal_prefers_quick_completion():
    """Paper Fig. 6: a lottery with mass near completion gets a low index
    even when its mean is higher."""
    lottery = CostDistribution(np.array([1.0, 1000.0]), np.array([0.4, 0.6]))
    steady = CostDistribution(np.array([400.0]), np.array([1.0]))
    assert lottery.support @ lottery.probs > steady.mean * 1.2  # higher mean
    assert gittins_index(lottery) < gittins_index(steady)       # better index


def test_gittins_refresh_after_lottery_lost():
    lottery = CostDistribution(np.array([1.0, 1000.0]), np.array([0.4, 0.6]))
    g0 = gittins_index(lottery, attained=0.0)
    g_lost = gittins_index(lottery, attained=5.0)  # past the short mode
    assert g_lost > g0 * 10


@settings(max_examples=200, deadline=None)
@given(st.lists(st.floats(1.0, 1e6), min_size=1, max_size=24),
       st.lists(st.floats(0.01, 1.0), min_size=1, max_size=24))
def test_gittins_properties(support, weights):
    k = min(len(support), len(weights))
    c = np.sort(np.array(support[:k]))
    c = np.unique(c)
    p = np.array(weights[:len(c)])
    if len(p) < len(c):
        c = c[:len(p)]
    p = p / p.sum()
    d = CostDistribution(c, p)
    g = gittins_index(d)
    # Gittins <= mean (Delta = max support recovers E[X]) and >= min support
    assert g <= mean_index(d) + 1e-6
    assert g >= c[0] - 1e-9
    # scale equivariance: G(a*X) = a*G(X)
    d2 = CostDistribution(c * 3.0, p)
    assert gittins_index(d2) == pytest.approx(3.0 * g, rel=1e-9)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 17), st.integers(2, 16), st.integers(0, 2**31 - 1))
def test_gittins_batch_matches_scalar(n, k, seed):
    rng = np.random.default_rng(seed)
    sup = np.sort(rng.uniform(1, 1e5, (n, k)), axis=1)
    probs = rng.dirichlet(np.ones(k), n)
    batch = gittins_index_batch(sup, probs)
    for i in range(n):
        d = CostDistribution(sup[i], probs[i])
        # batch rows may contain duplicate support values; scalar path merges
        assert batch[i] == pytest.approx(gittins_index(d), rel=1e-6)


def test_shift_conditions_and_reorigins():
    d = CostDistribution(np.array([10.0, 20.0, 30.0]),
                         np.array([0.2, 0.3, 0.5]))
    s = d.shift(15.0)
    # mass at 10 is impossible (already consumed 15) -> conditioned out
    np.testing.assert_allclose(s.support, [5.0, 15.0])
    np.testing.assert_allclose(s.probs, [0.375, 0.625])
    # fully exhausted prediction -> assume one more max-support tail
    # (DHR belief; see CostDistribution.shift)
    s2 = d.shift(100.0)
    assert s2.support[0] == pytest.approx(30.0)
    assert s2.probs.sum() == pytest.approx(1.0)


def test_hybrid_and_encdec_adaptations():
    hy = HybridCost(attn_fraction=0.5, ssm_fraction=0.5, ssm_step_weight=2.0)
    assert hy.total(10, 4) == pytest.approx(0.5 * (8 + 40) + 1.0 * 14)
    ed = EncDecCost(encoder_weight=1.0)
    assert ed.attained(10, 0) == pytest.approx(100.0)  # encoder paid upfront
    lin = LinearCost()
    assert lin.total(10, 5) == 15.0
    ov = OverallLengthCost()
    assert ov.total(10, 5) == 20.0
