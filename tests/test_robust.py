"""Adaptive robustness under prediction drift (PR 10).

Covers the acceptance criteria:
  * ``truncate_rows`` is bit-identical to the compact scalar
    ``LengthDistribution.truncate`` oracle, and its exhausted flag fires
    exactly when a request outran its whole predicted support;
  * mid-flight posterior updates are bit-identical between the eager
    scalar object path and the batched numpy path (pallas float32-close),
    end-to-end through the simulator;
  * exhausted posteriors fall back to a proper tail belief — never a
    NaN / zero-mass row — and an empty-state refresh is a no-op;
  * ``HedgedPolicy`` order oracles: with the hedge saturated toward one
    expert, the blended order equals that expert's own scheduler order;
  * hedge weight dynamics (good predictions -> w_trust up, drifted
    predictions -> w_free up, clamp keeps both experts alive);
  * ``prediction_loss`` / ``crps`` / ``CalibrationMonitor`` unit math,
    and the scheduler actually applying conformal widening;
  * ``FlakyPredictor(mode="drift")`` and ``generate_workload(drift_*)``
    fault injection, including RNG seed compatibility;
  * ``Gateway.summary()`` surfacing calibration + hedge state.
"""

import zlib

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CalibrationMonitor, LengthDistribution, Predictor,
                        Scheduler, crps, make_policy, prediction_loss,
                        truncate_rows)
from repro.core.policies import HedgedPolicy
from repro.models import build_model
from repro.serving import Gateway, GatewayConfig, ServeRequest, ServingEngine
from repro.simulator import generate_workload, make_profile, simulate
from repro.testing import FlakyPredictor, VirtualClock, scale_distribution


def random_length_dist(rng, max_k=24, max_len=4000) -> LengthDistribution:
    k = int(rng.integers(1, max_k + 1))
    lens = np.sort(rng.choice(np.arange(1, max_len), k, replace=False))
    return LengthDistribution(lens, rng.dirichlet(np.ones(k)))


class FixedPredictor(Predictor):
    """Deterministic prompt-keyed predictor (embedding-free)."""

    def __init__(self, pool=32, seed=0, max_len=4000):
        rng = np.random.default_rng(seed)
        self.dists = [random_length_dist(rng, max_len=max_len)
                      for _ in range(pool)]

    def predict(self, prompt, input_len):
        return self.dists[zlib.crc32(prompt.encode()) % len(self.dists)]


class TinyPredictor(Predictor):
    """Every prediction is a small, easily-outrun distribution."""

    def predict(self, prompt, input_len):
        return LengthDistribution(np.array([2, 4, 6]),
                                  np.array([0.2, 0.5, 0.3]))


# ---------------------------------------------------------- truncate_rows

def test_truncate_rows_matches_scalar_truncate_bitwise():
    rng = np.random.default_rng(1)
    n, k = 40, 16
    support = np.sort(rng.integers(1, 500, (n, k)), axis=1).astype(float)
    probs = rng.dirichlet(np.ones(k), n)
    cut = rng.integers(0, 400, n).astype(float)
    out, exhausted = truncate_rows(support, probs, cut)
    for i in range(n):
        d = LengthDistribution(support[i].astype(np.int64), probs[i])
        t = d.truncate(cut[i])
        if t is None:
            assert exhausted[i]
            np.testing.assert_array_equal(out[i], probs[i])  # untouched
            continue
        assert not exhausted[i]
        alive = support[i] > cut[i]
        # dead columns carry exact zeros; survivors match the compact
        # scalar oracle bit for bit (same sequential-cumsum renormalizer)
        assert np.all(out[i][~alive] == 0.0)
        np.testing.assert_array_equal(out[i][alive], t.probs)
        assert np.cumsum(out[i])[-1] == np.cumsum(t.probs)[-1]


def test_truncate_rows_exhausted_and_padded_rows():
    # row 0 fully outrun, row 1 partially, row 2 has zero-prob padding
    support = np.array([[2., 4., 6.], [2., 4., 6.], [2., 4., 4.]])
    probs = np.array([[.2, .5, .3], [.2, .5, .3], [.4, .6, 0.]])
    out, ex = truncate_rows(support, probs, np.array([10., 3., 2.]))
    assert list(ex) == [True, False, False]
    np.testing.assert_array_equal(out[0], probs[0])
    np.testing.assert_allclose(out[1], [0., .5 / .8, .3 / .8])
    np.testing.assert_allclose(out[2], [0., 1., 0.])  # pad stays dead
    assert np.isfinite(out).all()


# ------------------------------------------------- mid-flight posteriors

def _posterior_pair(backend_a, backend_b, predictor_cls=FixedPredictor,
                    n=48, q=0.5, seed=3):
    rng = np.random.default_rng(seed)
    scheds = [Scheduler(policy=make_policy("sagesched"),
                        predictor=predictor_cls(max_len=600),
                        priority_backend=b, bucket_size=50,
                        posterior_quantile=q)
              for b in (backend_a, backend_b)]
    for i in range(n):
        il = int(rng.integers(1, 1500))
        for s in scheds:
            s.admit(f"r{i}", f"p{i % 11}", il, arrival=float(i))
    for i in range(n):
        g = int(rng.integers(0, 800))
        for s in scheds:
            s.on_progress(f"r{i}", g)
    for s in scheds:
        s.set_now(float(n))
        s.refresh()
    return scheds


def test_posterior_object_numpy_bit_identical():
    obj, num = _posterior_pair("object", "numpy")
    assert obj.stats["posterior_updates"] > 0
    assert obj.stats["posterior_updates"] == num.stats["posterior_updates"]
    for i in range(len(obj)):
        a, b = obj.get(f"r{i}"), num.get(f"r{i}")
        assert a.priority == b.priority, f"r{i}"
        assert a.posterior_cut == b.posterior_cut, f"r{i}"
    assert obj.order() == num.order()


def test_posterior_pallas_close_to_oracle():
    obj, pal = _posterior_pair("object", "pallas", n=32)
    assert pal.stats["posterior_updates"] > 0
    p_obj = np.array([obj.get(f"r{i}").priority for i in range(32)])
    p_pal = np.array([pal.get(f"r{i}").priority for i in range(32)])
    np.testing.assert_allclose(p_pal, p_obj, rtol=1e-4)


@pytest.mark.parametrize("backend", ["object", "numpy"])
def test_posterior_exhausted_fallback_never_nan(backend):
    """A request that outruns its whole predicted support gets a proper
    flat tail belief — finite, unit-mass, with a finite next cut."""
    sched = Scheduler(policy=make_policy("sagesched"),
                      predictor=TinyPredictor(), priority_backend=backend,
                      posterior_quantile=0.5)
    sched.admit("r0", "p", 100, arrival=0.0)
    sched.on_progress("r0", 50)   # far past the support max of 6
    sched.refresh()
    sr = sched.get("r0")
    assert sched.stats["posterior_updates"] >= 1
    assert np.isfinite(sr.priority)
    assert np.isfinite(sr.posterior_cut)
    assert sr.posterior_cut > 50  # next trigger is beyond current progress
    if backend == "numpy":
        st = sched._state
        i = st.index["r0"]
        row = st.len_probs[i, :st.k]
        assert np.isfinite(row).all()
        assert np.cumsum(row)[-1] == pytest.approx(1.0)
        # the fallback's support must actually extend past progress
        assert st.len_sup[i, :st.k].max() > 50


def test_posterior_refresh_on_empty_state_is_noop():
    sched = Scheduler(policy=make_policy("sagesched"),
                      predictor=TinyPredictor(),
                      posterior_quantile=0.9)
    sched.refresh()   # B = 0: must not touch any (empty) array
    assert sched.stats["posterior_updates"] == 0
    assert len(sched) == 0


def test_posterior_simulator_end_to_end_identical():
    """Full NodeSimulator runs with posterior updates enabled stay
    *identical* between the object oracle and the batched numpy path."""
    profiles = [make_profile(n) for n in ("sharegpt", "alpaca")]
    reqs = generate_workload(profiles, 200, rps=10.0, seed=5)

    def run(backend):
        sched = Scheduler(policy=make_policy("sagesched"),
                          predictor=FixedPredictor(seed=1, max_len=300),
                          priority_backend=backend,
                          posterior_quantile=0.9)
        return simulate(reqs, sched)

    a, b = run("object"), run("numpy")
    assert a.scheduler_stats["posterior_updates"] > 0
    assert a.scheduler_stats == b.scheduler_stats
    assert a.makespan == b.makespan
    assert a.n_preemptions == b.n_preemptions
    for m1, m2 in zip(a.metrics, b.metrics):
        assert m1.request_id == m2.request_id
        assert m1.ttft == m2.ttft and m1.ttlt == m2.ttlt


def test_runtime_refreshing_property():
    s1 = Scheduler(policy=make_policy("fcfs"), predictor=TinyPredictor())
    assert not s1.runtime_refreshing
    s2 = Scheduler(policy=make_policy("fcfs"), predictor=TinyPredictor(),
                   posterior_quantile=0.9)
    assert s2.runtime_refreshing  # posterior cuts are runtime boundaries


# ------------------------------------------------------------ hedged order

def _admit_same(scheds, n=40, seed=7):
    rng = np.random.default_rng(seed)
    for i in range(n):
        il = int(rng.integers(1, 1500))
        for s in scheds:
            s.admit(f"r{i}", f"p{i % 9}", il, arrival=float(i))
    for s in scheds:
        s.set_now(float(n))


def test_hedged_rejects_object_backend_and_scalar_priority():
    with pytest.raises(ValueError):
        Scheduler(policy=make_policy("hedged"), priority_backend="object")
    with pytest.raises(RuntimeError):
        HedgedPolicy().priority(None)


def test_hedged_order_saturated_trusting_matches_sagesched():
    hedged = Scheduler(policy=HedgedPolicy(w_trust=1.0),
                       predictor=FixedPredictor(seed=2))
    pure = Scheduler(policy=make_policy("sagesched"),
                     predictor=FixedPredictor(seed=2))
    _admit_same([hedged, pure])
    assert hedged.order() == pure.order()


def test_hedged_order_saturated_free_matches_fcfs():
    hedged = Scheduler(policy=HedgedPolicy(w_trust=0.0),
                       predictor=FixedPredictor(seed=2))
    pure = Scheduler(policy=make_policy("fcfs"),
                     predictor=FixedPredictor(seed=2))
    _admit_same([hedged, pure])
    assert hedged.order() == pure.order()


def test_hedge_weight_dynamics_and_clamp():
    pol = HedgedPolicy(max_len=4096)
    sharp_right = LengthDistribution(np.array([100]), np.array([1.0]))
    for _ in range(30):
        pol.observe_outcome(sharp_right, 100)
    w_t, w_f = pol.weights
    assert w_t > 0.95
    # clamp: the free expert is never fully abandoned
    assert w_f >= np.exp(-pol.max_log_ratio) / (1 + np.exp(-pol.max_log_ratio))
    # confidently-wrong predictions drive weight back toward FCFS
    for _ in range(30):
        pol.observe_outcome(sharp_right, 2000)
    w_t2, _ = pol.weights
    assert w_t2 < 0.5
    # degraded-mode admissions (no prediction) are not scored
    n = pol.updates
    pol.observe_outcome(None, 50)
    assert pol.updates == n
    assert pol.weights[0] + pol.weights[1] == pytest.approx(1.0)


def test_hedged_scheduler_updates_weights_on_complete():
    sched = Scheduler(policy=make_policy("hedged"),
                      predictor=TinyPredictor())
    sched.admit("r0", "p", 10, arrival=0.0)
    sched.on_complete("r0", output_len=500)   # way past the tiny support
    assert sched.stats["hedge"]["updates"] == 1
    assert sched.stats["hedge"]["w_trust"] < 0.5


# ---------------------------------------------- loss / crps / calibration

def test_prediction_loss_anchors():
    point = LengthDistribution(np.array([100]), np.array([1.0]))
    assert prediction_loss(point, 100, 4096) < 0.25      # sharp and right
    assert prediction_loss(point, 3000, 4096) > 0.75     # confidently wrong
    grid = np.arange(1, 4097)
    flat = LengthDistribution(grid, np.full(grid.size, 1 / grid.size))
    assert prediction_loss(flat, 500, 4096) == pytest.approx(0.5, abs=0.05)


def test_crps_anchors():
    # point mass on the truth: perfect score
    assert crps(np.array([50.]), np.array([1.0]), 50) == 0.0
    # point mass off by d: crps == |d| for a deterministic forecast
    assert crps(np.array([50.]), np.array([1.0]), 80) == pytest.approx(30.0)
    # more bias -> worse score
    a = crps(np.array([40., 60.]), np.array([.5, .5]), 50)
    b = crps(np.array([40., 60.]), np.array([.5, .5]), 200)
    assert 0 < a < b


def test_calibration_monitor_coverage_and_widening():
    mon = CalibrationMonitor(window=64, quantiles=(0.5, 0.9),
                             min_samples=8, widen_gain=2.0, max_widen=0.5)
    wide = LengthDistribution(np.array([10, 100, 1000]),
                              np.array([.1, .8, .1]))
    assert mon.widen_weight("t") == 0.0   # unseen tenant
    for _ in range(4):
        mon.observe("t", wide, 50)
    assert mon.widen_weight("t") == 0.0   # below min_samples
    for _ in range(20):
        mon.observe("t", wide, 5000)      # every outcome escapes coverage
    w = mon.widen_weight("t")
    assert w == 0.5                       # deficit-driven, capped
    s = mon.summary()["t"]
    assert s["count"] == 24
    assert s["coverage@0.9"] < 0.2
    assert s["observed_over_predicted"] > 10
    assert s["crps_tokens"] > 0
    # a well-covered tenant widens by exactly 0
    for _ in range(20):
        mon.observe("ok", wide, 100)
    assert mon.widen_weight("ok") == 0.0


def test_scheduler_applies_conformal_widening():
    mon = CalibrationMonitor(min_samples=4, quantiles=(0.5, 0.9))
    tiny = TinyPredictor().predict("p", 1)
    for _ in range(8):
        mon.observe("hot", tiny, 500)     # badly under-covered tenant
    sched = Scheduler(policy=make_policy("sagesched"),
                      predictor=TinyPredictor(), calibration=mon)
    sr_cold = sched.admit("a", "p", 10, arrival=0.0, tenant="cold")
    sr_hot = sched.admit("b", "p", 10, arrival=1.0, tenant="hot")
    assert sched.stats["conformal_widenings"] == 1
    # the stored belief widened toward the flat prior...
    assert sr_hot.length_dist.lengths.max() > sr_cold.length_dist.lengths.max()
    # ...but the graded admission-time prediction stays pristine
    np.testing.assert_array_equal(sr_hot.pred_dist.lengths,
                                  tiny.lengths)
    # completions feed the monitor keyed by tenant
    sched.on_complete("a", output_len=4)
    assert sched.calibration_summary()["cold"]["count"] == 1


# ------------------------------------------------------- drift injection

def test_flaky_predictor_drift_ramp():
    inner = TinyPredictor()
    flaky = FlakyPredictor(inner, mode="drift", fail_after=2,
                           n_failures=4, drift_scale=3.0, drift_bias=10.0)
    base = inner.predict("p", 1)
    d0 = flaky.predict("p", 1)            # before the window: verbatim
    np.testing.assert_array_equal(d0.lengths, base.lengths)
    flaky.predict("p", 1)
    for _ in range(3):
        flaky.predict("p", 1)
    d_end = flaky.predict("p", 1)         # last call of the window: full
    want = scale_distribution(base, 3.0, 10.0)
    np.testing.assert_array_equal(d_end.lengths, want.lengths)
    np.testing.assert_allclose(d_end.probs, want.probs)
    assert flaky.faults == 4


def test_scale_distribution_merges_collisions():
    d = LengthDistribution(np.array([1, 2, 3]), np.array([.2, .3, .5]))
    s = scale_distribution(d, 0.4)        # 1,2,3 -> 1,1,1
    np.testing.assert_array_equal(s.lengths, [1])
    assert np.cumsum(s.probs)[-1] == pytest.approx(1.0)


def test_workload_drift_seed_compatibility():
    prof = [make_profile("sharegpt")]
    base = generate_workload(prof, 60, rps=10.0, seed=9)
    same = generate_workload(prof, 60, rps=10.0, seed=9, drift_scale=1.0)
    drifted = generate_workload(prof, 60, rps=10.0, seed=9,
                                drift_scale=2.0, drift_mode="step",
                                drift_start=0.5)
    for a, b, d in zip(base, same, drifted):
        # scale 1.0 is bit-identical to the undrifted generator
        assert (a.arrival, a.prompt, a.input_len, a.true_output_len) == \
               (b.arrival, b.prompt, b.input_len, b.true_output_len)
        assert b.drift_factor == 1.0
        # a drifted trace touches ONLY the true lengths
        assert (a.arrival, a.prompt, a.input_len) == \
               (d.arrival, d.prompt, d.input_len)
    first, second = drifted[:30], drifted[30:]
    assert all(r.drift_factor == 1.0 for r in first)
    assert all(r.drift_factor == 2.0 for r in second)
    assert any(d.true_output_len != a.true_output_len
               for a, d in zip(base[30:], second))


def test_workload_drift_mode_validation():
    with pytest.raises(ValueError):
        generate_workload([make_profile("sharegpt")], 4, rps=1.0,
                          drift_mode="sideways")


# --------------------------------------------------------- gateway summary

def test_gateway_summary_surfaces_calibration_and_hedge():
    cfg = get_config("llama3.2-1b", reduced=True)
    sched = Scheduler(policy=make_policy("hedged"),
                      predictor=TinyPredictor())
    eng = ServingEngine(model=build_model(cfg), scheduler=sched,
                        n_slots=2, max_seq_len=96, seed=0,
                        clock=VirtualClock())
    gw = Gateway(eng, GatewayConfig(max_inflight=2))
    rng = np.random.default_rng(0)
    for i in range(2):
        toks = [int(t) for t in rng.integers(3, cfg.vocab_size, 6)]
        gw.offer(ServeRequest(request_id=f"g{i}", prompt="p",
                              prompt_tokens=toks, max_new_tokens=4,
                              eos_token=0))
    gw.run_until_drained(max_steps=500)
    s = gw.summary()
    assert s["queued"] == 0 and s["inflight"] == 0
    assert s["dispositions"] == {"FINISHED": 2}
    assert s["disposition_reasons"] == {"finished:length": 2}
    assert s["calibration"]["default"]["count"] == 2
    assert s["hedge"]["updates"] == 2
    assert not s["degraded"]
    # engine metrics carry the same calibration table
    assert eng.metrics.calibration == s["calibration"]
    assert "calibration" in eng.metrics.summary([])
