"""Unit coverage for the efficient-mode tolerance contract.

Two halves:

1. ``testing.assert_tokens_close`` — the contract itself must be sharp:
   it passes bit-identical streams, charges autoregressive suffix drift
   as ONE divergence, and catches the injected failure mode it exists
   for (an ulp-scale logit perturbation flipping a sampling threshold).

2. ``models.attention.combine_lse_partials`` — the LSE-combine merge
   must equal a dense softmax over the concatenated sequence to f32
   tolerance for *random* splits, including degenerate (fully-masked)
   stripes.  This is the algebraic fact the sharded lse-split attention
   path rests on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import combine_lse_partials
from repro.testing import TokenMismatch, assert_tokens_close


# ------------------------------------------------- assert_tokens_close

def test_bit_identical_streams_pass():
    streams = [[1, 2, 3, 4], [9, 8, 7]]
    stats = assert_tokens_close(streams, [list(s) for s in streams],
                                bit_identical=True)
    assert stats["rate"] == 1.0 and stats["divergences"] == 0


def test_single_stream_int_form():
    stats = assert_tokens_close([1, 2, 3], [1, 2, 3])
    assert stats["compared"] == 3


def test_bit_identical_rejects_any_flip():
    with pytest.raises(TokenMismatch, match="bit-identical"):
        assert_tokens_close([[1, 2, 3]], [[1, 2, 4]], bit_identical=True)


def test_suffix_drift_charged_once():
    """Everything after the first flip is autoregressive consequence,
    not independent evidence: a long stream that diverges at position
    500 of 1000 has match rate 500/501, not 500/1000."""
    want = list(range(1000))
    got = want[:500] + [x + 1 for x in want[500:]]
    stats = assert_tokens_close([got], [want], min_match_rate=0.99)
    assert stats["divergences"] == 1
    assert stats["compared"] == 501 and stats["matched"] == 500
    # but an early flip in a short stream fails the default 0.999 bar
    with pytest.raises(TokenMismatch, match="match rate"):
        assert_tokens_close([[5, 1, 2]], [[4, 1, 2]])


def test_length_mismatch_is_divergence():
    with pytest.raises(TokenMismatch):
        assert_tokens_close([[1, 2]], [[1, 2, 3]], bit_identical=True)


def test_catches_ulp_perturbation_flipping_threshold():
    """The injected failure the contract exists to catch: perturb the
    reference logits by one bf16 ulp so that a near-tied greedy argmax
    flips, decode both streams, and require the checker to flag it when
    the flip rate is material."""
    rng = np.random.default_rng(0)
    vocab, steps = 64, 400
    base = rng.normal(size=(steps, vocab)).astype(np.float32)
    # engineer near-ties every 4th step: runner-up within half an ulp
    tie = np.arange(0, steps, 4)
    top = base[tie].argmax(axis=1)
    runner = (top + 1) % vocab
    base[tie, runner] = base[tie, top] - 1e-4
    perturbed = base.copy()
    perturbed[tie, runner] += 2e-4          # flips exactly the ties

    want = [list(base.argmax(axis=1))]
    got = [list(perturbed.argmax(axis=1))]
    with pytest.raises(TokenMismatch, match="match rate"):
        assert_tokens_close(got, want)
    # the same perturbation below the tie margin changes nothing
    ok = base.copy()
    ok[tie, runner] += 1e-5
    stats = assert_tokens_close([list(ok.argmax(axis=1))], want,
                                bit_identical=True)
    assert stats["rate"] == 1.0


def test_logit_drift_bound():
    with pytest.raises(TokenMismatch, match="logit drift"):
        assert_tokens_close([[1, 2]], [[1, 2]],
                            logits=np.array([0.0, 1.0]),
                            ref_logits=np.array([0.0, 2.0]),
                            max_logit_diff=0.5)
    stats = assert_tokens_close([[1, 2]], [[1, 2]],
                                logits=np.array([0.0, 1.0]),
                                ref_logits=np.array([0.0, 1.0001]),
                                max_logit_diff=0.5)
    assert stats["max_logit_diff"] < 0.5


# -------------------------------------------- combine_lse_partials law

def _dense_softmax_attn(scores, v):
    """scores (h, S) f32, v (S, dh) -> (out (h, dh), lse (h,))."""
    m = scores.max(axis=1, keepdims=True)
    p = np.exp(scores - m)
    l = p.sum(axis=1, keepdims=True)
    return (p / l) @ v, (m + np.log(l))[:, 0]


@pytest.mark.parametrize("seed", range(5))
def test_lse_combine_matches_dense_softmax(seed):
    """For ANY partition of the key sequence, per-stripe normalized
    partials merged by LSE combine equal the dense softmax over the
    whole sequence — to f32 tolerance."""
    rng = np.random.default_rng(seed)
    h, S, dh = 6, 96, 32
    scores = rng.normal(scale=3.0, size=(h, S)).astype(np.float32)
    v = rng.normal(size=(S, dh)).astype(np.float32)
    want_out, want_lse = _dense_softmax_attn(scores, v)

    # random split points, including size-1 stripes
    n_splits = int(rng.integers(2, 6))
    cuts = np.sort(rng.choice(np.arange(1, S), n_splits - 1,
                              replace=False))
    bounds = [0, *cuts.tolist(), S]
    outs, lses = [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        o, l = _dense_softmax_attn(scores[:, lo:hi], v[lo:hi])
        outs.append(o)
        lses.append(l)
    got_out, got_lse = combine_lse_partials(
        jnp.stack(outs), jnp.stack(lses))
    np.testing.assert_allclose(np.asarray(got_out), want_out,
                               atol=2e-6, rtol=2e-6)
    np.testing.assert_allclose(np.asarray(got_lse), want_lse,
                               atol=2e-6, rtol=2e-6)


def test_lse_combine_fully_masked_stripe_weighs_zero():
    """A stripe whose every key is masked contributes lse ~ -1e30; its
    merge weight must underflow to exactly 0, not NaN."""
    rng = np.random.default_rng(3)
    h, S, dh = 4, 32, 16
    scores = rng.normal(size=(h, S)).astype(np.float32)
    v = rng.normal(size=(S, dh)).astype(np.float32)
    want_out, want_lse = _dense_softmax_attn(scores, v)

    masked = np.full((h, S), -1e30, np.float32)
    o_live, l_live = _dense_softmax_attn(scores, v)
    o_dead, l_dead = _dense_softmax_attn(masked, v)
    got_out, got_lse = combine_lse_partials(
        jnp.stack([o_live, o_dead]), jnp.stack([l_live, l_dead]))
    assert np.isfinite(np.asarray(got_out)).all()
    np.testing.assert_allclose(np.asarray(got_out), want_out,
                               atol=2e-6, rtol=2e-6)
    np.testing.assert_allclose(np.asarray(got_lse), want_lse,
                               atol=2e-6, rtol=2e-6)


def test_lse_combine_axis_argument():
    rng = np.random.default_rng(4)
    outs = rng.normal(size=(3, 5, 2, 8)).astype(np.float32)
    lses = rng.normal(size=(3, 5, 2)).astype(np.float32)
    o0, l0 = combine_lse_partials(jnp.asarray(outs), jnp.asarray(lses))
    o1, l1 = combine_lse_partials(
        jnp.asarray(np.moveaxis(outs, 0, 1)),
        jnp.asarray(np.moveaxis(lses, 0, 1)), axis=1)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), atol=1e-6)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-6)
